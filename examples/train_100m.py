"""End-to-end driver (deliverable b): train a ~100M-parameter llama-family
model for a few hundred steps on synthetic data, with checkpointing and
fault tolerance enabled.

  PYTHONPATH=src python examples/train_100m.py            # 300 steps (~30-60min CPU)
  PYTHONPATH=src python examples/train_100m.py --quick    # 40 steps

The config: 8L, d_model=768, d_ff=3072, vocab 32768 (tied) -> ~100M params.
Loss on the synthetic zipf+markov stream: 10.51 -> 9.1 over 150 steps
(recorded run in EXPERIMENTS.md §Training). Requires the 1/sqrt(2L)
residual-init damping (models/model.py) — without it the embedding-table
gradient explodes to ~2.6e6 and learning stalls.
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args, _ = ap.parse_known_args()
    steps = args.steps or (40 if args.quick else 300)
    sys.exit(train_main([
        "--arch", "llama3.2-1b",        # family template...
        "--layers", "8",                 # ...resized to ~100M params
        "--d-model", "768",
        "--steps", str(steps),
        "--batch", "4", "--seq", "128",
        "--lr", "2e-3",
        "--ckpt-dir", "/tmp/repro_100m_ckpt",
        "--ckpt-every", "50",
        "--log-every", "10",
    ]))
