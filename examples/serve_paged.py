"""Paged serving example: continuous batching with zero-copy admission,
copy-on-write prefix sharing, SVA/TLB statistics, and the adaptive
translation front-end (IOTLB prefetching + online TLB-geometry
auto-tuning).

Most requests open with the same system prompt, so admission maps the
already-resident prefix pages (refcount++) and prefills only each prompt's
suffix; exact-duplicate prompts also share the partial tail page and
CoW-duplicate it on their first divergent token.

  PYTHONPATH=src python examples/serve_paged.py
  PYTHONPATH=src python examples/serve_paged.py --tlb-prefetch stream \
      --tlb-autotune 4
  PYTHONPATH=src python examples/serve_paged.py --scheduler continuous

``--scheduler continuous`` switches to the token-budget continuous-batching
scheduler and serves the same requests as two bursty arrival waves over an
OVERSUBSCRIBED page pool (``--pool-pages``): admission is lazy (prompt
pages only), prompts prefill in chunks inside mixed decode steps, and pool
pressure is resolved by preempting the newest sequence (its KV goes warm
into the prefix cache; resume re-matches it) — watch the preemptions /
resumes / steps-to-first-token lines.

``--disagg share`` (or ``copy``) splits the same demo across a prefill
worker and a decode worker connected by IOMMU-priced KV transfers:
finished prefills migrate to the decode worker's slots, zero-copy (page
re-attachment under the decode ASID) or staged (device-side batched page
copy) — watch the transfer line for bytes moved and remote-DMA PTW
cycles. Outputs are bit-identical to the colocated engines either way.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.disagg import DisaggEngine
from repro.core.serving.engine import ServingEngine
from repro.core.sva.iommu import IOMMU, Sv39Walk, TLBConfig
from repro.models import init_params

ap = argparse.ArgumentParser(
    description="Paged serving demo over the SVA/IOMMU stack. The serving "
                "TLB's static geometry comes from ModelConfig.serve_tlb_"
                "{entries,ways,policy}; the flags below arm the ADAPTIVE "
                "front-end on top of it.",
    epilog="Geometry/policy methodology and the static-vs-adaptive "
           "benchmark contract are documented in benchmarks/README.md "
           "(see benchmarks/tlb_sweep.py for the full design-space sweep "
           "and paged_serving.py --translation-report for modeled PTW "
           "overhead).")
ap.add_argument("--tlb-prefetch", default="none",
                choices=("none", "next_page", "stream"),
                help="IOTLB prefetch policy on the decode gather stream "
                     "(ModelConfig.serve_tlb_prefetch_policy)")
ap.add_argument("--tlb-prefetch-degree", type=int, default=2,
                help="prefetch fills issued per trigger")
ap.add_argument("--tlb-prefetch-distance", type=int, default=4,
                help="stream run-ahead distance in pages")
ap.add_argument("--tlb-autotune", type=int, default=0, metavar="STEPS",
                help="auto-tune the serving TLB geometry online with this "
                     "measurement window in decode steps "
                     "(ModelConfig.serve_tlb_autotune; 0 = off)")
ap.add_argument("--tlb-ranges", type=int, default=0, metavar="N",
                help="range-coalesced IOTLB entries: one entry covers a "
                     "physically contiguous run of up to N pages "
                     "(ModelConfig.serve_tlb_ranges; 0 = per-page, else "
                     ">= 2 — watch the range: block in the IOMMU stats)")
ap.add_argument("--scheduler", default="fixed",
                choices=("fixed", "continuous"),
                help="continuous = token-budget scheduling with chunked "
                     "prefill and preempt/resume, demoed as two bursty "
                     "arrival waves over an oversubscribed pool")
ap.add_argument("--disagg", default="off",
                choices=("off", "copy", "share"),
                help="disaggregate into a 2-slot prefill worker + 2-slot "
                     "decode worker; finished prefills hand their KV off "
                     "by IOMMU-priced migration (share = zero-copy page "
                     "re-attachment, copy = staged payload). Implies the "
                     "continuous two-wave demo")
ap.add_argument("--pool-pages", type=int, default=0,
                help="physical KV page pool size (0 = full n_slots*pages "
                     "reservation; --scheduler continuous defaults to an "
                     "oversubscribed 16-page pool so preemption fires)")
args = ap.parse_args()

cfg = reduce_for_smoke(get_config("qwen2-7b"))
cfg = dataclasses.replace(
    cfg,
    serve_tlb_prefetch_policy=args.tlb_prefetch,
    serve_tlb_prefetch_degree=args.tlb_prefetch_degree,
    serve_tlb_prefetch_distance=args.tlb_prefetch_distance,
    serve_tlb_autotune=args.tlb_autotune,
    serve_tlb_ranges=args.tlb_ranges,
    # Small-TLB demo geometry when auto-tuning, so the ladder has room to
    # differentiate within a short example run.
    serve_tlb_entries=64 if args.tlb_autotune else cfg.serve_tlb_entries)
params = init_params(cfg, jax.random.key(0))
bursty = args.scheduler == "continuous" or args.disagg != "off"
pool_pages = args.pool_pages or (16 if bursty else 0)
if args.disagg != "off":
    # Prefill/decode disaggregation at the same total width; the transfer
    # fabric prices each hand-off as the paper's 4-entry IOTLB over a
    # no-LLC Sv39 walk (remote DMA by virtual address).
    eng = DisaggEngine(cfg, params, n_prefill_slots=2, n_decode_slots=2,
                       max_len=128, page_size=8, offload_mode="zero_copy",
                       disagg_mode=args.disagg,
                       xfer_iommu=IOMMU(walk_model=Sv39Walk(llc=False),
                                        tlb=TLBConfig(4, "lru")),
                       pool_pages=pool_pages or None,
                       translation_stats=True)
else:
    eng = ServingEngine(cfg, params, n_slots=4, max_len=128, page_size=8,
                        offload_mode="zero_copy",
                        scheduler=args.scheduler,
                        pool_pages=pool_pages or None,
                        translation_stats=True)  # live IOTLB hit/miss counts

rng = np.random.default_rng(0)
system = rng.integers(0, cfg.vocab_size, size=16).tolist()  # shared prefix
prompts = [system + rng.integers(0, cfg.vocab_size,
                                 size=rng.integers(2, 8)).tolist()
           for _ in range(7)]
prompts.append(list(prompts[1]))                 # exact duplicate
prompts += [rng.integers(0, cfg.vocab_size, size=12).tolist()
            for _ in range(2)]                   # unrelated
if bursty:
    workers = (f"a 2-slot prefill worker + 2-slot decode worker "
               f"({args.disagg}-mode KV transfer) and " if args.disagg != "off"
               else "")
    print(f"two bursty arrival waves of 10 requests over {workers}an "
          f"oversubscribed {eng.mgr.pool.n_pages}-page pool (lazy "
          "admission, chunked prefill, preempt/resume under pressure)...")
    finished = {}
    # Longer generations than the fixed demo: decode growth (one page per
    # 8 tokens per sequence) is what oversubscribes the pool.
    rids = [eng.submit(p, max_tokens=24) for p in prompts[:6]]
    for _ in range(3):                           # burst 2 lands mid-serve
        eng.step(finished)
    rids += [eng.submit(p, max_tokens=24) for p in prompts[6:]]
    done = {**finished, **eng.run()}
else:
    print("submitting 10 requests into 4 slots (continuous batching; "
          "8 share a system prompt, 2 are exact duplicates)...")
    rids = [eng.submit(p, max_tokens=10) for p in prompts]
    done = eng.run()
for rid in rids[:4]:
    r = done[rid]
    print(f"  req {rid}: ttft {(r.first_token_at-r.submitted_at)*1e3:6.0f}ms "
          f"-> {r.out_tokens}")
s = eng.stats()
print(f"\n{s['tokens']} tokens, {s['decode_steps']} decode steps, "
      f"{s['prefills']} prefills")
print(f"SVA: {s['sva']}")
print(f"TLB: {s['tlb']}")
print(f"IOMMU: {s['iommu']}  (unified front-end; the simulator's 4-entry "
      "IOTLB is the same class)")
if "range" in s["iommu"]:
    rg = s["iommu"]["range"]
    print(f"range entries (<= {rg['max_run']} pages each): "
          f"fills={rg['fills']} hits={rg['hits']} "
          f"coalesced_pages={rg['coalesced_pages']} splits={rg['splits']} "
          f"resident={rg['n_ranges']}; contiguity-hinted allocs: "
          f"run_allocs={s['pool_run_allocs']} "
          f"fallbacks={s['pool_run_fallbacks']}")
if "autotune" in s["iommu"]:
    at = s["iommu"]["autotune"]
    print(f"auto-tuner: phase={at['phase']} switches={at['switches']} "
          f"windows={at['windows']} -> current geometry "
          f"e{s['iommu']['tlb_entries']}.w{s['iommu']['tlb_ways']}."
          f"{s['iommu']['tlb_policy']} (explored: {at['explored']})")
if bursty:
    sc = s["sched"]
    ttft = [done[r].first_token_step - done[r].submitted_step for r in rids]
    print(f"scheduler: preemptions={sc['preemptions']} "
          f"resumes={sc['resumes']}; steps-to-first-token "
          f"mean={np.mean(ttft):.1f} max={max(ttft)}")
if args.disagg != "off":
    t = s["transfer"]
    print(f"transfers: {t['transfers']} ({args.disagg}): "
          f"pages shared={t['pages_shared']} copied={t['pages_copied']}, "
          f"payload {t['payload_bytes']}B + table {t['table_bytes']}B, "
          f"remote-DMA PTW {t['ptw_cycles']:.0f} cycles "
          f"(deferred={s['disagg']['deferred']} "
          f"cancelled={s['disagg']['cancelled']})")
print(f"prefix cache: {s['prefix']}")
print(f"prefill tokens saved: {s['prefill_tokens_saved']} "
      f"(shared admissions: {s['shared_admissions']}); "
      f"CoW page copies: {s['cow_page_copies']}")
print(f"pages used/free: {s['pool_used']}/{s['pool_free']} "
      f"(warm prefix cache retains pages after completion)")
