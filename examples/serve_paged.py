"""Paged serving example: continuous batching with zero-copy admission,
copy-on-write prefix sharing, and SVA/TLB statistics.

Most requests open with the same system prompt, so admission maps the
already-resident prefix pages (refcount++) and prefills only each prompt's
suffix; exact-duplicate prompts also share the partial tail page and
CoW-duplicate it on their first divergent token.

  PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.engine import ServingEngine
from repro.models import init_params

cfg = reduce_for_smoke(get_config("qwen2-7b"))
params = init_params(cfg, jax.random.key(0))
eng = ServingEngine(cfg, params, n_slots=4, max_len=128, page_size=8,
                    offload_mode="zero_copy",
                    translation_stats=True)   # live IOTLB hit/miss counting

rng = np.random.default_rng(0)
system = rng.integers(0, cfg.vocab_size, size=16).tolist()  # shared prefix
print("submitting 10 requests into 4 slots (continuous batching; "
      "8 share a system prompt, 2 are exact duplicates)...")
prompts = [system + rng.integers(0, cfg.vocab_size,
                                 size=rng.integers(2, 8)).tolist()
           for _ in range(7)]
prompts.append(list(prompts[1]))                 # exact duplicate
prompts += [rng.integers(0, cfg.vocab_size, size=12).tolist()
            for _ in range(2)]                   # unrelated
rids = [eng.submit(p, max_tokens=10) for p in prompts]
done = eng.run()
for rid in rids[:4]:
    r = done[rid]
    print(f"  req {rid}: ttft {(r.first_token_at-r.submitted_at)*1e3:6.0f}ms "
          f"-> {r.out_tokens}")
s = eng.stats()
print(f"\n{s['tokens']} tokens, {s['decode_steps']} decode steps, "
      f"{s['prefills']} prefills")
print(f"SVA: {s['sva']}")
print(f"TLB: {s['tlb']}")
print(f"IOMMU: {s['iommu']}  (unified front-end; the simulator's 4-entry "
      "IOTLB is the same class)")
print(f"prefix cache: {s['prefix']}")
print(f"prefill tokens saved: {s['prefill_tokens_saved']} "
      f"(shared admissions: {s['shared_admissions']}); "
      f"CoW page copies: {s['cow_page_copies']}")
print(f"pages used/free: {s['pool_used']}/{s['pool_free']} "
      f"(warm prefix cache retains pages after completion)")
