"""Paged serving example: continuous batching with zero-copy admission,
prefix-shared pages, and SVA/TLB statistics.

  PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.engine import ServingEngine
from repro.models import init_params

cfg = reduce_for_smoke(get_config("qwen2-7b"))
params = init_params(cfg, jax.random.key(0))
eng = ServingEngine(cfg, params, n_slots=4, max_len=128, page_size=8,
                    offload_mode="zero_copy")

rng = np.random.default_rng(0)
print("submitting 10 requests into 4 slots (continuous batching)...")
rids = [eng.submit(rng.integers(0, cfg.vocab_size, size=rng.integers(4, 20))
                   .tolist(), max_tokens=10) for _ in range(10)]
done = eng.run()
for rid in rids[:4]:
    r = done[rid]
    print(f"  req {rid}: ttft {(r.first_token_at-r.submitted_at)*1e3:6.0f}ms "
          f"-> {r.out_tokens}")
s = eng.stats()
print(f"\n{s['tokens']} tokens, {s['decode_steps']} decode steps, "
      f"{s['prefills']} prefills")
print(f"SVA: {s['sva']}")
print(f"TLB: {s['tlb']}")
print(f"pages used/free: {s['pool_used']}/{s['pool_free']}")
