"""Paged serving example: continuous batching with zero-copy admission,
copy-on-write prefix sharing, SVA/TLB statistics, and the adaptive
translation front-end (IOTLB prefetching + online TLB-geometry
auto-tuning).

Most requests open with the same system prompt, so admission maps the
already-resident prefix pages (refcount++) and prefills only each prompt's
suffix; exact-duplicate prompts also share the partial tail page and
CoW-duplicate it on their first divergent token.

  PYTHONPATH=src python examples/serve_paged.py
  PYTHONPATH=src python examples/serve_paged.py --tlb-prefetch stream \
      --tlb-autotune 4
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.engine import ServingEngine
from repro.models import init_params

ap = argparse.ArgumentParser(
    description="Paged serving demo over the SVA/IOMMU stack. The serving "
                "TLB's static geometry comes from ModelConfig.serve_tlb_"
                "{entries,ways,policy}; the flags below arm the ADAPTIVE "
                "front-end on top of it.",
    epilog="Geometry/policy methodology and the static-vs-adaptive "
           "benchmark contract are documented in benchmarks/README.md "
           "(see benchmarks/tlb_sweep.py for the full design-space sweep "
           "and paged_serving.py --translation-report for modeled PTW "
           "overhead).")
ap.add_argument("--tlb-prefetch", default="none",
                choices=("none", "next_page", "stream"),
                help="IOTLB prefetch policy on the decode gather stream "
                     "(ModelConfig.serve_tlb_prefetch_policy)")
ap.add_argument("--tlb-prefetch-degree", type=int, default=2,
                help="prefetch fills issued per trigger")
ap.add_argument("--tlb-prefetch-distance", type=int, default=4,
                help="stream run-ahead distance in pages")
ap.add_argument("--tlb-autotune", type=int, default=0, metavar="STEPS",
                help="auto-tune the serving TLB geometry online with this "
                     "measurement window in decode steps "
                     "(ModelConfig.serve_tlb_autotune; 0 = off)")
args = ap.parse_args()

cfg = reduce_for_smoke(get_config("qwen2-7b"))
cfg = dataclasses.replace(
    cfg,
    serve_tlb_prefetch_policy=args.tlb_prefetch,
    serve_tlb_prefetch_degree=args.tlb_prefetch_degree,
    serve_tlb_prefetch_distance=args.tlb_prefetch_distance,
    serve_tlb_autotune=args.tlb_autotune,
    # Small-TLB demo geometry when auto-tuning, so the ladder has room to
    # differentiate within a short example run.
    serve_tlb_entries=64 if args.tlb_autotune else cfg.serve_tlb_entries)
params = init_params(cfg, jax.random.key(0))
eng = ServingEngine(cfg, params, n_slots=4, max_len=128, page_size=8,
                    offload_mode="zero_copy",
                    translation_stats=True)   # live IOTLB hit/miss counting

rng = np.random.default_rng(0)
system = rng.integers(0, cfg.vocab_size, size=16).tolist()  # shared prefix
print("submitting 10 requests into 4 slots (continuous batching; "
      "8 share a system prompt, 2 are exact duplicates)...")
prompts = [system + rng.integers(0, cfg.vocab_size,
                                 size=rng.integers(2, 8)).tolist()
           for _ in range(7)]
prompts.append(list(prompts[1]))                 # exact duplicate
prompts += [rng.integers(0, cfg.vocab_size, size=12).tolist()
            for _ in range(2)]                   # unrelated
rids = [eng.submit(p, max_tokens=10) for p in prompts]
done = eng.run()
for rid in rids[:4]:
    r = done[rid]
    print(f"  req {rid}: ttft {(r.first_token_at-r.submitted_at)*1e3:6.0f}ms "
          f"-> {r.out_tokens}")
s = eng.stats()
print(f"\n{s['tokens']} tokens, {s['decode_steps']} decode steps, "
      f"{s['prefills']} prefills")
print(f"SVA: {s['sva']}")
print(f"TLB: {s['tlb']}")
print(f"IOMMU: {s['iommu']}  (unified front-end; the simulator's 4-entry "
      "IOTLB is the same class)")
if "autotune" in s["iommu"]:
    at = s["iommu"]["autotune"]
    print(f"auto-tuner: phase={at['phase']} switches={at['switches']} "
          f"windows={at['windows']} -> current geometry "
          f"e{s['iommu']['tlb_entries']}.w{s['iommu']['tlb_ways']}."
          f"{s['iommu']['tlb_policy']} (explored: {at['explored']})")
print(f"prefix cache: {s['prefix']}")
print(f"prefill tokens saved: {s['prefill_tokens_saved']} "
      f"(shared admissions: {s['shared_admissions']}); "
      f"CoW page copies: {s['cow_page_copies']}")
print(f"pages used/free: {s['pool_used']}/{s['pool_free']} "
      f"(warm prefix cache retains pages after completion)")
