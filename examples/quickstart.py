"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config, reduce_for_smoke
from repro.core.simulator.run import simulate_kernel
from repro.launch.steps import make_train_step
from repro.models import (NO_MESH, forward_decode, forward_prefill,
                          init_cache, init_params)
from repro.optim import init_opt_state

# ---- 1. pick an assigned architecture (reduced for CPU) -------------------
cfg = reduce_for_smoke(get_config("llama3.2-1b"))
params = init_params(cfg, jax.random.key(0))
print(f"model: {cfg.name} ({cfg.family}), {cfg.n_layers} layers")

# ---- 2. one training step --------------------------------------------------
step = make_train_step(cfg, TrainConfig(lr=1e-3), NO_MESH)
batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 64), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(jax.random.key(2), (2, 64), 0,
                                      cfg.vocab_size)}
params, opt, metrics = step(params, init_opt_state(params), batch)
print(f"train loss: {float(metrics['loss']):.4f}")

# ---- 3. paged serving: prefill then decode through block tables ------------
cache = init_cache(cfg, batch=2, max_len=96, page_size=8)
logits, cache = forward_prefill(cfg, params, {"tokens": batch["tokens"]},
                                cache)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
for i in range(4):
    logits, cache = forward_decode(cfg, params, tok, jnp.int32(64 + i), cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
print("decoded tokens:", tok[:, 0].tolist())

# ---- 4. the paper's platform simulator --------------------------------------
for config in ("baseline", "iommu", "iommu_llc"):
    r = simulate_kernel("gemm", config, dram_latency=1000)
    print(f"gemm@1000cyc {config:10s}: {r.total:.3g} cycles "
          f"(DMA {r.dma_pct:.1f}%, {r.walks:.0f} walks)")
