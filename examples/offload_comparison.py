"""The paper's Fig. 2 experiment, at both scales:

 1. the simulator reproduces the FPGA platform numbers (host vs copy-based
    vs zero-copy offload of axpy@32768), and
 2. the serving engine runs the same A/B (copy vs zero-copy admission) live.

  PYTHONPATH=src python examples/offload_comparison.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.engine import ServingEngine
from repro.core.simulator.run import offload_breakdown
from repro.models import init_params

print("=== paper scale (simulator, host cycles, L=200) ===")
for mode in ("host", "copy", "zero_copy"):
    b = offload_breakdown(mode, 32768, 200)
    print(f"  {mode:9s}: total {b.total:9.0f}  "
          f"(xfer {b.xfer:8.0f} | offload {b.offload:6.0f} | "
          f"compute {b.compute:7.0f})")
cb = offload_breakdown("copy", 32768, 200).total
zb = offload_breakdown("zero_copy", 32768, 200).total
print(f"  zero-copy is {100*(1-zb/cb):.1f}% faster (paper: 47%)\n")

print("=== serving scale (engine wall time, CPU) ===")
cfg = reduce_for_smoke(get_config("llama3.2-1b"))
params = init_params(cfg, jax.random.key(0))
for mode in ("copy", "zero_copy"):
    eng = ServingEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                        offload_mode=mode)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, size=12).tolist(),
                   max_tokens=8)
    eng.run()
    s = eng.stats()
    print(f"  {mode:9s}: {time.perf_counter()-t0:6.2f}s  "
          f"staging_copies={s['staging_copies']} "
          f"bytes_copied={s['sva']['bytes_copied']}")
