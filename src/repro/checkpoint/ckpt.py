"""Sharded, async, atomic checkpointing with elastic restore.

Layout per step:  <dir>/step_<n>.tmp/ -> atomic rename -> <dir>/step_<n>/
  manifest.json    tree structure + shapes/dtypes + step
  leaf_<i>.npy     one file per leaf (per-host shard files on multihost;
                   full arrays on a single host)

Restore reshards onto whatever mesh the restoring job runs (elastic scaling:
a job restarted on a different topology re-reads and re-places every leaf
with its NamedSharding).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3,
         executor: Optional[ThreadPoolExecutor] = None) -> Future | None:
    """Write a checkpoint; async when an executor is given (device_get happens
    synchronously — cheap; file IO in the background thread)."""
    leaves, treedef = _flatten(state)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    treedef_repr = str(treedef)

    def _write():
        d = pathlib.Path(ckpt_dir)
        d.mkdir(parents=True, exist_ok=True)
        tmp = d / f"step_{step}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        for i, arr in enumerate(host_leaves):
            np.save(tmp / f"leaf_{i}.npy", arr)
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "treedef": treedef_repr,
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = d / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)               # atomic commit
        _cleanup(d, keep)
        return str(final)

    if executor is not None:
        return executor.submit(_write)
    _write()
    return None


def _cleanup(d: pathlib.Path, keep: int):
    steps = sorted((int(p.name.split("_")[1]), p) for p in d.glob("step_*")
                   if not p.name.endswith(".tmp"))
    for _, p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if not p.name.endswith(".tmp") and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target: Any, mesh=None,
            shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of ``target`` (a pytree of arrays
    or ShapeDtypeStructs). With ``shardings`` (pytree of NamedSharding) each
    leaf is placed sharded — this is the elastic-reshard path."""
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(target)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves)}"
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (tgt, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(d / f"leaf_{i}.npy")
        assert tuple(arr.shape) == tuple(tgt.shape), \
            f"leaf {i}: ckpt {arr.shape} vs target {tgt.shape}"
        arr = arr.astype(tgt.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out)
