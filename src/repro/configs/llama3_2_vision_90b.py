"""llama-3.2-vision-90b — [vlm] 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers [hf:meta-llama/Llama-3.2-90B-Vision;
unverified].

Backbone only: every 5th layer is a gated cross-attention layer attending to
precomputed image patch embeddings (modality frontend is a STUB; input_specs
provides the patch-embedding tensor directly, per task spec).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=28672,
        vocab_size=128256,
        block_pattern=("attn_mlp", "attn_mlp", "attn_mlp", "attn_mlp", "xattn_mlp"),
        n_image_tokens=4096,
        rope_theta=500_000.0,
        act="silu",
    )
