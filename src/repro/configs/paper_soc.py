"""Platform configuration of the paper's prototype SoC (§III-A).

Cheshire host (CVA6, 50 MHz domain) + 8-core Snitch cluster (20 MHz domain)
+ RISC-V IOMMU + parametrizable DRAM delayer, emulated on a VCU128 FPGA.
All constants are taken from the paper text; the simulator consumes this.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class PaperSoCConfig:
    # clock domains (Hz); the cluster runs at 20 MHz, host domain at 50 MHz.
    host_clk_hz: float = 50e6
    cluster_clk_hz: float = 20e6

    # Snitch cluster: 8 compute PEs + 1 DMA core, L1 TCDM scratchpad.
    n_pes: int = 8
    tcdm_bytes: int = 128 * 1024          # L1 scratchpad (double-buffer halves)
    flops_per_cycle_per_pe: float = 1.0   # FPU: 1 single-precision FMA-class op/cyc

    # IOMMU (zero-day-labs IP as integrated, §III-A)
    iotlb_entries: int = 4
    ddt_entries: int = 1                  # one (device, process) directory entry
    ptw_levels: int = 3                   # Sv39: up to 3 sequential accesses

    # memory system
    page_bytes: int = 4096
    llc_bytes: int = 128 * 1024           # Cheshire LLC (LLC/SPM partition)
    llc_line_bytes: int = 64
    llc_ways: int = 8
    l1d_bytes: int = 32 * 1024            # CVA6 write-through D-cache
    dram_base_latency: int = 35           # cycles @50MHz observed on FPGA
    # parametrizable AXI delayer settings used in the paper's sweeps:
    dram_latency_sweep: Tuple[int, ...] = (200, 600, 1000)
    dram_bytes_per_cycle: float = 8.0     # 64-bit AXI data beat per cycle
    max_burst_bytes: int = 4096           # AXI bursts split at page boundaries

    # host-side costs (calibrated; see simulator.calibrate)
    ioctl_overhead_cycles: int = 70_000   # Linux ioctl + driver path per map call
    pte_bytes: int = 8                    # one page-table entry
    ptes_per_page_mapping: int = 3        # "at most 24 bytes (3 PTEs) per 4 KiB"


def config() -> PaperSoCConfig:
    return PaperSoCConfig()
