"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; every assigned
input shape as a ``ShapeConfig``; a dry-run / benchmark cell is the product
``Cell = (ModelConfig, ShapeConfig, MeshConfig)``.

Layer composition is expressed as a *block pattern*: the model is
``first_k_dense`` standalone layers followed by ``n_blocks`` repetitions of
``block_pattern`` (a tuple of layer kinds), scanned with ``jax.lax.scan`` so
the HLO stays small for the 40-cell dry-run.

Layer kinds:
  attn_mlp    self-attention + dense MLP           (llama/qwen/gemma/seamless enc)
  attn_mlp_local  sliding-window self-attn + MLP   (gemma2 'local' layers)
  attn_moe    self-attention + MoE FFN             (olmoe, kimi)
  xattn_mlp   gated cross-attention + dense MLP    (llama-3.2-vision)
  cross_mlp   self-attn + cross-attn + dense MLP   (seamless decoder)
  mamba / mamba_moe   Mamba mixer + dense/MoE FFN  (jamba)
  attn / attn_moe_j   attention inside jamba block
  rwkv        RWKV-6 time-mix + channel-mix        (rwkv6)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    d_ff: int                       # per-expert hidden width
    n_shared_experts: int = 0       # always-on shared experts (kimi-k2 style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 style selective SSM (used by jamba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                 # d_inner = expand * d_model
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # layer composition (see module docstring)
    block_pattern: Tuple[str, ...] = ("attn_mlp",)
    first_k_dense: int = 0          # standalone dense attn_mlp layers before the scan

    # attention details
    d_head: int = 0                 # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    qkv_bias: bool = False          # qwen2
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    logit_softcap: Optional[float] = None   # gemma2: 30.0
    sliding_window: Optional[int] = None    # gemma2: 4096 on 'local' layers
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma2: x *= sqrt(d_model)
    norm_eps: float = 1e-5
    act: str = "silu"               # silu | gelu | relu

    # mixture of experts
    moe: Optional[MoEConfig] = None

    # ssm (jamba)
    ssm: Optional[SSMConfig] = None

    # encoder-decoder (seamless)
    n_enc_layers: int = 0           # >0 -> enc-dec; n_layers is the decoder depth
    enc_block_pattern: Tuple[str, ...] = ("attn_mlp",)

    # vlm (llama-3.2-vision): number of precomputed image-embedding tokens
    n_image_tokens: int = 0

    # numerics / execution
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat: str = "full"             # none | dots | full
    scan_blocks: bool = True        # scan over block_pattern repetitions
    unroll_scans: bool = False      # unroll inner seq scans (roofline cost runs)
    flash_q_chunk: int = 512        # flash attention q block
    flash_kv_chunk: int = 1024      # flash attention kv block
    use_pallas: bool = False        # opt-in TPU kernels (CPU uses pure-JAX paths)
    # decode-attention backend: "jax" (pure-JAX gather path) or "pallas"
    # (kernels/paged_attention scalar-prefetch kernel on the decode hot
    # path — interpret-mode off-TPU, real kernel on TPU).
    decode_backend: str = "jax"
    # Warm prefix-cache tuning (serving): eviction policy of the
    # cross-request PrefixIndex and an optional cap on the pages it may
    # retain after release (0 = bounded only by pool pressure). gdsfs is
    # the size-aware score (frequency x covered-tokens / page-span).
    prefix_cache_policy: str = "lru"        # lru | lfu | gdsfs
    prefix_cache_pages: int = 0
    # Online prefix-cache cap tuning: window length in decode steps after
    # which the cap shrinks/grows from live pool pressure (free-page
    # headroom vs eviction-vs-reuse rates). 0 = off (static cap above).
    prefix_cache_autotune: int = 0
    # Continuous-batching scheduler (core/serving/scheduler.py):
    # per-step token budget for the mixed decode+chunked-prefill batch and
    # the per-sequence prefill chunk cap. Used when the engine runs with
    # scheduler="continuous"; validated here so every entry point agrees.
    sched_token_budget: int = 256
    sched_prefill_chunk: int = 64
    # Serving-side translation front-end geometry: the delta-upload cache
    # the PagedKVManager runs decode page gathers through (same
    # TranslationCache as the simulator's hardware IOTLB; tuned per
    # deployment via benchmarks/tlb_sweep.py — or ONLINE via
    # serve_tlb_autotune below).
    serve_tlb_entries: int = 4096
    serve_tlb_ways: int = 0                 # 0 = fully associative
    serve_tlb_policy: str = "lru"           # lru | fifo | lfu | random | gdsfs
    # Range-coalesced IOTLB entries (SPARTA-style): the max physically
    # contiguous run one entry may cover. 0 = per-page entries only
    # (bit-identical to the historical front-end); >= 2 arms coalescing —
    # translation accounting only, never data movement, so serving
    # outputs stay bit-identical range-on vs range-off.
    serve_tlb_ranges: int = 0
    # IOTLB prefetching on the decode gather stream (Kurth et al.,
    # MMU-aware DMA prefetch): none | next_page | stream, with the issue
    # degree and the stream run-ahead distance. Defaults off.
    serve_tlb_prefetch_policy: str = "none"  # none | next_page | stream
    serve_tlb_prefetch_degree: int = 2
    serve_tlb_prefetch_distance: int = 4
    # Online TLB-geometry auto-tuning: measurement-window length in decode
    # steps (0 = off). Candidates are (entries, ways, policy) triples; an
    # empty tuple uses a default entries ladder around serve_tlb_entries.
    serve_tlb_autotune: int = 0
    serve_tlb_autotune_candidates: Tuple[Tuple[int, int, str], ...] = ()
    # svasan (core/sva/sanitizer.py): shadow-state checking of the paged
    # SVA stack while serving. False still honors the REPRO_SVASAN=1
    # environment knob; True forces it on for this config.
    svasan: bool = False

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.decode_backend not in ("jax", "pallas"):
            raise ValueError(
                f"{self.name}: decode_backend={self.decode_backend!r} "
                "(expected 'jax' or 'pallas')")
        if self.prefix_cache_policy not in ("lru", "lfu", "gdsfs"):
            raise ValueError(
                f"{self.name}: prefix_cache_policy="
                f"{self.prefix_cache_policy!r} "
                "(expected 'lru', 'lfu' or 'gdsfs')")
        if self.prefix_cache_pages < 0:
            raise ValueError(
                f"{self.name}: prefix_cache_pages={self.prefix_cache_pages} "
                "(must be >= 0; 0 = uncapped)")
        if self.prefix_cache_autotune < 0:
            raise ValueError(
                f"{self.name}: prefix_cache_autotune="
                f"{self.prefix_cache_autotune} "
                "(window length in decode steps; 0 = off)")
        if self.sched_token_budget < 1:
            raise ValueError(
                f"{self.name}: sched_token_budget={self.sched_token_budget} "
                "(need >= 1)")
        if self.sched_prefill_chunk < 1:
            raise ValueError(
                f"{self.name}: sched_prefill_chunk={self.sched_prefill_chunk} "
                "(need >= 1)")
        if self.serve_tlb_policy not in ("lru", "fifo", "lfu", "random",
                                         "gdsfs"):
            raise ValueError(
                f"{self.name}: serve_tlb_policy={self.serve_tlb_policy!r} "
                "(expected lru | fifo | lfu | random | gdsfs)")
        if self.serve_tlb_prefetch_policy not in ("none", "next_page",
                                                  "stream"):
            raise ValueError(
                f"{self.name}: serve_tlb_prefetch_policy="
                f"{self.serve_tlb_prefetch_policy!r} "
                "(expected none | next_page | stream)")
        if self.serve_tlb_prefetch_degree < 1:
            raise ValueError(
                f"{self.name}: serve_tlb_prefetch_degree="
                f"{self.serve_tlb_prefetch_degree} (need >= 1)")
        if self.serve_tlb_prefetch_distance < 1:
            raise ValueError(
                f"{self.name}: serve_tlb_prefetch_distance="
                f"{self.serve_tlb_prefetch_distance} (need >= 1)")
        if self.serve_tlb_autotune < 0:
            raise ValueError(
                f"{self.name}: serve_tlb_autotune={self.serve_tlb_autotune} "
                "(window length in decode steps; 0 = off)")
        for cand in self.serve_tlb_autotune_candidates:
            if len(cand) != 3:
                raise ValueError(
                    f"{self.name}: serve_tlb_autotune_candidates entries "
                    f"are (entries, ways, policy) triples; got {cand!r}")
        if self.serve_tlb_entries < 1:
            raise ValueError(
                f"{self.name}: serve_tlb_entries={self.serve_tlb_entries} "
                "(need >= 1)")
        ways = self.serve_tlb_ways
        if ways < 0 or ways > self.serve_tlb_entries or \
                (ways and self.serve_tlb_entries % ways):
            raise ValueError(
                f"{self.name}: serve_tlb_ways={ways} must divide "
                f"serve_tlb_entries={self.serve_tlb_entries} "
                "(0 = fully associative)")
        if self.serve_tlb_ranges < 0 or self.serve_tlb_ranges == 1:
            raise ValueError(
                f"{self.name}: serve_tlb_ranges={self.serve_tlb_ranges} "
                "(0 = off, else the max coalesced run length, >= 2)")
        blk = len(self.block_pattern)
        body = self.n_layers - self.first_k_dense
        if body % blk != 0:
            raise ValueError(
                f"{self.name}: n_layers-first_k_dense={body} not divisible by "
                f"block_pattern length {blk}")

    @property
    def n_blocks(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.block_pattern)

    @property
    def n_q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        kinds = set(self.block_pattern)
        return kinds <= {"rwkv"}

    @property
    def has_subquadratic_path(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / local-global alternating)."""
        kinds = set(self.block_pattern)
        if kinds <= {"rwkv"}:
            return True
        if "mamba" in kinds or "mamba_moe" in kinds:
            return True
        if self.sliding_window is not None:   # gemma2 local/global alternation
            return True
        return False

    def layer_kinds(self) -> Tuple[str, ...]:
        """Full per-layer kind list (first_k_dense + repeated pattern)."""
        return ("attn_mlp",) * self.first_k_dense + self.block_pattern * self.n_blocks


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode
    # decode shapes: seq_len is the KV-cache length; one new token is decoded.

    @property
    def lowers(self) -> str:
        return "train_step" if self.kind == "train" else "serve_step"


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


SINGLE_POD = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    zero1: bool = True              # shard optimizer state over the data axis
    grad_compression: str = "none"  # none | int8_ef (error-feedback int8)
    microbatches: int = 1           # >1 -> gradient accumulation


@dataclass(frozen=True)
class PagedKVConfig:
    """The paper's technique, as serving-runtime configuration."""
    page_size: int = 64             # tokens per physical page ("AXI burst"/block)
    table_levels: int = 1           # 1 = flat block table; 2/3 = radix walk
    offload_mode: str = "zero_copy"  # zero_copy (map) | copy (staging, baseline)
    table_residency: str = "smem"   # smem (scalar-prefetch, ~LLC-on) | hbm (~LLC-off)
    max_pages_per_seq: int = 0      # 0 -> derived from shape


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (structure preserved)."""
    kw = dict(
        n_layers=cfg.first_k_dense + len(cfg.block_pattern),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        param_dtype="float32",
        activation_dtype="float32",
        scan_blocks=cfg.scan_blocks,
        sliding_window=8 if cfg.sliding_window else None,
    )
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, n_experts=4, experts_per_token=2, d_ff=32)
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=4, d_conv=4)
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 2
    if cfg.n_image_tokens:
        kw["n_image_tokens"] = 8
    return replace(cfg, **kw)


def model_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (for 6*N*D roofline term)."""
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    dh, hq, hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads

    def attn_p():
        return d * (hq * dh) + 2 * d * (hkv * dh) + (hq * dh) * d \
            + (cfg.qkv_bias and (hq + 2 * hkv) * dh or 0)

    def mlp_p(width):
        return 3 * d * width

    def moe_p(active_only=False):
        m = cfg.moe
        n = (m.experts_per_token if active_only else m.n_experts) + m.n_shared_experts
        return n * 3 * d * m.d_ff + d * m.n_experts   # + router

    def mamba_p():
        s = cfg.ssm
        d_in = s.expand * d
        dt_rank = s.dt_rank or -(-d // 16)
        return (d * 2 * d_in            # in_proj (x and z)
                + d_in * s.d_conv       # depthwise conv
                + d_in * (dt_rank + 2 * s.d_state)  # x -> dt,B,C
                + dt_rank * d_in        # dt_proj
                + d_in                  # A log diag is d_in*d_state; D is d_in
                + d_in * s.d_state
                + d_in * d)             # out_proj

    def rwkv_p():
        # time-mix: r,k,v,g,o projections + decay/ddlerp low-rank (small)
        tm = 5 * d * d + 6 * 32 * d * 2
        cm = d * dff + dff * d          # rwkv channel mix (2 mats, k/v)
        return tm + cm

    kind_p = {}
    for kind in set(cfg.layer_kinds()):
        p = 0
        if kind in ("attn_mlp", "attn_mlp_local", "attn_moe", "cross_mlp",
                    "attn", "attn_moe_j"):
            p += attn_p()
        if kind in ("xattn_mlp", "cross_mlp"):
            p += attn_p()               # cross-attention projections
        if kind in ("attn_mlp", "attn_mlp_local", "xattn_mlp", "cross_mlp",
                    "mamba", "attn"):
            p += mlp_p(dff)
        if kind in ("attn_moe", "mamba_moe", "attn_moe_j"):
            p += moe_p()
        if kind in ("mamba", "mamba_moe"):
            p += mamba_p()
        if kind == "rwkv":
            p += rwkv_p()
        kind_p[kind] = p

    total = sum(kind_p[k] for k in cfg.layer_kinds())
    if cfg.is_encdec:
        total += cfg.n_enc_layers * (attn_p() + mlp_p(dff))
    total += v * d * (1 if cfg.tie_embeddings else 2)
    return int(total)


def model_active_params(cfg: ModelConfig) -> int:
    """Active (per-token) parameters for MoE archs."""
    if cfg.moe is None:
        return model_params(cfg)
    full = model_params(cfg)
    m = cfg.moe
    inactive_per_moe = (m.n_experts - m.experts_per_token) * 3 * cfg.d_model * m.d_ff
    n_moe_layers = sum(1 for k in cfg.layer_kinds() if k.endswith("moe") or k == "attn_moe_j")
    return int(full - n_moe_layers * inactive_per_moe)
