"""kimi-k2-1t-a32b — [moe] 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048,
MoE 384e top-8, vocab 163840 — trillion-param MoE [arXiv:2501.kimi2; unverified].

DeepSeek-V3-style layout: first layer dense, remaining 60 layers MoE with one
shared expert. Dense-layer FFN width = 8 * expert width (18432 in the real
model; we use 8*2048=16384 to stay within the published table's parameters).
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_head=112,
        d_ff=16384,                 # dense first layer width
        vocab_size=163840,
        block_pattern=("attn_moe",),
        first_k_dense=1,
        moe=MoEConfig(n_experts=384, experts_per_token=8, d_ff=2048,
                      n_shared_experts=1),
        rope_theta=50_000.0,
        act="silu",
    )
