"""rwkv6-3b — [ssm] 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.

RWKV-6 "Finch": data-dependent decay linear attention [arXiv:2404.05892; hf].
Head size 64 (40 heads); channel-mix hidden 8960 = 3.5 * d_model.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,                  # d_model / 64 time-mix heads
        n_kv_heads=40,
        d_head=64,
        d_ff=8960,
        vocab_size=65536,
        block_pattern=("rwkv",),
        act="relu",                  # rwkv channel-mix uses squared relu
        norm_eps=1e-5,
    )
