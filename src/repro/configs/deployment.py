"""Declarative multi-tenant deployment descriptions.

A deployment is tenants x pool shares x IOTLB geometry x translation
knobs, written as data and validated at construction, then compiled onto
a base :class:`~repro.configs.base.ModelConfig`:

    dep = DeploymentConfig(
        tenants=(TenantSpec("acme", pool_share=0.5, tlb_ways=2),
                 TenantSpec("bravo", pool_share=0.25, tlb_ways=1)),
        tlb_entries=1024, tlb_ways=4)
    cfg = dep.compile(get_config("llama3.2-1b"))      # TLB geometry applied
    eng = ServingEngine(cfg, params, n_slots, max_len,
                        tenants=dep.tenant_dict(pool_pages))

Shares are fractions of the ENGINE's page pool (whose size is only known
at engine construction), so they compile to page quotas via
:meth:`DeploymentConfig.tenant_dict`. ``tlb_ways`` on a
:class:`TenantSpec` reserves private IOTLB ways for that tenant
(``TLBConfig.partitions`` — see core/sva/tlb.py); ways left over stay a
shared pool every tenant may use.

Everything is validated twice: structural errors (duplicate tenants,
over-committed shares, partitions exceeding the declared ways) raise at
construction; errors that need the base config (partitioning a
fully-associative TLB, partitions + the geometry auto-tuner) raise in
:meth:`DeploymentConfig.compile`. The error strings are pinned by
``tests/test_multitenant.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.configs.base import ModelConfig

__all__ = ["TenantSpec", "DeploymentConfig", "two_tenant_demo"]


@dataclass(frozen=True)
class TenantSpec:
    """One tenant: a share of the page pool, an optional private
    prefix-cache share, and optional private IOTLB ways. All knobs
    default to "unlimited/shared" — a ``TenantSpec("x")`` tenant gets
    isolation (own ASIDs, own prefix scope) and nothing else."""
    name: str
    pool_share: float = 0.0       # fraction of pool pages -> quota_pages
    prefix_share: float = 0.0     # fraction -> quota_prefix_pages
    tlb_ways: int = 0             # private IOTLB ways (0 = shared only)

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise ValueError(f"tenant name {self.name!r} "
                             "(need a non-empty string)")
        for knob in ("pool_share", "prefix_share"):
            v = getattr(self, knob)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(
                    f"tenant {self.name!r}: {knob}={v} (need 0.0..1.0)")
        if not isinstance(self.tlb_ways, int) or self.tlb_ways < 0:
            raise ValueError(
                f"tenant {self.name!r}: tlb_ways={self.tlb_ways!r} "
                "(need an int >= 0)")


@dataclass(frozen=True)
class DeploymentConfig:
    """Tenants + serving-IOTLB geometry overrides (0/"" = inherit the
    base config's ``serve_tlb_*`` value)."""
    tenants: Tuple[TenantSpec, ...]
    tlb_entries: int = 0
    tlb_ways: int = 0
    tlb_policy: str = ""
    tlb_ranges: int = 0
    prefetch_policy: str = ""     # "" = inherit; none | next_page | stream
    autotune_interval: int = 0    # geometry auto-tune (exclusive w/ ways)

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("a deployment needs at least one tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        pool = sum(t.pool_share for t in self.tenants)
        if pool > 1.0 + 1e-9:
            raise ValueError(
                f"tenant pool_shares sum to {pool:.3f} (over-committed; "
                "need <= 1.0)")
        if sum(t.prefix_share for t in self.tenants) > 1.0 + 1e-9:
            raise ValueError("tenant prefix_shares sum over 1.0")
        part = sum(t.tlb_ways for t in self.tenants)
        if self.tlb_ways and part > self.tlb_ways:
            raise ValueError(
                f"tenant tlb_ways reserve {part} ways but the deployment "
                f"TLB has {self.tlb_ways}")
        if self.autotune_interval and part:
            raise ValueError(
                "TLB way partitions and the geometry auto-tuner are "
                "mutually exclusive (a retune would drop the partitions)")

    # ------------------------------------------------------------ compile
    def compile(self, base: ModelConfig) -> ModelConfig:
        """Apply the deployment's TLB geometry onto ``base`` and validate
        the parts that need the resolved geometry."""
        kw: Dict[str, object] = {}
        if self.tlb_entries:
            kw["serve_tlb_entries"] = self.tlb_entries
        if self.tlb_ways:
            kw["serve_tlb_ways"] = self.tlb_ways
        if self.tlb_policy:
            kw["serve_tlb_policy"] = self.tlb_policy
        if self.tlb_ranges:
            kw["serve_tlb_ranges"] = self.tlb_ranges
        if self.prefetch_policy:
            kw["serve_tlb_prefetch_policy"] = self.prefetch_policy
        if self.autotune_interval:
            kw["serve_tlb_autotune"] = self.autotune_interval
        cfg = dataclasses.replace(base, **kw) if kw else base
        part = sum(t.tlb_ways for t in self.tenants)
        if part:
            if not cfg.serve_tlb_ways:
                raise ValueError(
                    "tenant tlb_ways need a set-associative serving TLB "
                    "(set tlb_ways on the deployment or serve_tlb_ways "
                    "on the config)")
            if part > cfg.serve_tlb_ways:
                raise ValueError(
                    f"tenant tlb_ways reserve {part} ways but the "
                    f"serving TLB has {cfg.serve_tlb_ways}")
            if cfg.serve_tlb_autotune:
                raise ValueError(
                    "TLB way partitions and the geometry auto-tuner are "
                    "mutually exclusive (a retune would drop the "
                    "partitions)")
        return cfg

    def tenant_dict(self, pool_pages: int) -> Dict[str, dict]:
        """Resolve shares against a concrete pool size: the ``tenants=``
        mapping :class:`~repro.core.sva.kv_manager.PagedKVManager` (and
        the engines) take. Shares floor to whole pages; a nonzero share
        always grants at least one page."""
        if pool_pages < 1:
            raise ValueError(f"pool_pages={pool_pages} (need >= 1)")
        out: Dict[str, dict] = {}
        for t in self.tenants:
            spec: Dict[str, int] = {}
            if t.pool_share:
                spec["quota_pages"] = max(1, int(t.pool_share * pool_pages))
            if t.prefix_share:
                spec["quota_prefix_pages"] = max(
                    1, int(t.prefix_share * pool_pages))
            if t.tlb_ways:
                spec["tlb_ways"] = t.tlb_ways
            out[t.name] = spec
        return out

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tenants)


def two_tenant_demo(partitioned: bool = True,
                    ways: int = 4) -> "DeploymentConfig":
    """The benchmarks' stock two-tenant deployment: tenant ``a`` holds
    half the pool with 2 private ways, tenant ``b`` a quarter with 1;
    ``partitioned=False`` keeps the quotas but shares the whole TLB (the
    A/B's control arm)."""
    return DeploymentConfig(
        tenants=(TenantSpec("a", pool_share=0.5,
                            tlb_ways=2 if partitioned else 0),
                 TenantSpec("b", pool_share=0.25,
                            tlb_ways=1 if partitioned else 0)),
        tlb_ways=ways)
