"""jamba-1.5-large-398b — [hybrid] 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
MoE 16e top-2, vocab 65536 — Mamba+attention 1:7 interleave [arXiv:2403.19887; hf].

Jamba period-8 block: attention at index 4, Mamba elsewhere; MoE FFN on every
second layer (odd indices), dense FFN otherwise. 72 layers = 9 blocks.
Total params ~398B, active ~94B (top-2 of 16 experts).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=24576,
        vocab_size=65536,
        block_pattern=(
            "mamba", "mamba_moe", "mamba", "mamba_moe",
            "attn", "mamba_moe", "mamba", "mamba_moe",
        ),
        moe=MoEConfig(n_experts=16, experts_per_token=2, d_ff=24576),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        rope_theta=10_000.0,   # jamba attention layers are NoPE in the paper; kept for generality
        act="silu",
    )
