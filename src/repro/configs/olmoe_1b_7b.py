"""olmoe-1b-7b — [moe] 16L d_model=2048 16H (kv=16) d_ff=1024, MoE 64e top-8.

64 experts, top-8 routing, vocab 50304 [arXiv:2409.02060; hf].
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1024,
        vocab_size=50304,
        block_pattern=("attn_moe",),
        moe=MoEConfig(n_experts=64, experts_per_token=8, d_ff=1024),
        rope_theta=10_000.0,
        act="silu",
    )
