"""gemma2-2b — [dense] 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local+global alternating attention, logit softcap [arXiv:2408.00118; hf].
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        d_head=256,
        d_ff=9216,
        vocab_size=256_000,
        # alternating local (sliding-window) / global attention, scanned in pairs
        block_pattern=("attn_mlp_local", "attn_mlp"),
        sliding_window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        rope_theta=10_000.0,
        tie_embeddings=True,
        scale_embeddings=True,
        act="gelu",
        norm_eps=1e-6,
    )
