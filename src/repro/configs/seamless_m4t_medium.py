"""seamless-m4t-medium — [audio] 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — encoder-decoder, multimodal [arXiv:2308.11596; hf].

Backbone only: a 12L transformer encoder over precomputed audio-frame
embeddings (modality frontend is a STUB per task spec) and a 12L decoder with
cross-attention.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        n_layers=12,                 # decoder depth
        n_enc_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_head=64,
        d_ff=4096,
        vocab_size=256206,
        block_pattern=("cross_mlp",),
        enc_block_pattern=("attn_mlp",),
        rope_theta=10_000.0,
        act="relu",
        norm_eps=1e-5,
    )
