"""Architecture / shape / cell registry.

``get_config("llama3.2-1b")`` returns the exact assigned config;
``CELLS`` enumerates the 40 (arch x shape) dry-run cells.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

from repro.configs.base import (
    MeshConfig, ModelConfig, MoEConfig, PagedKVConfig, SSMConfig, ShapeConfig,
    TrainConfig, SINGLE_POD, MULTI_POD, model_active_params, model_params,
    reduce_for_smoke,
)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "llama-3.2-vision-90b": "repro.configs.llama3_2_vision_90b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).config()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell is runnable, else the documented reason."""
    if shape.name == "long_500k" and not cfg.has_subquadratic_path:
        return ("long_500k requires a sub-quadratic attention path; "
                f"{cfg.name} is pure full-attention (see DESIGN.md §7)")
    return None


def all_cells(include_skipped: bool = True) -> List[Tuple[str, str, Optional[str]]]:
    """All 40 (arch, shape, skip_reason) cells in registry order."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            cells.append((arch, sname, cell_skip_reason(cfg, shape)))
    if not include_skipped:
        cells = [c for c in cells if c[2] is None]
    return cells


__all__ = [
    "ARCH_IDS", "SHAPES", "MeshConfig", "ModelConfig", "MoEConfig",
    "PagedKVConfig", "SSMConfig", "ShapeConfig", "TrainConfig", "SINGLE_POD",
    "MULTI_POD", "all_cells", "cell_skip_reason", "get_config", "get_shape",
    "model_active_params", "model_params", "reduce_for_smoke",
]
