"""Synthetic tokenized data pipeline with background host prefetch.

Produces packed (tokens, labels) batches from a deterministic zipfian
"language" (so loss curves are reproducible), with a prefetch thread that
stages the next batch while the device computes — the host side of the
paper's zero-copy story (no staging copies between generator and device
buffers; arrays are handed to jax.device_put directly, donated per step).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    # markov blending makes the stream learnable (loss visibly decreases)
    markov_order: int = 1


class SyntheticLM:
    """Deterministic zipf+markov token stream, packed into LM batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        base = 1.0 / np.power(np.arange(1, v + 1), cfg.zipf_a)
        self.base = base / base.sum()
        # one shared sparse transition structure: each token prefers a few
        # successors — gives the model something to learn.
        self.succ = self.rng.integers(0, v, size=(v, 4))

    def _gen_doc(self, length: int) -> np.ndarray:
        v = self.cfg.vocab_size
        out = np.empty(length, np.int64)
        tok = int(self.rng.choice(v, p=self.base))
        for i in range(length):
            out[i] = tok
            if self.rng.random() < 0.7:
                tok = int(self.succ[tok, self.rng.integers(0, 4)])
            else:
                tok = int(self.rng.choice(v, p=self.base))
        return out

    def batches(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        B, S = self.cfg.batch, self.cfg.seq_len
        while True:
            stream = self._gen_doc(B * (S + 1))
            chunk = stream.reshape(B, S + 1)
            yield chunk[:, :-1].astype(np.int32), chunk[:, 1:].astype(np.int32)


class Prefetcher:
    """Stages ``depth`` batches ahead on a host thread."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.it = it
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        for item in self.it:
            if self._stop.is_set():
                return
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass


def make_pipeline(vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                  prefetch: int = 2):
    ds = SyntheticLM(DataConfig(vocab_size, batch, seq_len, seed))
    return Prefetcher(ds.batches(), depth=prefetch)
