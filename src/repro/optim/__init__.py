from repro.optim.adamw import (OptState, adamw_update, clip_by_global_norm,
                               init_opt_state, lr_schedule, opt_state_specs)

__all__ = ["OptState", "adamw_update", "clip_by_global_norm",
           "init_opt_state", "lr_schedule", "opt_state_specs"]
