"""AdamW with fp32 master weights, built from scratch (no optax).

Optimizer state inherits parameter shardings (FSDP+TP annotations), so the
memory behavior of ZeRO falls out of pure sharding — see DESIGN.md §5.
State per param: master fp32 + mu fp32 + nu fp32 (12 B) + bf16 param (2 B).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    master: Any   # fp32 params
    mu: Any       # fp32 first moment
    nu: Any       # fp32 second moment
    count: jax.Array
    ef: Any = None   # int8-compression error-feedback buffers (optional)


def init_opt_state(params, with_ef: bool = False) -> OptState:
    # copy=True: master must not alias fp32 params (donation safety)
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return OptState(master=f32(params), mu=zeros(params), nu=zeros(params),
                    count=jnp.zeros((), jnp.int32),
                    ef=zeros(params) if with_ef else None)


def opt_state_specs(param_specs, with_ef: bool = False):
    """ParamSpec tree for the optimizer state mirroring param shardings."""
    from repro.models.params import ParamSpec, tree_map_specs
    f32 = lambda t: tree_map_specs(
        lambda s: ParamSpec(s.shape, jnp.float32, s.pspec, "zeros"), t)
    return OptState(master=f32(param_specs), mu=f32(param_specs),
                    nu=f32(param_specs),
                    count=ParamSpec((), jnp.int32, jax.sharding.PartitionSpec(),
                                    init="zeros"),
                    ef=f32(param_specs) if with_ef else None)


def lr_schedule(tc: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = tc.lr * step / max(tc.warmup_steps, 1)
    prog = jnp.clip((step - tc.warmup_steps)
                    / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = tc.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < tc.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                         for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def adamw_update(params, grads, state: OptState, tc: TrainConfig
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """grads must already be fp32 (post-clip)."""
    count = state.count + 1
    lr = lr_schedule(tc, count)
    b1, b2 = tc.beta1, tc.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(m, g, mu, nu):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        step_ = (mu / c1) / (jnp.sqrt(nu / c2) + tc.eps)
        m = m - lr * (step_ + tc.weight_decay * m)
        return m, mu, nu

    flat_m, treedef = jax.tree.flatten(state.master)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(m, g, mu, nu) for m, g, mu, nu
           in zip(flat_m, flat_g, flat_mu, flat_nu)]
    master = jax.tree.unflatten(treedef, [o[0] for o in out])
    mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), master, params)
    return (new_params, OptState(master, mu, nu, count, ef=state.ef),
            {"lr": lr})
