"""Int8 gradient compression with error feedback (1-bit-Adam-family trick).

Two pieces:

  * ``ef_compress(grads, ef)`` — quantize-dequantize each gradient leaf to
    int8 with a per-leaf scale, carrying the quantization residual in an
    error-feedback buffer so the bias vanishes over steps. Used as a
    gradient transform inside train_step; on hardware the all-reduce then
    moves 4x fewer bytes (the roofline benchmark accounts collective bytes
    at 1/4 for compressed runs).

  * ``compressed_psum(x, axis)`` — an explicit shard_map-compatible int8
    ring reduce: quantize -> psum(int32) -> dequantize. Demonstrates the
    actual collective; validated in tests against fp32 psum.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quant_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 quantization. grads/ef: matching fp32 pytrees."""
    def leaf(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quant_leaf(g)
        deq = _dequant_leaf(q, s)
        return deq, g - deq
    out = jax.tree.map(leaf, grads, ef)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef


def init_ef(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """Int8-quantized psum (call inside shard_map). The scale is agreed via a
    max-psum first (tiny), then int8 payloads reduce in int32."""
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale
