import jax.numpy as jnp


def gesummv_ref(alpha, beta, a, b, x):
    xf = x.astype(jnp.float32)
    return (alpha * (a.astype(jnp.float32) @ xf)
            + beta * (b.astype(jnp.float32) @ xf)).astype(x.dtype)
