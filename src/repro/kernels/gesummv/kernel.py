"""Fused gesummv (paper kernel #2): y = alpha*A@x + beta*B@x.

Row-blocked: each grid step streams a (bm, K) stripe of BOTH matrices into
VMEM (one pass over memory — the fusion the paper's cluster implementation
exploits) against a VMEM-resident x.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ab_ref, a_ref, b_ref, x_ref, o_ref):
    alpha, beta = ab_ref[0], ab_ref[1]
    x = x_ref[...]
    ya = jax.lax.dot_general(a_ref[...], x, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    yb = jax.lax.dot_general(b_ref[...], x, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[...] = (alpha * ya + beta * yb).astype(o_ref.dtype)


def gesummv(alpha, beta, a, b, x, *, bm: int = 128, interpret: bool = True):
    N, K = a.shape
    bm = min(bm, N)
    while N % bm:
        bm -= 1
    ab = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(beta, jnp.float32)])
    return pl.pallas_call(
        _kernel,
        grid=(N // bm,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), x.dtype),
        interpret=interpret,
    )(ab, a, b, x)
