import functools

import jax

from repro.kernels.gesummv.kernel import gesummv
from repro.kernels.gesummv.ref import gesummv_ref


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "use_pallas"))
def gesummv_op(alpha, beta, a, b, x, *, bm=128, interpret=True,
               use_pallas=True):
    if not use_pallas:
        return gesummv_ref(alpha, beta, a, b, x)
    return gesummv(alpha, beta, a, b, x, bm=bm, interpret=interpret)
