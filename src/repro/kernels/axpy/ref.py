def axpy_ref(a, x, y):
    return a * x + y
