import functools

import jax

from repro.kernels.axpy.kernel import axpy
from repro.kernels.axpy.ref import axpy_ref


@functools.partial(jax.jit, static_argnames=("block", "interpret",
                                             "use_pallas"))
def axpy_op(a, x, y, *, block=8192, interpret=True, use_pallas=True):
    if not use_pallas:
        return axpy_ref(a, x, y)
    return axpy(a, x, y, block=block, interpret=interpret)
