"""Streaming axpy (paper kernel #4): y = a*x + y, tiled through VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def axpy(a, x, y, *, block: int = 8192, interpret: bool = True):
    n = x.shape[0]
    block = min(block, n)
    while n % block:
        block -= 1
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(jnp.reshape(a, (1,)).astype(x.dtype), x, y)
