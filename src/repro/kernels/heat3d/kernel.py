"""heat3d 7-point stencil (paper kernel #3), z-slab tiled with halos.

Each grid step DMAs a (bz+2, Y, X) slab (one-plane halo on each side, via an
Unblocked index map over a pre-padded volume) into VMEM and computes the
interior update — the 3-D input tiling + double buffering of §III-B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, o_ref, *, c0: float, c1: float):
    u = u_ref[...]                           # (bz+2, Y+2, X+2)
    center = u[1:-1, 1:-1, 1:-1]
    neigh = (u[:-2, 1:-1, 1:-1] + u[2:, 1:-1, 1:-1]
             + u[1:-1, :-2, 1:-1] + u[1:-1, 2:, 1:-1]
             + u[1:-1, 1:-1, :-2] + u[1:-1, 1:-1, 2:])
    o_ref[...] = (c0 * center + c1 * neigh).astype(o_ref.dtype)


def heat3d_step(u: jax.Array, *, c0: float = 0.4, c1: float = 0.1,
                bz: int = 8, interpret: bool = True) -> jax.Array:
    """One timestep over (Z, Y, X); boundary kept fixed (Dirichlet)."""
    Z, Y, X = u.shape
    bz = min(bz, Z - 2)
    while (Z - 2) % bz:
        bz -= 1
    inner = pl.pallas_call(
        functools.partial(_kernel, c0=c0, c1=c1),
        grid=((Z - 2) // bz,),
        # Unblocked (element-indexed) input spec: consecutive slabs OVERLAP
        # by the one-plane halo — the stencil's redundant-fetch pattern.
        in_specs=[pl.BlockSpec((bz + 2, Y, X),
                               lambda i: (i * bz, 0, 0),
                               indexing_mode=pl.Unblocked())],
        out_specs=pl.BlockSpec((bz, Y - 2, X - 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Z - 2, Y - 2, X - 2), u.dtype),
        interpret=interpret,
    )(u)
    return u.at[1:-1, 1:-1, 1:-1].set(inner)
