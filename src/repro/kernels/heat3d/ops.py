import functools

import jax

from repro.kernels.heat3d.kernel import heat3d_step
from repro.kernels.heat3d.ref import heat3d_step_ref


@functools.partial(jax.jit, static_argnames=("steps", "bz", "interpret",
                                             "use_pallas"))
def heat3d(u, *, steps: int = 1, bz: int = 8, interpret=True,
           use_pallas=True):
    for _ in range(steps):
        u = (heat3d_step(u, bz=bz, interpret=interpret) if use_pallas
             else heat3d_step_ref(u))
    return u
