import jax.numpy as jnp


def heat3d_step_ref(u, *, c0: float = 0.4, c1: float = 0.1):
    center = u[1:-1, 1:-1, 1:-1]
    neigh = (u[:-2, 1:-1, 1:-1] + u[2:, 1:-1, 1:-1]
             + u[1:-1, :-2, 1:-1] + u[1:-1, 2:, 1:-1]
             + u[1:-1, 1:-1, :-2] + u[1:-1, 1:-1, 2:])
    return u.at[1:-1, 1:-1, 1:-1].set((c0 * center + c1 * neigh).astype(u.dtype))
