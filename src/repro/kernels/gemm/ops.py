import functools

import jax

from repro.kernels.gemm.kernel import gemm
from repro.kernels.gemm.ref import gemm_ref


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "use_pallas"))
def matmul(a, b, *, bm=128, bn=128, bk=128, interpret=True, use_pallas=True):
    if not use_pallas:
        return gemm_ref(a, b)
    return gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
