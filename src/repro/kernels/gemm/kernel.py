"""Tiled GEMM with double-buffered HBM->VMEM pipelining (paper kernel #1).

The Snitch cluster's DMA double buffering maps to the Pallas grid pipeline:
grid (M/bm, N/bn, K/bk) with a VMEM fp32 accumulator revisited across the K
axis; the next K-tile's DMA overlaps the current tile's MXU work. Tile sizes
default to MXU-aligned 128 multiples (TPU target); interpret mode validates
on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
         bk: int = 128, interpret: bool = True) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N)."""
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=interpret,
    )(a, b)
