"""Flash attention forward (TPU target for train/prefill attention).

Grid (B, H, nq, nkv), online softmax carried in VMEM scratch across the
innermost kv axis. Causal block-skipping: blocks strictly above the diagonal
are skipped with pl.when (the FLOPs the pure-JAX path wastes — see
models/attention.py note). GQA is handled by the ops wrapper (KV repeat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bkv: int, n_kv: int, causal: bool, softcap,
            scale: float):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0]                         # (bq, D)
        k = k_ref[0, 0]                         # (bkv, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal (the causal-FLOPs saving
        # the pure-JAX path does not get)
        pl.when(ikv * bkv <= iq * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ikv == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, softcap=None,
                        bq: int = 128, bkv: int = 128,
                        interpret: bool = True):
    """q, k, v: (B, H, S, D) with H == Hkv (pre-repeated). -> (B, H, S, D)."""
    B, H, S, D = q.shape
    Skv = k.shape[2]
    bq, bkv = min(bq, S), min(bkv, Skv)
    while S % bq:
        bq -= 1
    while Skv % bkv:
        bkv -= 1
    grid = (B, H, S // bq, Skv // bkv)
    kernel = functools.partial(_kernel, bq=bq, bkv=bkv, n_kv=Skv // bkv,
                               causal=causal, softcap=softcap,
                               scale=D ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
