import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, softcap=None):
    """q,k,v: (B,H,S,D) dense oracle."""
    D = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        Sq, Skv = q.shape[2], k.shape[2]
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
