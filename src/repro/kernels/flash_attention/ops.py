import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "softcap", "bq", "bkv",
                                             "interpret", "use_pallas"))
def flash_attention_op(q, k, v, *, causal=True, softcap=None, bq=128,
                       bkv=128, interpret=True, use_pallas=True):
    """q: (B,S,Hq,D); k,v: (B,S,Hkv,D) — BSHD layout like models/attention."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    if Hq != Hkv:
        k = jnp.repeat(k, Hq // Hkv, axis=2)
        v = jnp.repeat(v, Hq // Hkv, axis=2)
    qt, kt, vt = (x.swapaxes(1, 2) for x in (q, k, v))
    if not use_pallas:
        return attention_ref(qt, kt, vt, causal=causal,
                             softcap=softcap).swapaxes(1, 2)
    o = flash_attention_fwd(qt, kt, vt, causal=causal, softcap=softcap,
                            bq=bq, bkv=bkv, interpret=interpret)
    return o.swapaxes(1, 2)
