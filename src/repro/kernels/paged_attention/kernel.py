"""Paged decode attention — the paper's technique as a TPU kernel.

The block table is passed as a SCALAR-PREFETCH operand: it lives in SMEM and
drives the BlockSpec index maps, so every KV page's HBM->VMEM DMA is issued
*through the translation* with zero per-access walk cost. This is the TPU
realization of the paper's LLC-resident page-table walk (translations in
fast memory next to the walker), while the bulk KV pages stream around it
(the DMA-bypasses-LLC path). ``table_residency="hbm"`` instead loads
translations from HBM inside the kernel — the paper's LLC-off baseline.

Layout (per sequence-batch):
  q:        (B, Hq, D)
  k_pool:   (B, n_pages, page, Hkv, D)  physical pages
  v_pool:   (B, n_pages, page, Hkv, D)
  table:    (B, n_pages) int32          logical -> physical
  lengths:  (B,) int32                  valid tokens per sequence
  out:      (B, Hq, D)

Grid: (B, n_pages) — online softmax accumulates across the page axis in VMEM
scratch, exactly the Snitch double-buffered DMA pattern (pages are fetched
one grid step ahead by the Pallas pipeline while the previous page computes).

``paged_attention_global`` is the same kernel over the serving engine's
GLOBAL layout: ONE physical pool shared by every slot —
  k_pool / v_pool: (total_pages, page, Hkv, D)
  table:           (B, max_pages) int32 into the global pool; entries
                   >= total_pages are the NULL page marking unallocated
                   slots (they only appear at logical positions >= length,
                   so the length mask already excludes them; the index map
                   just clamps them to a safe page for the DMA).
Because the per-sequence translation happens in the SMEM index map, two
slots whose tables point at the same physical page (copy-on-write prefix
sharing) stream it from the same HBM address — the kernel IS the map-don't-
copy path at decode granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(table_ref, len_ref,        # scalar-prefetch (SMEM)
            q_ref, k_ref, v_ref,       # VMEM blocks
            o_ref,                     # output block
            m_ref, l_ref, acc_ref,     # VMEM scratch carried across pages
            *, page: int, n_pages: int, softcap):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                               # (Hq, D)
    # KV block: (1, 1, page, Hkv, D) per-slot, (1, page, Hkv, D) global —
    # same page once the leading singleton block dims are dropped.
    k = k_ref[...].reshape(k_ref.shape[-3:])   # (page, Hkv, D)
    v = v_ref[...].reshape(v_ref.shape[-3:])
    Hq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv

    # scores: (Hq, page) — each q head attends its kv group's head
    kg = jnp.repeat(k, G, axis=1)              # (page, Hq, D)
    s = jnp.einsum("hd,phd->hp", q.astype(jnp.float32),
                   kg.astype(jnp.float32))
    s = s * (D ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    length = len_ref[b]
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = pos < length
    s = jnp.where(valid, s, -jnp.inf)

    m_prev, l_prev = m_ref[...], l_ref[...]    # (Hq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p_ = jnp.exp(s - m_safe)                   # (Hq, page)
    p_ = jnp.where(valid, p_, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev),
                     jnp.exp(m_prev - m_safe), 0.0)
    l_new = l_prev * corr + jnp.sum(p_, axis=-1, keepdims=True)
    vg = jnp.repeat(v, G, axis=1).astype(jnp.float32)   # (page, Hq, D)
    pv = jnp.einsum("hp,phd->hd", p_, vg)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv[:, None, :]
    m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...][:, 0] / l).astype(o_ref.dtype)


def paged_attention(q, k_pool, v_pool, block_table, lengths, *,
                    softcap=None, table_residency: str = "smem",
                    interpret: bool = True):
    """See module docstring. Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    _, n_pages, page, Hkv, _ = k_pool.shape

    if table_residency == "hbm":
        # LLC-off baseline: translations are NOT prefetched; resolve them
        # with an explicit gather pass (pays the full-table data movement),
        # then run the kernel on an identity table.
        k_pool = jnp.take_along_axis(
            k_pool, block_table[:, :, None, None, None], axis=1)
        v_pool = jnp.take_along_axis(
            v_pool, block_table[:, :, None, None, None], axis=1)
        block_table = jnp.broadcast_to(
            jnp.arange(n_pages, dtype=jnp.int32), block_table.shape)

    grid = (B, n_pages)
    kernel = functools.partial(_kernel, page=page, n_pages=n_pages,
                               softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, p, tbl, ln: (b, 0, 0)),
            # THE TECHNIQUE: the KV page DMA source address goes through the
            # SMEM-resident block table (IOVA -> PA translation at zero cost)
            pl.BlockSpec((1, 1, page, Hkv, D),
                         lambda b, p, tbl, ln: (b, tbl[b, p], 0, 0, 0)),
            pl.BlockSpec((1, 1, page, Hkv, D),
                         lambda b, p, tbl, ln: (b, tbl[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, p, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)


def paged_attention_global(q, k_pool, v_pool, block_table, lengths, *,
                           softcap=None, table_residency: str = "smem",
                           interpret: bool = True):
    """Decode attention over the GLOBAL (shared-pool) layout — see module
    docstring. q: (B, Hq, D); pools: (total, page, Hkv, D); table: (B, P)
    int32 with NULL (>= total) marking unallocated entries. Returns
    (B, Hq, D)."""
    B, Hq, D = q.shape
    total, page, Hkv, _ = k_pool.shape
    P = block_table.shape[1]

    if table_residency == "hbm":
        # LLC-off baseline: gather each sequence's pages out of the shared
        # pool into a private per-slot pool (pays the full data movement),
        # then run the per-slot kernel on an identity table.
        null = (block_table >= total)[:, :, None, None, None]
        safe = jnp.where(block_table >= total, 0, block_table)
        kg = jnp.where(null, 0, k_pool[safe]).astype(k_pool.dtype)
        vg = jnp.where(null, 0, v_pool[safe]).astype(v_pool.dtype)
        ident = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
        return paged_attention(q, kg, vg, ident, lengths, softcap=softcap,
                               interpret=interpret)

    grid = (B, P)
    kernel = functools.partial(_kernel, page=page, n_pages=P, softcap=softcap)

    def kv_index(b, p, tbl, ln):
        # THE TECHNIQUE, shared-pool form: the DMA source page is the
        # SMEM-resident translation. NULL entries are clamped to page 0 for
        # a safe (dead) fetch — their logical positions are >= length, so
        # the kernel's validity mask already zeroes their contribution.
        t = tbl[b, p]
        return (jnp.where(t >= total, 0, t), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, p, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D), kv_index),
            pl.BlockSpec((1, page, Hkv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, p, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)
