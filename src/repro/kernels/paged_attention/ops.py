"""Jitted public wrapper for the paged-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("softcap", "table_residency",
                                             "interpret", "use_pallas"))
def paged_decode(q, k_pool, v_pool, block_table, lengths, *, softcap=None,
                 table_residency: str = "smem", interpret: bool = True,
                 use_pallas: bool = True):
    if not use_pallas:
        return paged_attention_ref(q, k_pool, v_pool, block_table, lengths,
                                   softcap=softcap)
    return paged_attention(q, k_pool, v_pool, block_table, lengths,
                           softcap=softcap, table_residency=table_residency,
                           interpret=interpret)
