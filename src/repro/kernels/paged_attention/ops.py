"""Jitted public wrapper for the paged-attention kernel.

Dispatches on pool rank: (B, n_pages, page, Hkv, D) is the per-slot layout,
(total_pages, page, Hkv, D) the serving engine's shared global pool (block
tables may then point several slots at the SAME physical page — prefix
sharing resolves inside the scalar-prefetch index map).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import (paged_attention,
                                                  paged_attention_global)
from repro.kernels.paged_attention.ref import (paged_attention_global_ref,
                                               paged_attention_ref)


@functools.partial(jax.jit, static_argnames=("softcap", "table_residency",
                                             "interpret", "use_pallas"))
def paged_decode(q, k_pool, v_pool, block_table, lengths, *, softcap=None,
                 table_residency: str = "smem", interpret: bool = True,
                 use_pallas: bool = True):
    is_global = k_pool.ndim == 4
    if not use_pallas:
        ref = paged_attention_global_ref if is_global else paged_attention_ref
        return ref(q, k_pool, v_pool, block_table, lengths, softcap=softcap)
    fn = paged_attention_global if is_global else paged_attention
    return fn(q, k_pool, v_pool, block_table, lengths, softcap=softcap,
              table_residency=table_residency, interpret=interpret)
