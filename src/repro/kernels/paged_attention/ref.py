"""Pure-jnp oracle for the paged decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pool, v_pool, block_table, lengths, *,
                        softcap=None):
    """q: (B,Hq,D); pools: (B,n_pages,page,Hkv,D); table: (B,n_pages);
    lengths: (B,). Returns (B,Hq,D) fp-accurate dense attention through the
    block-table translation."""
    B, Hq, D = q.shape
    _, n_pages, page, Hkv, _ = k_pool.shape
    G = Hq // Hkv
    k = jnp.take_along_axis(k_pool, block_table[:, :, None, None, None],
                            axis=1).reshape(B, n_pages * page, Hkv, D)
    v = jnp.take_along_axis(v_pool, block_table[:, :, None, None, None],
                            axis=1).reshape(B, n_pages * page, Hkv, D)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(n_pages * page)
    s = jnp.where(pos[None, None, :] < lengths[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_attention_global_ref(q, k_pool, v_pool, block_table, lengths, *,
                               softcap=None):
    """Global-layout oracle: pools (total, page, Hkv, D), table (B, P) with
    NULL entries (>= total) reading as zero pages. Gathers each sequence's
    logical view out of the shared pool, then reuses the per-slot oracle on
    an identity table."""
    total = k_pool.shape[0]
    B, P = block_table.shape
    null = (block_table >= total)[:, :, None, None, None]
    safe = jnp.where(block_table >= total, 0, block_table)
    kg = jnp.where(null, 0, k_pool[safe]).astype(k_pool.dtype)
    vg = jnp.where(null, 0, v_pool[safe]).astype(v_pool.dtype)
    ident = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    return paged_attention_ref(q, kg, vg, ident, lengths, softcap=softcap)
