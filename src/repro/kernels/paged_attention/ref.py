"""Pure-jnp oracle for the paged decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pool, v_pool, block_table, lengths, *,
                        softcap=None):
    """q: (B,Hq,D); pools: (B,n_pages,page,Hkv,D); table: (B,n_pages);
    lengths: (B,). Returns (B,Hq,D) fp-accurate dense attention through the
    block-table translation."""
    B, Hq, D = q.shape
    _, n_pages, page, Hkv, _ = k_pool.shape
    G = Hq // Hkv
    k = jnp.take_along_axis(k_pool, block_table[:, :, None, None, None],
                            axis=1).reshape(B, n_pages * page, Hkv, D)
    v = jnp.take_along_axis(v_pool, block_table[:, :, None, None, None],
                            axis=1).reshape(B, n_pages * page, Hkv, D)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(n_pages * page)
    s = jnp.where(pos[None, None, :] < lengths[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
