"""Full sort = Pallas block-local bitonic sort + global bitonic merge stages.

The global stages are data-independent compare-exchanges at stride >= block,
expressed as reshape/min/max — bandwidth-bound, like the paper's DMA merge
passes.
"""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.mergesort.kernel import block_sort
from repro.kernels.mergesort.ref import sort_ref


def _global_stage(x, j):
    """One all-ascending compare-exchange ladder step at stride j."""
    n = x.shape[0]
    idx = jnp.arange(n)
    partner = idx ^ j
    xp = x[partner]
    keep_min = idx < partner
    return jnp.where(keep_min, jnp.minimum(x, xp), jnp.maximum(x, xp))


@functools.partial(jax.jit, static_argnames=("block", "interpret",
                                             "use_pallas"))
def mergesort(x, *, block: int = 1024, interpret: bool = True,
              use_pallas: bool = True):
    """Ascending sort of a power-of-two length array."""
    if not use_pallas:
        return sort_ref(x)
    n = x.shape[0]
    assert (n & (n - 1)) == 0, "power-of-two length"
    block = min(block, n)
    # bitonic structure requires alternating block directions; simplest
    # correct composition: local sort produces ascending blocks, then global
    # bitonic stages re-establish order per merge level k > block using full
    # compare-exchange ladders (j from k/2 down to 1).
    x = block_sort(x, block=block, interpret=interpret)
    k = block * 2
    while k <= n:
        # direction pattern for this level needs bitonic inputs: flip odd blocks
        nb = n // (k // 2)
        xb = x.reshape(nb, k // 2)
        flip = (jnp.arange(nb) % 2) == 1
        xb = jnp.where(flip[:, None], xb[:, ::-1], xb)
        x = xb.reshape(n)
        j = k // 2
        while j >= 1:
            x = _global_stage(x, j)
            j //= 2
        k *= 2
    return x
