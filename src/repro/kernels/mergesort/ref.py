import jax.numpy as jnp


def sort_ref(x):
    return jnp.sort(x)


def block_sort_ref(x, block: int):
    n = x.shape[0]
    return jnp.sort(x.reshape(n // block, block), axis=1).reshape(n)
