"""Sort (paper kernel #5) — TPU adaptation of the cluster's parallel merge
sort.

HARDWARE ADAPTATION (DESIGN.md §8): the Snitch implementation merges with
scalar cores; TPUs have no scalar sort units, so the TPU-native equivalent is
a BITONIC sorting network — data-independent compare-exchange stages that
vectorize on the VPU. The Pallas kernel sorts VMEM-resident blocks with a
fully unrolled bitonic network; the global stages (cross-block, bandwidth-
bound) run as jnp reshape/min/max passes in ops.py, playing the role of the
DMA-engine merge passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_block(x: jax.Array) -> jax.Array:
    """Fully-unrolled bitonic sort of a (rows, n) block along axis 1 (asc)."""
    rows, n = x.shape
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            idx = jnp.arange(n)
            partner = idx ^ j
            xp = x[:, partner]
            up = (idx & k) == 0                 # ascending region
            first = idx < partner
            keep_min = jnp.where(up, first, ~first)
            lo = jnp.minimum(x, xp)
            hi = jnp.maximum(x, xp)
            x = jnp.where(keep_min[None, :], lo, hi)
            j //= 2
        k *= 2
    return x


def _kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_block(x_ref[...])


def block_sort(x: jax.Array, *, block: int = 1024,
               interpret: bool = True) -> jax.Array:
    """Sort contiguous blocks of a (n,) array (n, block powers of two)."""
    n = x.shape[0]
    block = min(block, n)
    assert n % block == 0 and (block & (block - 1)) == 0
    xb = x.reshape(n // block, block)
    out = pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xb.shape, x.dtype),
        interpret=interpret,
    )(xb)
    return out.reshape(n)
