"""Fault tolerance: heartbeats, straggler detection, failure injection, and
the checkpoint/restart orchestration used by launch/train.py.

On a real cluster the heartbeat source is the coordinator's liveness RPC;
here it is process-local so the whole machinery is CPU-testable. The restart
loop is the piece that matters at 1000+ nodes: any step-time exception rolls
back to the last committed checkpoint and continues, and the restore path is
elastic (a different mesh shape reshards the same checkpoint).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class WorkerFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic fault injection for tests: fail at given step numbers."""
    fail_at_steps: tuple = ()
    kind: str = "worker"
    _fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise WorkerFailure(f"injected {self.kind} failure at step {step}")


class HeartbeatMonitor:
    """Tracks per-worker heartbeats; ``dead_workers`` after a timeout."""

    def __init__(self, workers: List[str], timeout_s: float = 30.0):
        self.timeout_s = timeout_s
        self.last: Dict[str, float] = {w: time.monotonic() for w in workers}

    def beat(self, worker: str):
        self.last[worker] = time.monotonic()

    def dead_workers(self) -> List[str]:
        now = time.monotonic()
        return [w for w, t in self.last.items() if now - t > self.timeout_s]

    def assert_alive(self):
        dead = self.dead_workers()
        if dead:
            raise WorkerFailure(f"workers missed heartbeat: {dead}")


class StragglerDetector:
    """Flags steps slower than ``factor`` x the rolling median step time.

    At pod scale the mitigation hook would reassign the slow host's shard;
    here we record and expose the events (and tests assert detection).
    """

    def __init__(self, window: int = 32, factor: float = 3.0):
        self.times = collections.deque(maxlen=window)
        self.factor = factor
        self.events: List[dict] = []

    def record(self, step: int, seconds: float) -> bool:
        med = (sorted(self.times)[len(self.times) // 2]
               if len(self.times) >= 8 else None)
        self.times.append(seconds)
        if med is not None and seconds > self.factor * med:
            self.events.append({"step": step, "seconds": seconds,
                                "median": med})
            return True
        return False


def run_with_restarts(train_loop: Callable[[Optional[int]], int],
                      max_restarts: int = 3) -> int:
    """Run ``train_loop(resume_step)``; on WorkerFailure, restart from the
    last checkpoint. Returns the final step. ``train_loop`` must itself
    restore state from its checkpoint dir when ``resume_step`` is not None."""
    restarts = 0
    resume: Optional[int] = None
    while True:
        try:
            return train_loop(resume)
        except WorkerFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            resume = -1     # sentinel: restore from latest
            print(f"[ft] {e}; restart {restarts}/{max_restarts} "
                  f"from latest checkpoint", flush=True)
