import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, with zero device allocation (ShapeDtypeStruct inputs).

Per cell this emits a JSON artifact with:
  * memory_analysis (per-device bytes: args / outputs / temps)
  * cost_analysis   (HLO FLOPs / bytes accessed)
  * collective bytes parsed from the optimized HLO text, by collective type
  * compile wall time

For roofline cost extraction (scan bodies are counted ONCE by XLA's cost
analysis — measured, see DESIGN.md §6) it can additionally compile unrolled
1-block and 2-block variants (--roofline) whose difference isolates the exact
per-block cost.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import re
import time
from collections import defaultdict

import jax
import numpy as np

from repro.configs import (SHAPES, TrainConfig, all_cells, cell_skip_reason,
                           get_config, get_shape)
from repro.launch.input_specs import input_specs
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import make_step
from repro.models import MeshInfo
from repro.models.params import abstract

# HLO collective result parsing: "bf16[128,4096]{...} all-reduce(..." etc.
_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)\(")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "tuple": 0,
}


def parse_collectives(hlo_text: str):
    """Sum result bytes per collective type (per-device program => per-chip)."""
    out = defaultdict(lambda: {"count": 0, "bytes": 0})
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind]["count"] += 1
        out[kind]["bytes"] += n * _DTYPE_BYTES[dt]
    return dict(out)


def collective_link_bytes(colls) -> int:
    """Roofline bytes-per-chip-on-link: all-reduce counts 2x (ring)."""
    total = 0
    for kind, v in colls.items():
        factor = 2 if kind == "all-reduce" else 1
        total += factor * v["bytes"]
    return total


def compile_cell(cfg, shape, mesh, tc=None, donate_cache=True):
    """Lower + compile one cell; returns (compiled, artifact_dict)."""
    mi = MeshInfo(mesh)
    step, state_specs = make_step(cfg, shape, mi, tc)
    state_abs = {k: abstract(v, mesh) for k, v in state_specs.items()}
    ins = input_specs(cfg, shape, mesh)

    t0 = time.time()
    with mesh_context(mesh):
        if shape.kind == "train":
            jitted = jax.jit(step, donate_argnums=(0, 1))
            lowered = jitted.lower(state_abs["params"], state_abs["opt_state"],
                                   ins["batch"])
        elif shape.kind == "prefill":
            jitted = jax.jit(step, donate_argnums=(2,) if donate_cache else ())
            lowered = jitted.lower(state_abs["params"], ins["batch"],
                                   ins["cache"])
        else:
            jitted = jax.jit(step, donate_argnums=(3,) if donate_cache else ())
            lowered = jitted.lower(state_abs["params"], ins["token"],
                                   ins["pos"], ins["cache"])
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):        # older JAX: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    art = {
        "arch": cfg.name,
        "shape": dataclasses.asdict(shape),
        "mesh": {"shape": tuple(int(s) for s in np.shape(mesh.devices)),
                 "axes": mesh.axis_names},
        "lower_s": round(t1 - t0, 3),
        "compile_s": round(t2 - t1, 3),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
                + mem.temp_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes,
        },
        "cost": {"flops": cost.get("flops", 0.0),
                 "bytes_accessed": cost.get("bytes accessed", 0.0)},
        "collectives": colls,
        "collective_link_bytes": collective_link_bytes(colls),
    }
    return compiled, art


def reduce_to_blocks(cfg, n: int):
    """Unrolled n-block variant of cfg (for per-block cost differencing)."""
    kw = dict(
        n_layers=cfg.first_k_dense + n * len(cfg.block_pattern),
        scan_blocks=False, unroll_scans=True,
        # single flash block: identical FLOPs, no 1000-step unrolled compile
        flash_q_chunk=1 << 30, flash_kv_chunk=1 << 30,
    )
    if cfg.is_encdec:
        kw["n_enc_layers"] = n * len(cfg.enc_block_pattern)
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool, roofline: bool,
             out_dir, tc=None, page_size=None):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    skip = cell_skip_reason(cfg, shape)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if skip:
        art = {"arch": arch, "shape": shape_name, "skipped": skip}
        _write(out_dir, tag, art)
        print(f"SKIP {tag}: {skip}")
        return art
    mesh = make_production_mesh(multi_pod=multi_pod)
    _, art = compile_cell(cfg, shape, mesh, tc)
    if roofline:
        for n in (1, 2):
            sub = reduce_to_blocks(cfg, n)
            _, sub_art = compile_cell(sub, shape, mesh, tc)
            art[f"unrolled_{n}block"] = {
                "cost": sub_art["cost"],
                "collectives": sub_art["collectives"],
                "collective_link_bytes": sub_art["collective_link_bytes"],
                "compile_s": sub_art["compile_s"],
            }
        art["n_blocks"] = cfg.n_blocks
    _write(out_dir, tag, art)
    mem_gb = art["memory"]["peak_bytes_per_device"] / 2**30
    print(f"OK   {tag}: compile={art['compile_s']:.1f}s "
          f"peak={mem_gb:.2f}GiB/dev flops={art['cost']['flops']:.3e} "
          f"coll={art['collective_link_bytes']:.3e}B", flush=True)
    return art


def _write(out_dir, tag, art):
    if out_dir:
        p = pathlib.Path(out_dir)
        p.mkdir(parents=True, exist_ok=True)
        (p / f"{tag}.json").write_text(json.dumps(art, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--roofline", action="store_true",
                    help="also compile unrolled 1/2-block cost variants")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    if args.all:
        cells = [(a, s) for a, s, _ in all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    t0 = time.time()
    n_ok = n_skip = n_fail = 0
    for arch, shape_name in cells:
        for mp in pods:
            tag = f"{arch}__{shape_name}__{'pod2' if mp else 'pod1'}"
            if args.skip_existing and (pathlib.Path(args.out) / f"{tag}.json").exists():
                existing = json.loads((pathlib.Path(args.out) / f"{tag}.json").read_text())
                if not existing.get("error"):
                    n_ok += 0 if existing.get("skipped") else 1
                    n_skip += 1 if existing.get("skipped") else 0
                    continue
            try:
                art = run_cell(arch, shape_name, mp, args.roofline, args.out)
                if art.get("skipped"):
                    n_skip += 1
                else:
                    n_ok += 1
            except Exception as e:  # noqa: BLE001 — record and continue
                n_fail += 1
                tag = f"{arch}__{shape_name}__{'pod2' if mp else 'pod1'}"
                _write(args.out, tag, {"arch": arch, "shape": shape_name,
                                       "error": repr(e)})
                print(f"FAIL {tag}: {e!r}", flush=True)
    print(f"\ndone in {time.time()-t0:.0f}s: ok={n_ok} skip={n_skip} "
          f"fail={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
