"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

The block stack (n_blocks, ...) is sharded over stages; microbatches flow
stage-to-stage via collective_permute (lax.ppermute). The schedule is the
classic GPipe fill-drain: T = n_micro + n_stages - 1 ticks, bubble fraction
(n_stages-1)/T. Backward is jax.grad through the loop (ppermute transposes
to the reverse permute), i.e. activations are stashed per tick.

This is an optional execution mode (off for the assigned production meshes,
which use DP x TP); it exists so the framework scales depth-wise across pods
— e.g. mesh ("stage", "data") with the pod axis as "stage".
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(block_params, x_micro, apply_stage: Callable, mesh,
                   stage_axis: str = "stage"):
    """Run microbatches through stage-sharded blocks.

    block_params: pytree, leaves (n_blocks, ...) — sharded over stage_axis.
    x_micro:      (n_micro, mb, S, d) microbatched activations (replicated).
    apply_stage:  fn(stage_block_params, x) -> x, applying the local blocks.

    Returns (n_micro, mb, S, d) outputs (replicated).
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    T = n_micro + n_stages - 1

    def per_stage(p_loc, xs):
        s = jax.lax.axis_index(stage_axis)
        # carries become stage-varying after the first ppermute; mark them so
        # (older JAX has no varying-manual-axes tracking: identity there)
        if hasattr(jax.lax, "pcast"):
            varying = lambda v: jax.lax.pcast(v, (stage_axis,), to="varying")
        else:
            varying = lambda v: v
        zero = varying(jnp.zeros_like(xs[0]))
        outs0 = varying(jnp.zeros_like(xs))
        xs = varying(xs)

        def tick(t, state):
            cur, outs = state
            # stage 0 injects microbatch t (when in range)
            mb_in = jnp.clip(t, 0, n_micro - 1)
            inject = xs[mb_in]
            cur = jnp.where(s == 0, inject, cur)
            y = apply_stage(p_loc, cur)
            # last stage records microbatch t-(n_stages-1)
            mb_out = t - (n_stages - 1)
            valid_out = jnp.logical_and(s == n_stages - 1,
                                        jnp.logical_and(mb_out >= 0,
                                                        mb_out < n_micro))
            idx = jnp.clip(mb_out, 0, n_micro - 1)
            outs = jnp.where(valid_out,
                             jax.lax.dynamic_update_index_in_dim(
                                 outs, y, idx, 0),
                             outs)
            # shift activations down the pipe
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            nxt = jax.lax.ppermute(y, stage_axis, perm)
            return (nxt, outs)

        (_, outs) = jax.lax.fori_loop(0, T, tick, (zero, outs0))
        # distribute the last stage's outputs to everyone
        outs = jax.lax.psum(
            jnp.where(s == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    in_block_spec = jax.tree.map(lambda _: P(stage_axis), block_params)
    from repro.models.dist import shard_map
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(in_block_spec, P()),
        out_specs=P(),
    )(block_params, x_micro)
