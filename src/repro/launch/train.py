"""Training launcher: synthetic data -> train_step loop with checkpointing,
failure injection, straggler detection, and restart-from-checkpoint.

Examples:
  # reduced llama on CPU, 30 steps, checkpoint every 10
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 30 --batch 4 --seq 128 --ckpt-dir /tmp/ck

  # inject a failure at step 12 and watch the restart path
  ... --inject-failure-at 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import latest_step, restore, save
from repro.configs import TrainConfig, get_config, reduce_for_smoke
from repro.data.pipeline import make_pipeline
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.launch.steps import make_train_step
from repro.models import MeshInfo, NO_MESH, init_params, model_specs
from repro.models.params import shardings as spec_shardings
from repro.optim import init_opt_state, opt_state_specs
from repro.runtime.ft import (FailureInjector, StragglerDetector,
                              run_with_restarts)


def build(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.layers:
        cfg = dataclasses.replace(
            cfg, n_layers=cfg.first_k_dense + args.layers * len(cfg.block_pattern))
    if args.d_model:
        head = max(args.d_model // max(cfg.n_heads, 1), 8)
        # scale_embeddings: from-scratch stability — with 0.02-init embeddings
        # the first rmsnorm's 1/rms amplifies backward ~50x into the tied
        # table (measured gnorm 2.6e6 -> 5e2 with the sqrt(d) scale).
        cfg = dataclasses.replace(cfg, d_model=args.d_model, d_head=head,
                                  d_ff=4 * args.d_model,
                                  vocab_size=min(cfg.vocab_size, 32768),
                                  scale_embeddings=True)
    return cfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0, dest="d_model")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--mesh", default="", help="e.g. 4,1 -> data=4,model=1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = build(args)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     grad_compression=args.grad_compression,
                     microbatches=args.microbatches)
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_host_mesh(data=d, model=m)
        mi = MeshInfo(mesh)
    else:
        mesh, mi = None, NO_MESH

    injector = FailureInjector(
        fail_at_steps=(args.inject_failure_at,) if args.inject_failure_at >= 0
        else ())
    straggler = StragglerDetector()
    executor = ThreadPoolExecutor(max_workers=1)
    step_fn = make_train_step(cfg, tc, mi)
    if mesh is not None:
        with mesh_context(mesh):
            step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    def train_loop(resume) -> int:
        params = init_params(cfg, jax.random.key(args.seed))
        opt = init_opt_state(params, with_ef=tc.grad_compression == "int8_ef")
        start = 0
        if resume is not None and args.ckpt_dir:
            step = latest_step(args.ckpt_dir)
            if step is not None:
                shard_tree = None
                if mesh is not None:
                    specs = model_specs(cfg)
                    shard_tree = {
                        "params": spec_shardings(specs, mesh),
                        "opt": spec_shardings(
                            opt_state_specs(
                                specs,
                                with_ef=tc.grad_compression == "int8_ef"),
                            mesh)}
                state = restore(args.ckpt_dir, step,
                                {"params": params, "opt": opt},
                                shardings=shard_tree)
                params, opt = state["params"], state["opt"]
                start = step
                print(f"[train] restored step {step}", flush=True)
        data = make_pipeline(cfg.vocab_size, args.batch, args.seq, args.seed)
        pending = None
        t_all = time.time()
        try:
            for step in range(start, args.steps):
                injector.check(step)
                toks, labels = next(data)
                batch = {"tokens": jnp.asarray(toks),
                         "labels": jnp.asarray(labels)}
                if cfg.is_encdec:
                    batch["enc_x"] = jnp.zeros((args.batch, 32, cfg.d_model),
                                               jnp.dtype(cfg.activation_dtype))
                elif cfg.n_image_tokens:
                    batch["img_x"] = jnp.zeros(
                        (args.batch, cfg.n_image_tokens, cfg.d_model),
                        jnp.dtype(cfg.activation_dtype))
                t0 = time.time()
                params, opt, metrics = step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                slow = straggler.record(step, dt)
                if step % args.log_every == 0:
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                          + ("  [straggler]" if slow else ""), flush=True)
                if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                    if pending is not None:
                        pending.result()
                    pending = save(args.ckpt_dir, step + 1,
                                   {"params": params, "opt": opt},
                                   executor=executor)
        finally:
            # Commit any in-flight async checkpoint even when a failure is
            # raised mid-loop: without this the crash loses the last save and
            # the restart silently begins from step 0.
            if pending is not None:
                pending.result()
        data.close()
        print(f"[train] done {args.steps - start} steps in "
              f"{time.time()-t_all:.1f}s; stragglers={len(straggler.events)}",
              flush=True)
        return args.steps

    run_with_restarts(train_loop, max_restarts=3)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
