"""Step builders: train_step (fwd+bwd+AdamW) and serve_step (prefill/decode).

All steps are pure functions of (params/opt_state, inputs) suitable for
``jax.jit`` with explicit in/out shardings, and are what the dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import (MeshInfo, forward_decode, forward_prefill,
                          forward_train, model_specs)
from repro.models.params import abstract, shardings as spec_shardings
from repro.optim import (OptState, adamw_update, clip_by_global_norm,
                         init_opt_state, opt_state_specs)


def make_train_step(cfg: ModelConfig, tc: TrainConfig, mi: MeshInfo):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_fn(params, batch):
        return forward_train(cfg, params, batch, mi)

    def train_step(params, opt_state: OptState, batch):
        if tc.microbatches > 1:
            B = batch["tokens"].shape[0]
            mb = tc.microbatches
            split = lambda x: x.reshape(mb, B // mb, *x.shape[1:])
            mbatch = jax.tree.map(split, batch)

            def acc_fn(carry, b):
                loss, g = jax.value_and_grad(loss_fn)(params, b)
                carry = jax.tree.map(jnp.add, carry,
                                     (loss, g))
                return carry, None
            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(acc_fn, zero, mbatch)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if tc.grad_compression == "int8_ef":
            from repro.optim.compression import ef_compress
            cg, new_ef = ef_compress(grads, opt_state.ef)
            grads = cg
            opt_state = opt_state._replace(ef=new_ef)

        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        params, opt_state, extras = adamw_update(params, grads, opt_state, tc)
        metrics = {"loss": loss, "grad_norm": gnorm, **extras}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mi: MeshInfo):
    def prefill_step(params, batch, cache):
        return forward_prefill(cfg, params, batch, cache, mi)
    return prefill_step


def make_decode_step(cfg: ModelConfig, mi: MeshInfo, sample: bool = False):
    def serve_step(params, token, pos, cache):
        logits, cache = forward_decode(cfg, params, token, pos, cache, mi)
        if sample:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, cache
        return logits, cache
    return serve_step


def make_step(cfg: ModelConfig, shape: ShapeConfig, mi: MeshInfo,
              tc: Optional[TrainConfig] = None):
    """The dry-run entry: step fn + (abstract) non-input state specs."""
    tc = tc or TrainConfig()
    pspecs = model_specs(cfg)
    if shape.kind == "train":
        fn = make_train_step(cfg, tc, mi)
        state_specs = {"params": pspecs,
                       "opt_state": opt_state_specs(
                           pspecs, with_ef=tc.grad_compression == "int8_ef")}
        return fn, state_specs
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mi), {"params": pspecs}
    return make_decode_step(cfg, mi), {"params": pspecs}
