"""Serving launcher: continuous-batching engine over the paged SVA layer.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --max-tokens 12 --offload-mode zero_copy
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.engine import ServingEngine
from repro.models import init_params


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--offload-mode", default="zero_copy",
                    choices=["zero_copy", "copy"])
    ap.add_argument("--translation-stats", action="store_true",
                    help="run decode-step page gathers through the IOMMU "
                         "for live IOTLB hit/miss stats (host-side sweep: "
                         "adds per-step overhead, off by default)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    params = init_params(cfg, jax.random.key(args.seed))
    eng = ServingEngine(cfg, params, n_slots=args.slots, max_len=args.max_len,
                        page_size=args.page_size,
                        offload_mode=args.offload_mode,
                        translation_stats=args.translation_stats)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    rids = [eng.submit(rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).tolist(),
                       max_tokens=args.max_tokens)
            for _ in range(args.requests)]
    done = eng.run()
    wall = time.time() - t0
    for rid in rids:
        r = done[rid]
        ttft = (r.first_token_at - r.submitted_at) * 1e3
        print(f"req {rid}: ttft={ttft:.0f}ms tokens={r.out_tokens[:8]}...")
    s = eng.stats()
    toks = s["tokens"]
    print(f"\n{toks} tokens in {wall:.2f}s ({toks/wall:.1f} tok/s) "
          f"mode={args.offload_mode}")
    print(json.dumps({k: v for k, v in s.items()
                      if k in ("prefills", "decode_steps", "staging_copies",
                               "sva", "tlb", "iommu", "svasan")}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
