"""ShapeDtypeStruct stand-ins for every model input of every dry-run cell.

Weak-type-correct, shardable, no device allocation. ``input_specs`` returns
(kwargs-for-step, donate-info) matching the step functions in steps.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import cache_specs
from repro.models.model import DEFAULT_PAGE_SIZE, ENCDEC_SRC_LEN
from repro.models.params import abstract, resolve_spec


def _sds(shape, dtype, spec: P, mesh):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=NamedSharding(mesh, resolve_spec(spec, shape, mesh)))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None
                ) -> Dict[str, Any]:
    """Training / prefill batch inputs."""
    B = shape.global_batch
    S = shape.seq_len
    act = jnp.dtype(cfg.activation_dtype)
    out = {"tokens": _sds((B, S), jnp.int32, P("batch", None), mesh)}
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32, P("batch", None), mesh)
    if cfg.is_encdec:
        src = S if shape.kind == "train" else ENCDEC_SRC_LEN
        out["enc_x"] = _sds((B, src, cfg.d_model), act, P("batch", None, None), mesh)
    elif cfg.n_image_tokens:
        out["img_x"] = _sds((B, cfg.n_image_tokens, cfg.d_model), act,
                            P("batch", None, None), mesh)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                 page_size: int = DEFAULT_PAGE_SIZE) -> Dict[str, Any]:
    """Decode-step inputs: one new token + the paged cache at length seq_len."""
    B = shape.global_batch
    src = ENCDEC_SRC_LEN
    cspecs = cache_specs(cfg, B, max_len=shape.seq_len, page_size=page_size,
                         src_len=src)
    cache = abstract(cspecs, mesh)
    return {
        "token": _sds((B, 1), jnp.int32, P("batch", None), mesh),
        "pos": _sds((), jnp.int32, P(), mesh),
        "cache": cache,
    }


def prefill_cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                        page_size: int = DEFAULT_PAGE_SIZE):
    cspecs = cache_specs(cfg, shape.global_batch, max_len=shape.seq_len,
                         page_size=page_size, src_len=ENCDEC_SRC_LEN)
    return abstract(cspecs, mesh)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None
                ) -> Dict[str, Any]:
    """All inputs for the cell's step function (see steps.make_step)."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, mesh)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, mesh),
                "cache": prefill_cache_specs(cfg, shape, mesh)}
    return decode_specs(cfg, shape, mesh)
