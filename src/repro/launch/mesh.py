"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. ``dryrun.py`` sets XLA_FLAGS for 512 placeholder devices
*before* any jax import; everything else sees the real device count.

Version compatibility: ``jax.sharding.AxisType`` / ``jax.set_mesh`` only
exist in newer JAX releases. On older versions (e.g. 0.4.37) meshes are
built without explicit axis types — every sharding in this codebase is an
explicit NamedSharding, so no ambient mesh is required — and
``mesh_context`` degrades to a no-op context manager.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def _axis_type_kwargs(n_axes: int) -> dict:
    if _HAS_AXIS_TYPES:
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]
              ) -> jax.sharding.Mesh:
    """Version-portable ``jax.make_mesh`` (explicit Auto axes when supported)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def mesh_context(mesh: jax.sharding.Mesh):
    """``jax.set_mesh(mesh)`` where it exists, else a no-op context."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_from_config(mc: MeshConfig) -> jax.sharding.Mesh:
    return make_mesh(mc.shape, mc.axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1
                   ) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return make_mesh((data, model), ("data", "model"))
