"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. ``dryrun.py`` sets XLA_FLAGS for 512 placeholder devices
*before* any jax import; everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_from_config(mc: MeshConfig) -> jax.sharding.Mesh:
    return jax.make_mesh(
        mc.shape, mc.axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(mc.axes))


def make_host_mesh(data: Optional[int] = None, model: int = 1
                   ) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
