"""Model assembly: embedding -> (first dense layers) -> scanned blocks -> head.

Public API:
  model_specs(cfg)                         ParamSpec tree
  cache_specs(cfg, batch, max_len, ...)    cache ParamSpec tree (serve modes)
  init_cache(cfg, batch, max_len, ...)     zero-filled runtime cache
  forward_train(cfg, params, batch, mi)    -> (loss, aux)
  forward_prefill(cfg, params, batch, cache, mi) -> (last_logits, cache)
  forward_decode(cfg, params, token, pos, cache, mi) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.blocks import (FwdCtx, apply_block, block_specs,
                                 layer_cache_specs, stack_specs, _sp_mode)
from repro.models.dist import NO_MESH, MeshInfo, shard
from repro.models.layers import (chunked_xent, embed, embedding_specs,
                                 logits_fn, rmsnorm, rmsnorm_spec)
from repro.models.params import ParamSpec, materialize, tree_map_specs

DEFAULT_PAGE_SIZE = 64
ENCDEC_SRC_LEN = 3072          # stubbed audio-frame count for serve shapes


# --------------------------------------------------------------- specs

def _residual_init_damping(specs: Dict[str, Any], cfg: ModelConfig):
    """GPT-2-style init: residual-writing projections scaled by 1/sqrt(2L)
    so the backward signal into the embedding stays O(1) at init (measured:
    embedding grad-norm 2.2e6 -> O(1e2) on a 12L/768 from-scratch run)."""
    import math
    damp = 1.0 / math.sqrt(2.0 * max(cfg.n_layers, 1))
    res_keys = {"wo", "w_down", "w2", "out_proj"}

    def walk(tree, name=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, ParamSpec) and name in res_keys \
                and tree.init == "normal" and len(tree.shape) >= 2:
            base = tree.scale if tree.scale is not None else tree.fan_in() ** -0.5
            return ParamSpec(tree.shape, tree.dtype, tree.pspec, tree.init,
                             base * damp)
        if isinstance(tree, tuple) and hasattr(tree, "_fields"):
            return type(tree)(*(walk(v) for v in tree))
        return tree
    return walk(specs)


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.param_dtype)
    specs: Dict[str, Any] = {
        "embed": embedding_specs(cfg.vocab_size, cfg.d_model, dt,
                                 cfg.tie_embeddings),
        "final_ln": rmsnorm_spec(cfg.d_model, dt),
        "blocks": stack_specs(block_specs(cfg), cfg.n_blocks),
    }
    if cfg.first_k_dense:
        from repro.models.blocks import layer_specs
        specs["first"] = {str(i): layer_specs(cfg, "attn_mlp")
                          for i in range(cfg.first_k_dense)}
    if cfg.is_encdec:
        specs["encoder"] = {
            "blocks": stack_specs(
                {str(i): _enc_layer_specs(cfg, k)
                 for i, k in enumerate(cfg.enc_block_pattern)},
                cfg.n_enc_layers // len(cfg.enc_block_pattern)),
            "final_ln": rmsnorm_spec(cfg.d_model, dt),
        }
    return _residual_init_damping(specs, cfg)


def _enc_layer_specs(cfg, kind):
    from repro.models.blocks import layer_specs
    return layer_specs(cfg, kind)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                page_size: int = DEFAULT_PAGE_SIZE,
                src_len: int = ENCDEC_SRC_LEN,
                per_seq: bool = False,
                global_pages: Optional[int] = None) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    blk = {}
    for i, kind in enumerate(cfg.block_pattern):
        c = layer_cache_specs(cfg, kind, batch, max_len, page_size, src_len,
                              stack=cfg.n_blocks, per_seq=per_seq,
                              global_pages=global_pages)
        if c is not None:
            blk[str(i)] = c
    out["blocks"] = blk
    if cfg.first_k_dense:
        out["first"] = {
            str(i): layer_cache_specs(cfg, "attn_mlp", batch, max_len,
                                      page_size, src_len, per_seq=per_seq,
                                      global_pages=global_pages)
            for i in range(cfg.first_k_dense)}
    if cfg.is_encdec:
        # encoder output embeddings, needed by decode steps
        out["enc_out"] = ParamSpec((batch, src_len, cfg.d_model),
                                   jnp.dtype(cfg.activation_dtype),
                                   P("batch", "tp", None), init="zeros")
    return out


def _identity_tables(cache):
    """Fill block tables with the identity mapping (dry-run/smoke default;
    the serving engine supplies real page allocations). Global-layout leaves
    instead start with every entry NULL (== total pages): nothing is mapped
    until the engine uploads real rows."""
    def walk(tree):
        if isinstance(tree, attn.PagedKV):
            bt = tree.block_table
            if attn.is_global_layout(tree):
                return tree._replace(
                    block_table=jnp.full_like(bt, tree.k_pool.shape[-4]))
            n_pages = bt.shape[-1]
            iota = jnp.broadcast_to(
                jnp.arange(n_pages, dtype=jnp.int32), bt.shape)
            return tree._replace(block_table=iota)
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, tuple) and hasattr(tree, "_fields"):
            return tree
        return tree
    return walk(cache)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               page_size: int = DEFAULT_PAGE_SIZE,
               src_len: int = ENCDEC_SRC_LEN,
               length: int = 0, per_seq: bool = False,
               global_pages: Optional[int] = None):
    specs = cache_specs(cfg, batch, max_len, page_size, src_len,
                        per_seq=per_seq, global_pages=global_pages)
    cache = materialize(specs, jax.random.key(0))
    cache = _identity_tables(cache)
    if length:
        cache = set_cache_length(cache, length)
    return cache


def set_cache_length(cache, length):
    """``length``: scalar, or (B,) per-sequence lengths (batched prefill)."""
    length = jnp.asarray(length)
    def walk(tree):
        if isinstance(tree, attn.PagedKV):
            return tree._replace(
                length=jnp.broadcast_to(length, tree.length.shape)
                .astype(tree.length.dtype))
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return tree
    return walk(cache)


# --------------------------------------------------------------- forward

def _maybe_remat(fn, cfg: ModelConfig, mode: str):
    if mode != "train" or cfg.remat == "none":
        return fn
    policy = {
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "full": None,
    }[cfg.remat]
    return jax.checkpoint(fn, policy=policy)


def _run_blocks(cfg, params, x, ctx: FwdCtx, cache):
    """Apply first_k_dense layers then the scanned block stack."""
    from repro.models.blocks import apply_layer

    new_cache: Dict[str, Any] = {} if cache is not None else None
    if cfg.first_k_dense:
        fc_out = {}
        for i in range(cfg.first_k_dense):
            c_in = cache.get("first", {}).get(str(i)) if cache else None
            x, c_out = apply_layer("attn_mlp", params["first"][str(i)], x,
                                   ctx, c_in)
            if cache is not None:
                fc_out[str(i)] = c_out
        if cache is not None:
            new_cache["first"] = fc_out

    blk_cache = cache.get("blocks") if cache else None

    def body(x, xs):
        p_blk, c_blk = xs
        x, c_out = apply_block(p_blk, x, ctx, c_blk)
        return x, c_out

    body = _maybe_remat(body, cfg, ctx.mode)

    if cfg.scan_blocks and cfg.n_blocks > 1:
        x, c_stack = jax.lax.scan(body, x, (params["blocks"], blk_cache))
    else:
        c_list = []
        for b in range(cfg.n_blocks):
            take = lambda t: jax.tree.map(lambda a: a[b], t)
            x, c_out = body(x, (take(params["blocks"]),
                                take(blk_cache) if blk_cache is not None else None))
            c_list.append(c_out)
        c_stack = (jax.tree.map(lambda *xs: jnp.stack(xs), *c_list)
                   if cache is not None and c_list and c_list[0] is not None
                   else None)
    if cache is not None:
        new_cache["blocks"] = c_stack
    return x, new_cache


def _run_encoder(cfg, params, enc_x, mi: MeshInfo):
    ctx = FwdCtx(cfg=cfg, mi=mi, mode="train", causal=False)
    x = enc_x.astype(jnp.dtype(cfg.activation_dtype))
    enc = params["encoder"]

    def body(x, p_blk):
        x, _ = apply_block(p_blk, x, ctx, None, pattern=cfg.enc_block_pattern)
        return x, None

    if cfg.scan_blocks:
        x, _ = jax.lax.scan(body, x, enc["blocks"])
    else:
        n = cfg.n_enc_layers // len(cfg.enc_block_pattern)
        for b in range(n):
            x, _ = body(x, jax.tree.map(lambda a: a[b], enc["blocks"]))
    return rmsnorm(x, enc["final_ln"], cfg.norm_eps)


def _embed_in(cfg, params, tokens, mi):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activation_dtype))
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, mi, P("batch", None, None))


def forward_train(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
                  mi: MeshInfo = NO_MESH):
    """batch: tokens (B,S), labels (B,S); + enc_x / img_x per family."""
    tokens, labels = batch["tokens"], batch["labels"]
    cross_x = None
    if cfg.is_encdec:
        cross_x = _run_encoder(cfg, params, batch["enc_x"], mi)
    elif cfg.n_image_tokens:
        cross_x = batch["img_x"].astype(jnp.dtype(cfg.activation_dtype))
    ctx = FwdCtx(cfg=cfg, mi=mi, mode="train", cross_x=cross_x)
    x = _embed_in(cfg, params, tokens, mi)
    x, _ = _run_blocks(cfg, params, x, ctx, None)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    loss = chunked_xent(params["embed"], x, labels, cfg.logit_softcap,
                        unroll=cfg.unroll_scans)
    return loss


def forward_prefill(cfg: ModelConfig, params, batch, cache,
                    mi: MeshInfo = NO_MESH):
    """``batch`` may carry ``lengths`` (B,) int32 — real per-sequence prompt
    lengths when rows are right-padded (batched/bucketed serving prefill):
    logits are then taken at each row's last REAL token and cache lengths
    are set per sequence.

    CoW prefix sharing adds ``prefix_lens`` (B,) int32 — tokens already
    resident in the shared pool, so ``tokens`` holds only each prompt's
    SUFFIX (``lengths`` = suffix lengths) — and ``write_tables`` (B, P)
    int32, the scatter tables with shared entries NULLed (see
    core/serving/engine.py)."""
    tokens = batch["tokens"]
    lengths = batch.get("lengths")
    prefix_lens = batch.get("prefix_lens")
    cross_x = None
    if cfg.is_encdec:
        cross_x = _run_encoder(cfg, params, batch["enc_x"], mi)
    elif cfg.n_image_tokens:
        cross_x = batch["img_x"].astype(jnp.dtype(cfg.activation_dtype))
    ctx = FwdCtx(cfg=cfg, mi=mi, mode="prefill", cross_x=cross_x,
                 seq_lengths=lengths, kv_prefix_lens=prefix_lens,
                 write_tables=batch.get("write_tables"))
    x = _embed_in(cfg, params, tokens, mi)
    x, cache = _run_blocks(cfg, params, x, ctx, cache)
    if cfg.is_encdec:
        cache["enc_out"] = cross_x
    if lengths is None:
        x = x[:, -1:]
    else:
        idx = jnp.maximum(lengths.astype(jnp.int32) - 1, 0)[:, None, None]
        x = jnp.take_along_axis(x, idx, axis=1)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = logits_fn(params["embed"], x, cfg.logit_softcap)
    total = tokens.shape[1] if lengths is None else lengths
    if prefix_lens is not None:
        total = total + prefix_lens        # cache holds prefix + suffix
    cache = set_cache_length(cache, total)
    return logits, cache


def forward_decode(cfg: ModelConfig, params, token, pos, cache,
                   mi: MeshInfo = NO_MESH, sp: Optional[bool] = None):
    """token: (B,1) int32; pos: scalar int32 (current cache length)."""
    if sp is None:
        sp = _decode_is_sp(cfg, cache)
    cross_x = cache.get("enc_out") if cfg.is_encdec else None
    ctx = FwdCtx(cfg=cfg, mi=mi, mode="decode", q_offset=pos,
                 cross_x=cross_x, sp=sp)
    x = _embed_in(cfg, params, token, mi)
    x, cache_out = _run_blocks(cfg, params, x, ctx, cache)
    if cfg.is_encdec:
        cache_out["enc_out"] = cache["enc_out"]
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = logits_fn(params["embed"], x, cfg.logit_softcap)
    return logits, cache_out


def _decode_is_sp(cfg, cache) -> bool:
    def find_kv(tree):
        if isinstance(tree, attn.PagedKV):
            return tree
        if isinstance(tree, dict):
            for v in tree.values():
                r = find_kv(v)
                if r is not None:
                    return r
        return None
    kv = find_kv(cache)
    if kv is None:
        return False
    if attn.is_global_layout(kv):
        return False            # global serving layout is never SP-sharded
    batch = kv.k_pool.shape[-5 + 0] if kv.k_pool.ndim == 5 else kv.k_pool.shape[1]
    n_pages = kv.k_pool.shape[-4]
    page = kv.k_pool.shape[-3]
    return _sp_mode(cfg, batch, n_pages * page)


def init_params(cfg: ModelConfig, key: jax.Array):
    return materialize(model_specs(cfg), key)
