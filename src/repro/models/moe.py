"""Mixture-of-Experts FFN with GShard-style dense dispatch.

Tokens are grouped; each group computes a (Tg, E, C) combine tensor (top-k
gates scattered to per-expert capacity slots) and dispatch/combine einsums
move activations to expert-sharded buffers. Under the production mesh:

  * token groups G shard over the full mesh (pod, data, model),
  * expert weights shard E over the ``model`` axis (expert parallelism),
  * the (G,E,C,d) dispatched buffer is resharded G->(pod,data), E->model —
    XLA lowers that resharding to the expert all-to-all.

Dispatch-einsum FLOPs are ~(E*C/d_ff) of the expert GEMM FLOPs — small at the
assigned configs (verified in the roofline's MODEL_FLOPS/HLO_FLOPS column).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.dist import MeshInfo, shard
from repro.models.layers import glu_mlp, glu_mlp_specs, _act
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    out = {
        "router": ParamSpec((d, m.n_experts), jnp.float32, P("fsdp", None),
                            init="normal", scale=d ** -0.5),
        "w_gate": ParamSpec((m.n_experts, d, m.d_ff), dt, P("tp", "fsdp", None)),
        "w_up": ParamSpec((m.n_experts, d, m.d_ff), dt, P("tp", "fsdp", None)),
        "w_down": ParamSpec((m.n_experts, m.d_ff, d), dt, P("tp", None, "fsdp")),
    }
    if m.n_shared_experts:
        out["shared"] = glu_mlp_specs(d, m.d_ff * m.n_shared_experts, dt)
    return out


def _n_groups(n_tokens: int, mi: MeshInfo) -> int:
    """Groups shard over the whole mesh; fall back gracefully for tiny T."""
    want = 1
    for a in mi.all_axes:
        want *= mi.size(a)
    g = math.gcd(n_tokens, max(want, 1))
    return max(g, 1)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig, mi: MeshInfo,
            router_noise_key: Optional[jax.Array] = None) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    G = _n_groups(T, mi)
    Tg = T // G
    E, K = m.n_experts, m.experts_per_token
    capacity = max(int(math.ceil(Tg * K / E * m.capacity_factor)), 1)

    xt = x.reshape(G, Tg, d)
    xt = shard(xt, mi, P(mi.all_axes, None, None))

    # --- routing (fp32) ---
    logits = (xt.astype(jnp.float32) @ p["router"])            # (G,Tg,E)
    if router_noise_key is not None:                            # optional jitter
        logits = logits + 1e-2 * jax.random.gumbel(router_noise_key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, K)              # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- capacity assignment (classic GShard): position of each (token,choice)
    # within its expert's capacity buffer, computed choice-major so the first
    # choice wins capacity.
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.int32)       # (G,Tg,K,E)
    oh_flat = onehot.swapaxes(1, 2).reshape(G, K * Tg, E)       # choice-major
    pos_flat = jnp.cumsum(oh_flat, axis=1) - 1                  # (G,K*Tg,E)
    pos = (pos_flat.reshape(G, K, Tg, E).swapaxes(1, 2)
           * onehot).sum(-1)                                    # (G,Tg,K)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    # combine tensor (G,Tg,E,C): gate at (expert, slot), zero elsewhere
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                             dtype=xt.dtype)[..., :capacity]    # (G,Tg,K,C)
    combine = jnp.einsum("gtke,gtkc->gtec",
                         onehot.astype(xt.dtype) * gate_vals[..., None].astype(xt.dtype),
                         slot_oh)                               # (G,Tg,E,C)
    dispatch = (combine > 0).astype(xt.dtype)

    # --- dispatch -> expert FFN -> combine ---
    de = jnp.einsum("gtec,gtd->gecd", dispatch, xt)             # (G,E,C,d)
    de = shard(de, mi, P(("pod", "data") if mi.multi_pod else ("data",),
                         "tp", None, None))
    h = _act(cfg.act)(jnp.einsum("gecd,edf->gecf", de, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", de, p["w_up"])
    eo = jnp.einsum("gecf,efd->gecd", h, p["w_down"])           # (G,E,C,d)
    eo = shard(eo, mi, P(("pod", "data") if mi.multi_pod else ("data",),
                         "tp", None, None))
    out = jnp.einsum("gtec,gecd->gtd", combine, eo)
    out = shard(out, mi, P(mi.all_axes, None, None))
    out = out.reshape(B, S, d)

    if "shared" in p:
        out = out + glu_mlp(p["shared"], x, cfg.act)
    return out


def aux_load_balance_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (fraction * probability)."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ p["router"]).reshape(-1, m.n_experts)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, m.n_experts, dtype=jnp.float32), axis=0)
    prob = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(frac * prob)
