"""Mamba-1 selective SSM mixer (jamba's sequence layer).

    h_t = exp(dt_t * A) h_{t-1} + (dt_t * B_t) x_t      (diagonal A, ZOH-lite)
    y_t = C_t . h_t + D * x_t

Training uses a chunked associative scan over time (memory-bounded); decode
is the O(1) single-step recurrence. d_inner is tensor-parallel over ``model``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.dist import MeshInfo
from repro.models.params import ParamSpec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.d_state, s.d_conv


def mamba_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, dt_rank, N, K = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "in_proj": ParamSpec((d, 2 * d_in), dt, P("fsdp", "tp")),
        "conv_w": ParamSpec((K, d_in), dt, P(None, "tp")),
        "conv_b": ParamSpec((d_in,), dt, P("tp"), init="zeros"),
        "x_proj": ParamSpec((d_in, dt_rank + 2 * N), dt, P("tp", None)),
        "dt_proj": ParamSpec((dt_rank, d_in), dt, P(None, "tp")),
        "dt_bias": ParamSpec((d_in,), jnp.float32, P("tp"),
                             init="uniform_pm", scale=4.0),
        "A_log": ParamSpec((d_in, N), jnp.float32, P("tp", None),
                           init="uniform_pm", scale=1.0),
        "D": ParamSpec((d_in,), jnp.float32, P("tp"), init="ones"),
        "out_proj": ParamSpec((d_in, d), dt, P("tp", "fsdp")),
    }


class MambaState(NamedTuple):
    conv: jax.Array   # (B, K-1, d_in) last inputs for the causal conv
    ssm: jax.Array    # (B, d_in, N) fp32


def mamba_state_specs(cfg: ModelConfig, batch: int, stack=None) -> MambaState:
    d_in, _, N, K = _dims(cfg)
    lead = (stack,) if stack else ()
    ld = (None,) * len(lead)
    dt = jnp.dtype(cfg.activation_dtype)
    return MambaState(
        conv=ParamSpec(lead + (batch, K - 1, d_in), dt,
                       P(*ld, "batch", None, "tp"), init="zeros"),
        ssm=ParamSpec(lead + (batch, d_in, N), jnp.float32,
                      P(*ld, "batch", "tp", None), init="zeros"),
    )


def _conv_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                 carry: jax.Array):
    """Depthwise causal conv. x: (B,T,d_in), w: (K,d_in), carry: (B,K-1,d_in)."""
    K = w.shape[0]
    xp = jnp.concatenate([carry, x], axis=1)                  # (B, T+K-1, d_in)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    return out, xp[:, -(K - 1):]


def _ssm_inputs(p: dict, x: jax.Array, cfg: ModelConfig):
    d_in, dt_rank, N, _ = _dims(cfg)
    proj = x @ p["x_proj"]                                    # (B,T,dt_rank+2N)
    dt_lr, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus((dt_lr @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])                      # (B,T,d_in)
    A = -jnp.exp(p["A_log"])                                  # (d_in,N)
    decay = jnp.exp(dt[..., None] * A)                        # (B,T,d_in,N)
    drive = (dt[..., None] * Bm[:, :, None, :].astype(jnp.float32)
             * x[..., None].astype(jnp.float32))              # (B,T,d_in,N)
    return decay, drive, Cm


def mamba_mix(p: dict, x: jax.Array, cfg: ModelConfig, mi: MeshInfo,
              state: MambaState, chunk: int = 256):
    """x: (B,T,d). Returns (out (B,T,d), new MambaState)."""
    B, T, d = x.shape
    d_in, _, N, K = _dims(cfg)
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_carry = _conv_causal(xi, p["conv_w"], p["conv_b"], state.conv)
    xi = jax.nn.silu(xi)

    decay, drive, Cm = _ssm_inputs(p, xi, cfg)

    nC = max(T // chunk, 1)
    C = T // nC
    dec_c = decay.reshape(B, nC, C, d_in, N).swapaxes(0, 1)
    drv_c = drive.reshape(B, nC, C, d_in, N).swapaxes(0, 1)

    def chunk_step(h0, inp):
        dec, drv = inp                                        # (B,C,d_in,N)
        # associative scan within the chunk: (a, b) pairs
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2
        a_sc, b_sc = jax.lax.associative_scan(comb, (dec, drv), axis=1)
        h = a_sc * h0[:, None] + b_sc                         # (B,C,d_in,N)
        return h[:, -1], h

    h0 = state.ssm.astype(jnp.float32)
    h_fin, hs = jax.lax.scan(jax.checkpoint(chunk_step), h0, (dec_c, drv_c),
                             unroll=bool(cfg.unroll_scans))
    h = hs.swapaxes(0, 1).reshape(B, T, d_in, N)
    y = jnp.einsum("btdn,btn->btd", h, Cm.astype(jnp.float32)) \
        + p["D"] * xi.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, MambaState(conv=conv_carry, ssm=h_fin)


def mamba_mix_step(p: dict, x: jax.Array, cfg: ModelConfig,
                   state: MambaState):
    """Single-token decode. x: (B,1,d)."""
    B, _, d = x.shape
    d_in, _, N, K = _dims(cfg)
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_c, conv_carry = _conv_causal(xi, p["conv_w"], p["conv_b"], state.conv)
    xi_c = jax.nn.silu(xi_c)
    decay, drive, Cm = _ssm_inputs(p, xi_c, cfg)
    h = decay[:, 0] * state.ssm + drive[:, 0]                 # (B,d_in,N)
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32)) \
        + p["D"] * xi_c[:, 0].astype(jnp.float32)
    out = (y[:, None].astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, MambaState(conv=conv_carry, ssm=h)
