from repro.models.model import (cache_specs, forward_decode, forward_prefill,
                                forward_train, init_cache, init_params,
                                model_specs, set_cache_length)
from repro.models.dist import MeshInfo, NO_MESH, shard

__all__ = [
    "MeshInfo", "NO_MESH", "cache_specs", "forward_decode", "forward_prefill",
    "forward_train", "init_cache", "init_params", "model_specs",
    "set_cache_length", "shard",
]
