"""RWKV-6 "Finch": data-dependent-decay linear attention + channel mix.

Time-mix recurrence per head (head size N):
    y_t   = r_t^T (S_t + u ⊙ k_t v_t^T)
    S_t+1 = diag(w_t) S_t + k_t v_t^T        (w_t data-dependent, in (0,1))

Training uses the chunked parallel form: within a chunk the (t,s) interaction
matrix uses per-channel cumulative log-decays (all exponents <= 0, so the
quadratic form is numerically safe); across chunks the (N x N) state is
carried by a scan. Decode is the plain recurrence.

Faithfulness note (DESIGN.md §8): the ddlerp token-shift mixing uses static
per-target mix coefficients plus a low-rank *data-dependent decay* (the Finch
headline feature); the auxiliary low-rank mixers for r/k/v/g are folded into
the static coefficients.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.dist import MeshInfo, shard
from repro.models.params import ParamSpec

LORA_R = 64   # decay low-rank width


def rwkv_time_mix_specs(cfg: ModelConfig) -> dict:
    d, H, N = cfg.d_model, cfg.n_heads, cfg.d_head
    dt = jnp.dtype(cfg.param_dtype)
    mat = lambda: ParamSpec((d, d), dt, P("fsdp", "tp"))
    return {
        "mu": ParamSpec((5, d), dt, P(None, None), init="uniform_pm", scale=0.5),
        "w0": ParamSpec((d,), jnp.float32, P(None), init="uniform_pm", scale=1.0),
        "w_lora_a": ParamSpec((d, LORA_R), dt, P("fsdp", None)),
        "w_lora_b": ParamSpec((LORA_R, d), jnp.float32, P(None, None),
                              init="zeros"),
        "u": ParamSpec((H, N), jnp.float32, P("tp", None),
                       init="uniform_pm", scale=0.5),
        "wr": mat(), "wk": mat(), "wv": mat(), "wg": mat(),
        "wo": ParamSpec((d, d), dt, P("tp", "fsdp")),
        "ln_w": ParamSpec((d,), jnp.float32, P(None), init="ones"),
    }


def rwkv_channel_mix_specs(cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "mu_k": ParamSpec((d,), dt, P(None), init="uniform_pm", scale=0.5),
        "mu_r": ParamSpec((d,), dt, P(None), init="uniform_pm", scale=0.5),
        "wk": ParamSpec((d, ff), dt, P("fsdp", "tp")),
        "wv": ParamSpec((ff, d), dt, P("tp", "fsdp")),
        "wr": ParamSpec((d, d), dt, P("fsdp", "tp")),
    }


class RWKVState(NamedTuple):
    wkv: jax.Array        # (B, H, N, N) fp32
    shift_tm: jax.Array   # (B, d) last token (time-mix)
    shift_cm: jax.Array   # (B, d) last token (channel-mix)


def rwkv_state_specs(cfg: ModelConfig, batch: int, stack=None) -> RWKVState:
    d, H, N = cfg.d_model, cfg.n_heads, cfg.d_head
    lead = (stack,) if stack else ()
    ld = (None,) * len(lead)
    dt = jnp.dtype(cfg.activation_dtype)
    return RWKVState(
        wkv=ParamSpec(lead + (batch, H, N, N), jnp.float32,
                      P(*ld, "batch", "tp", None, None), init="zeros"),
        shift_tm=ParamSpec(lead + (batch, d), dt, P(*ld, "batch", None), init="zeros"),
        shift_cm=ParamSpec(lead + (batch, d), dt, P(*ld, "batch", None), init="zeros"),
    )


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel decay w_t in (0,1); returns log(w_t) (fp32)."""
    lora = jnp.tanh(xw @ p["w_lora_a"]).astype(jnp.float32) @ p["w_lora_b"]
    return -jnp.exp(jnp.clip(p["w0"] + lora, -8.0, 6.0))    # log w <= 0


def _group_norm(y: jax.Array, w: jax.Array, H: int, eps: float = 64e-5):
    """Per-head groupnorm over the value dim. y: (B, T, H, N)."""
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + eps)
    B, T = y.shape[:2]
    return yn.reshape(B, T, -1) * w


def rwkv_time_mix(p: dict, x: jax.Array, x_prev: jax.Array, cfg: ModelConfig,
                  mi: MeshInfo, state: jax.Array, chunk: int = 32):
    unroll = bool(cfg.unroll_scans)
    """Chunked-parallel WKV6. x: (B,T,d); x_prev: x shifted right by one.

    Returns (out (B,T,d), final_state (B,H,N,N)).
    """
    B, T, d = x.shape
    H, N = cfg.n_heads, cfg.d_head
    dx = x_prev - x
    mix = lambda i: x + dx * p["mu"][i]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))

    r = (xr @ p["wr"]).reshape(B, T, H, N)
    k = (xk @ p["wk"]).reshape(B, T, H, N)
    v = (xv @ p["wv"]).reshape(B, T, H, N)
    g = jax.nn.silu(xg @ p["wg"])
    logw = _decay(p, xw).reshape(B, T, H, N)                 # fp32, <= 0

    nC = max(T // chunk, 1)
    C = T // nC
    rc = r.reshape(B, nC, C, H, N).swapaxes(0, 1)
    kc = k.reshape(B, nC, C, H, N).swapaxes(0, 1)
    vc = v.reshape(B, nC, C, H, N).swapaxes(0, 1)
    wc = logw.reshape(B, nC, C, H, N).swapaxes(0, 1)

    u = p["u"]                                               # (H,N)

    def chunk_step(S, inp):
        rj, kj, vj, wj = inp                                 # (B,C,H,N)
        rf, kf, vf = (a.astype(jnp.float32) for a in (rj, kj, vj))
        cl = jnp.cumsum(wj, axis=1)                          # (B,C,H,N) inclusive
        cl_prev = cl - wj                                    # exclusive cumsum
        # inter: y_inter[t] = (r_t * exp(cl_prev_t))^T S
        q_in = rf * jnp.exp(cl_prev)
        y_inter = jnp.einsum("bchn,bhnm->bchm", q_in, S)
        # intra: A[t,s] = sum_n r_t[n] k_s[n] exp(cl_prev_t[n] - cl_s[n]), s < t
        expo = cl_prev[:, :, None] - cl[:, None, :]          # (B,t,s,H,N)
        mask_lt = jnp.tril(jnp.ones((C, C), bool), -1)
        expo = jnp.where(mask_lt[None, :, :, None, None], expo, -jnp.inf)
        A = jnp.einsum("bthn,bshn,btshn->bths", rf, kf, jnp.exp(expo))
        # diagonal bonus term u
        diag = jnp.einsum("bthn,hn,bthn->bth", rf, u, kf)
        y = y_inter + jnp.einsum("bths,bshm->bthm", A, vf) \
            + diag[..., None] * vf
        # state update: S' = diag(prod w) S + sum_s k_s exp(cl_end - cl_s) v_s^T
        cl_end = cl[:, -1]                                   # (B,H,N)
        k_dec = kf * jnp.exp(cl_end[:, None] - cl)
        S_new = jnp.exp(cl_end)[..., None] * S \
            + jnp.einsum("bchn,bchm->bhnm", k_dec, vf)
        return S_new, y

    S0 = state.astype(jnp.float32)
    if unroll:
        # Roofline-cost path: batched-parallel chunk form — all heavy math
        # runs ONCE over a leading chunk axis (fully visible to XLA's
        # cost_analysis, which counts while bodies once); only the tiny
        # (B,H,N,N) state recurrence remains a scan (~0.1% of FLOPs).
        rf, kf, vf = (a.astype(jnp.float32) for a in (rc, kc, vc))
        cl = jnp.cumsum(wc, axis=2)                          # (nC,B,C,H,N)
        cl_prev = cl - wc
        cl_end = cl[:, :, -1]                                # (nC,B,H,N)
        k_dec = kf * jnp.exp(cl_end[:, :, None] - cl)
        B_sum = jnp.einsum("jbchn,jbchm->jbhnm", k_dec, vf)
        A_decay = jnp.exp(cl_end)

        def state_step(S, inp):
            a, b = inp
            return a[..., None] * S + b, S                    # ys: pre-chunk state
        S_fin, S_in = jax.lax.scan(state_step, S0, (A_decay, B_sum))

        q_in = rf * jnp.exp(cl_prev)
        y_inter = jnp.einsum("jbchn,jbhnm->jbchm", q_in, S_in)
        expo = cl_prev[:, :, :, None] - cl[:, :, None]       # (nC,B,t,s,H,N)
        mask_lt = jnp.tril(jnp.ones((C, C), bool), -1)
        expo = jnp.where(mask_lt[None, None, :, :, None, None], expo, -jnp.inf)
        A = jnp.einsum("jbthn,jbshn,jbtshn->jbths", rf, kf, jnp.exp(expo))
        diag = jnp.einsum("jbthn,hn,jbthn->jbth", rf, u, kf)
        ys = y_inter + jnp.einsum("jbths,jbshm->jbthm", A, vf) \
            + diag[..., None] * vf
    else:
        S_fin, ys = jax.lax.scan(jax.checkpoint(chunk_step), S0,
                                 (rc, kc, vc, wc))
    y = ys.swapaxes(0, 1).reshape(B, T, H, N)
    out = _group_norm(y, p["ln_w"], H).astype(x.dtype) * g
    out = out @ p["wo"]
    return out, S_fin


def rwkv_time_mix_step(p: dict, x: jax.Array, x_prev: jax.Array,
                       cfg: ModelConfig, state: jax.Array):
    """Single-token decode. x: (B,1,d); state: (B,H,N,N)."""
    B, _, d = x.shape
    H, N = cfg.n_heads, cfg.d_head
    dx = x_prev - x
    mix = lambda i: x + dx * p["mu"][i]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ p["wr"]).reshape(B, H, N).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, H, N).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, H, N).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(_decay(p, xw).reshape(B, H, N))
    kv = k[..., None] * v[..., None, :]                      # (B,H,N,N)
    y = jnp.einsum("bhn,bhnm->bhm", r, state + p["u"][..., None] * kv)
    S_new = w[..., None] * state + kv
    out = _group_norm(y[:, None], p["ln_w"], H).astype(x.dtype) * g
    return out @ p["wo"], S_new


def rwkv_channel_mix(p: dict, x: jax.Array, x_prev: jax.Array) -> jax.Array:
    dx = x_prev - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])


def token_shift(x: jax.Array, carry: jax.Array):
    """x: (B,T,d), carry: (B,d) last token of previous segment.

    Returns (x_prev, new_carry).
    """
    x_prev = jnp.concatenate([carry[:, None], x[:, :-1]], axis=1)
    return x_prev, x[:, -1]
