"""Shared layers: norms, MLPs, rotary embeddings, embedding/head, losses."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamSpec


# ---------------------------------------------------------------- norms

def rmsnorm_spec(d: int, dtype) -> ParamSpec:
    return ParamSpec((d,), dtype, P(None), init="ones")


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


# ---------------------------------------------------------------- MLPs

def glu_mlp_specs(d: int, ff: int, dtype) -> dict:
    return {
        "w_gate": ParamSpec((d, ff), dtype, P("fsdp", "tp")),
        "w_up": ParamSpec((d, ff), dtype, P("fsdp", "tp")),
        "w_down": ParamSpec((ff, d), dtype, P("tp", "fsdp")),
    }


def mlp2_specs(d: int, ff: int, dtype) -> dict:
    return {
        "w1": ParamSpec((d, ff), dtype, P("fsdp", "tp")),
        "w2": ParamSpec((ff, d), dtype, P("tp", "fsdp")),
    }


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def glu_mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    h = _act(act)(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def mlp2(p: dict, x: jax.Array, act: str) -> jax.Array:
    return _act(act)(x @ p["w1"]) @ p["w2"]


# ---------------------------------------------------------------- rotary

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions broadcastable to (..., S)."""
    d = x.shape[-1]
    d2 = d // 2
    freqs = theta ** (-jnp.arange(0, d2, dtype=jnp.float32) / d2)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :d2], x[..., d2:2 * d2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.concatenate([xr1, xr2], axis=-1)
    if 2 * d2 != d:                                             # odd head dim (kimi 112 is even; guard anyway)
        out = jnp.concatenate([out, x[..., 2 * d2:]], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embed / head

def embedding_specs(vocab: int, d: int, dtype, tie: bool) -> dict:
    out = {"table": ParamSpec((vocab, d), dtype, P("tp", "fsdp"),
                              init="normal", scale=0.02)}
    if not tie:
        out["head"] = ParamSpec((d, vocab), dtype, P("fsdp", "tp"),
                                init="normal", scale=0.02)
    return out


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def logits_fn(p: dict, x: jax.Array, softcap: Optional[float]) -> jax.Array:
    head = p["head"] if "head" in p else p["table"].T
    logits = x @ head
    if softcap is not None:
        logits = softcap * jnp.tanh(logits.astype(jnp.float32) / softcap)
    return logits


# ---------------------------------------------------------------- loss

def chunked_xent(embed_params: dict, x: jax.Array, labels: jax.Array,
                 softcap: Optional[float], n_chunks: int = 8,
                 unroll: bool = False):
    """Cross-entropy without materializing full (B,S,V) logits.

    Scans over sequence chunks; the (B,chunk,V) logits live only inside one
    scan step (and are rematerialized on backward).
    """
    B, S, D = x.shape
    while S % n_chunks != 0:
        n_chunks -= 1
    xc = x.reshape(B, n_chunks, S // n_chunks, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)

    def step(carry, xl):
        xi, li = xl
        logits = logits_fn(embed_params, xi, softcap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            (xc, lc), unroll=unroll)
    return total / (B * S)
