"""Parameter-spec trees: one definition drives init, abstract eval and sharding.

A model's parameter structure is a pytree whose leaves are ``ParamSpec``:
shape + dtype + PartitionSpec + initializer. From that single tree we derive

  * ``abstract(tree)``      -> ShapeDtypeStruct tree (dry-run, no allocation)
  * ``pspecs(tree)``        -> PartitionSpec tree (pjit in_shardings)
  * ``materialize(tree, k)`` -> real arrays (smoke tests / real training)

Sharding conventions (see DESIGN.md §5): weight matrices are sharded
FSDP-style on their d_model-sized dimension over the ``data`` axis and
tensor-parallel on their hidden/head/vocab dimension over the ``model`` axis.
Optimizer state inherits parameter shardings, which is what makes the ZeRO
memory behavior fall out of pure annotations.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Logical axis names used in specs; resolved to mesh axes by `logical_to_mesh`.
# "fsdp"  -> the data axis (param sharding over data; batch also uses data)
# "tp"    -> the model axis
LOGICAL_RULES_SINGLE = {"fsdp": "data", "tp": "model", "batch": ("data",)}


def logical_to_mesh(spec: P, mesh_axes: Tuple[str, ...]) -> P:
    """Resolve logical names to the mesh's axes.

    On the multi-pod mesh the batch shards over (pod, data) and fsdp stays on
    data (pods replicate params; pure DP across the DCN-connected pod axis).
    """
    multi_pod = "pod" in mesh_axes
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif entry == "batch":
            out.append(("pod", "data") if multi_pod else "data")
        elif entry == "fsdp":
            out.append("data")
        elif entry == "tp":
            out.append("model")
        else:
            out.append(entry)
    return P(*out)


def resolve_spec(spec: P, shape, mesh) -> P:
    """Resolve logical names and drop axes that do not divide the dim.

    E.g. GQA with n_kv_heads=8 on a model=16 axis falls back to replicating
    the KV-head dimension (the standard TP>kv_heads behavior).
    """
    resolved = logical_to_mesh(spec, mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, dim in enumerate(shape):
        entry = resolved[i] if i < len(resolved) else None
        if entry is None:
            out.append(None)
            continue
        axes = list(entry) if isinstance(entry, tuple) else [entry]
        while axes:
            total = 1
            for a in axes:
                total *= sizes[a]
            if dim % total == 0:
                break
            axes.pop()              # drop innermost axis and retry
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    pspec: P = P()
    init: str = "normal"        # normal | zeros | ones | uniform_pm (+- scale)
    scale: Optional[float] = None   # None -> 1/sqrt(fan_in)

    def fan_in(self) -> int:
        if len(self.shape) <= 1:
            return max(self.shape[-1] if self.shape else 1, 1)
        return self.shape[-2]


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(f: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_spec)


def abstract(tree, mesh=None):
    """ShapeDtypeStruct tree; attaches NamedSharding when a mesh is given."""
    def mk(s: ParamSpec):
        if mesh is not None:
            sh = jax.sharding.NamedSharding(
                mesh, resolve_spec(s.pspec, s.shape, mesh))
            return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
        return jax.ShapeDtypeStruct(s.shape, s.dtype)
    return tree_map_specs(mk, tree)


def pspecs(tree, mesh_axes: Tuple[str, ...] = ("data", "model")):
    return tree_map_specs(lambda s: logical_to_mesh(s.pspec, mesh_axes), tree)


def shardings(tree, mesh):
    return tree_map_specs(
        lambda s: jax.sharding.NamedSharding(
            mesh, resolve_spec(s.pspec, s.shape, mesh)), tree)


def materialize(tree, key: jax.Array):
    """Allocate real parameters (used for smoke tests and real training)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        scale = s.scale if s.scale is not None else s.fan_in() ** -0.5
        if s.init == "uniform_pm":
            return jax.random.uniform(k, s.shape, jnp.float32, -scale, scale).astype(s.dtype)
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(s.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def count_params(tree) -> int:
    import numpy as np
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def param_bytes(tree) -> int:
    import numpy as np
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                   for l in leaves))
