"""Attention: GQA projections, chunked flash attention, paged-KV decode.

Three execution paths, all pure JAX (Pallas kernels in repro.kernels are the
TPU-target equivalents, selected via ``cfg.use_pallas`` on real hardware):

  * ``flash_attention`` — memory-efficient chunked online-softmax attention
    (train / prefill). Scans over KV chunks carrying (m, l, acc).
  * ``paged_decode_attention`` — single-token decode over a *paged* KV pool
    with block-table indirection: the paper's technique. The gather through
    the block table is the IOVA translation; in the Pallas kernel
    (kernels/paged_attention) the table is scalar-prefetched to SMEM, the
    analogue of the paper's PTW-in-LLC.
  * ``sp_decode_attention`` — sequence-parallel decode (long_500k): KV pages
    sharded over the data axis, flash-decoding-style (m, l, acc) merge via
    psum — page placement is sequence-affine (shard i owns logical pages
    [i*P/n, (i+1)*P/n)), so translation stays shard-local, mirroring the
    paper's requirement that DMA bursts never cross the translation cache.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.dist import shard_map
from repro.models.params import ParamSpec


# ------------------------------------------------------------ projections

def attention_specs(cfg, cross: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    out = {
        "wq": ParamSpec((d, hq, dh), dt, P("fsdp", "tp", None)),
        "wk": ParamSpec((d, hkv, dh), dt, P("fsdp", "tp", None)),
        "wv": ParamSpec((d, hkv, dh), dt, P("fsdp", "tp", None)),
        "wo": ParamSpec((hq, dh, d), dt, P("tp", None, "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        out["bq"] = ParamSpec((hq, dh), dt, P("tp", None), init="zeros")
        out["bk"] = ParamSpec((hkv, dh), dt, P("tp", None), init="zeros")
        out["bv"] = ParamSpec((hkv, dh), dt, P("tp", None), init="zeros")
    if cross:
        out["gate"] = ParamSpec((), dt, P(), init="zeros")  # llama-vision gated x-attn
    return out


def qkv_proj(p: dict, x: jax.Array, kv_x: Optional[jax.Array] = None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def out_proj(p: dict, o: jax.Array) -> jax.Array:
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(y.dtype) * y
    return y


# ------------------------------------------------------------ flash attention

def _softcap(s: jax.Array, cap: Optional[float]) -> jax.Array:
    return cap * jnp.tanh(s / cap) if cap is not None else s


def _chunk_of(total: int, want: int) -> int:
    """Largest divisor of ``total`` that is <= want."""
    c = min(want, total)
    while total % c != 0:
        c -= 1
    return c


def _block_mask(q_pos, kv_pos, causal, window):
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    return mask


@functools.lru_cache(maxsize=64)
def _flash_core(causal: bool, window, softcap, C: int, QC: int, unroll: bool):
    """custom_vjp flash attention core for fixed static config.

    Saves only (q, k, v, out, lse) — the backward recomputes score blocks
    (FlashAttention-style), so live memory is one (QC, C) block per step
    instead of every block's residuals.
    """
    scale_of = lambda D: D ** -0.5

    def fwd_blocks(q, k, v, q_offset):
        B, Sq, H, D = q.shape
        Skv = k.shape[1]
        nq, nkv = Sq // QC, Skv // C
        scale = scale_of(D)
        kc = k.reshape(B, nkv, C, H, D).swapaxes(0, 1)
        vc = v.reshape(B, nkv, C, H, D).swapaxes(0, 1)
        qc = q.reshape(B, nq, QC, H, D).swapaxes(0, 1)

        def q_step(_, inp):
            qi, i = inp
            q_pos = i * QC + jnp.arange(QC) + q_offset

            def kv_step(carry, kv_inp):
                m, l, acc = carry
                kj, vj, j = kv_inp
                kv_pos = j * C + jnp.arange(C)
                s = jnp.einsum("bqhd,bchd->bqhc", qi, kj,
                               preferred_element_type=jnp.float32) * scale
                s = _softcap(s, softcap)
                mask = _block_mask(q_pos, kv_pos, causal, window)
                s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
                p_ = jnp.exp(s - m_safe[..., None])
                corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
                l_new = l * corr + jnp.sum(p_, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bqhc,bchd->bqhd", p_.astype(vj.dtype), vj,
                    preferred_element_type=jnp.float32)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((B, QC, H), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, QC, H), jnp.float32)
            a0 = jnp.zeros((B, QC, H, D), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), (kc, vc, jnp.arange(nkv)),
                unroll=unroll)
            out = (acc / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)
            m_s = jnp.where(jnp.isfinite(m), m, 0.0)
            lse = m_s + jnp.log(jnp.maximum(l, 1e-20))      # (B,QC,H)
            return 0, (out, lse)

        _, (outs, lses) = jax.lax.scan(q_step, 0, (qc, jnp.arange(nq)),
                                       unroll=unroll)
        out = outs.swapaxes(0, 1).reshape(B, Sq, H, D)
        lse = lses.swapaxes(0, 1).reshape(B, Sq, H)
        return out, lse

    @jax.custom_vjp
    def core(q, k, v, q_offset):
        out, _ = fwd_blocks(q, k, v, q_offset)
        return out

    def core_fwd(q, k, v, q_offset):
        out, lse = fwd_blocks(q, k, v, q_offset)
        return out, (q, k, v, out, lse, q_offset)

    def core_bwd(res, do):
        q, k, v, out, lse, q_offset = res
        B, Sq, H, D = q.shape
        Skv = k.shape[1]
        nq, nkv = Sq // QC, Skv // C
        scale = scale_of(D)
        delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                        axis=-1)                             # (B,Sq,H)
        qc = q.reshape(B, nq, QC, H, D).swapaxes(0, 1)
        doc = do.reshape(B, nq, QC, H, D).swapaxes(0, 1)
        lsec = lse.reshape(B, nq, QC, H).swapaxes(0, 1)
        dc = delta.reshape(B, nq, QC, H).swapaxes(0, 1)
        kc = k.reshape(B, nkv, C, H, D).swapaxes(0, 1)
        vc = v.reshape(B, nkv, C, H, D).swapaxes(0, 1)

        def q_step(carry, inp):
            dk, dv = carry                                   # fp32 (nkv,B,C,H,D)
            qi, doi, lsei, di, i = inp
            q_pos = i * QC + jnp.arange(QC) + q_offset

            def kv_step(dkv, kv_inp):
                dkj, dvj, kj, vj, j = kv_inp
                kv_pos = j * C + jnp.arange(C)
                s_raw = jnp.einsum("bqhd,bchd->bqhc", qi, kj,
                                   preferred_element_type=jnp.float32) * scale
                s = _softcap(s_raw, softcap)
                mask = _block_mask(q_pos, kv_pos, causal, window)
                s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
                p_ = jnp.exp(s - lsei[..., None])            # (B,QC,H,C)
                dp = jnp.einsum("bqhd,bchd->bqhc", doi.astype(jnp.float32),
                                vj.astype(jnp.float32))
                ds = p_ * (dp - di[..., None])
                if softcap is not None:
                    ds = ds * (1.0 - jnp.square(jnp.tanh(s_raw / softcap)))
                ds = jnp.where(mask[None, :, None, :], ds, 0.0) * scale
                dq_i = jnp.einsum("bqhc,bchd->bqhd", ds,
                                  kj.astype(jnp.float32))
                dk_j = dkj + jnp.einsum("bqhc,bqhd->bchd", ds,
                                        qi.astype(jnp.float32))
                dv_j = dvj + jnp.einsum("bqhc,bqhd->bchd", p_,
                                        doi.astype(jnp.float32))
                return dq_i, (dk_j, dv_j)

            def kv_scan(dq_acc, kv_inp):
                dq_i, dkv_j = kv_step(None, kv_inp)
                return dq_acc + dq_i, dkv_j

            dq0 = jnp.zeros((B, QC, H, D), jnp.float32)
            dq_i, (dk, dv) = jax.lax.scan(
                kv_scan, dq0, (dk, dv, kc, vc, jnp.arange(nkv)),
                unroll=unroll)
            return (dk, dv), dq_i

        dk0 = jnp.zeros((nkv, B, C, H, D), jnp.float32)
        dv0 = jnp.zeros((nkv, B, C, H, D), jnp.float32)
        (dk, dv), dqs = jax.lax.scan(
            q_step, (dk0, dv0), (qc, doc, lsec, dc, jnp.arange(nq)),
            unroll=unroll)
        dq = dqs.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)
        dk = dk.swapaxes(0, 1).reshape(B, Skv, H, D).astype(k.dtype)
        dv = dv.swapaxes(0, 1).reshape(B, Skv, H, D).astype(v.dtype)
        return dq, dk, dv, None

    core.defvjp(core_fwd, core_bwd)
    return core


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    q_offset: int | jax.Array = 0,
                    kv_chunk: int = 1024,
                    q_chunk: int = 512,
                    unroll: bool = False) -> jax.Array:
    """Double-chunked flash attention with GQA and a flash backward.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D).
    Outer scan over q chunks, inner over kv chunks: live score block is
    (B, q_chunk, Hq, kv_chunk) fp32. K/V are repeated to the full Hq so head
    sharding propagates under TP (GQA head counts rarely divide the mesh);
    jnp.repeat's transpose sums group gradients back to the KV heads.
    The backward is a custom VJP saving only (q, k, v, out, lse).

    NOTE (roofline): causal masking is applied but masked blocks are still
    computed — the pure-JAX path pays ~2x attention FLOPs on causal shapes;
    the Pallas flash kernel (kernels/flash_attention) skips them on TPU.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    C = _chunk_of(Skv, kv_chunk)
    QC = _chunk_of(Sq, q_chunk)
    core = _flash_core(bool(causal), window, softcap, C, QC, bool(unroll))
    q_offset = jnp.asarray(q_offset, jnp.int32)
    return core(q, k, v, q_offset)


# ------------------------------------------------------------ paged KV cache

class PagedKV(NamedTuple):
    """Paged KV pool + block table (the SVA structures). Two layouts:

    per-slot (dry-run / staging-copy baseline):
      k_pool / v_pool: (B, n_pages, page, Hkv, D) — each batch slot owns a
                       private row of physical pages.
      block_table:     (B, n_pages) int32, a permutation of [0, n_pages).

    global (zero-copy serving): ONE physical pool shared by all slots —
      k_pool / v_pool: (total_pages, page, Hkv, D)
      block_table:     (B, max_pages) int32 into the global pool; entries
                       >= total_pages are the NULL page (writes dropped,
                       reads zero) marking unallocated table slots.

    length: () or (B,) int32 — tokens currently valid per sequence.
    The layouts are statically distinguishable by rank (see
    ``is_global_layout``), so one jitted step handles either.
    """
    k_pool: jax.Array
    v_pool: jax.Array
    block_table: jax.Array
    length: jax.Array

    @property
    def page_size(self) -> int:
        return self.k_pool.shape[-3]

    @property
    def capacity(self) -> int:
        """Per-sequence token capacity."""
        if is_global_layout(self):
            return self.block_table.shape[-1] * self.page_size
        return self.k_pool.shape[1] * self.k_pool.shape[2]


def is_global_layout(kv: PagedKV) -> bool:
    """True for the shared-global-pool layout (see PagedKV docstring).

    Rank-based and therefore robust to a leading stacked-blocks axis:
    per-slot pools carry (pages, page, H, D) behind the table's (B, P) dims
    (+3 ranks), a global pool carries (total, page, H, D) beside a (B, P)
    table (+2 ranks).
    """
    return kv.k_pool.ndim == kv.block_table.ndim + 2


def paged_kv_specs(cfg, batch: int, max_len: int, page_size: int,
                   n_kv_layers: int, stack: Optional[int] = None):
    """ShapeDtypeStruct-compatible ParamSpecs for a paged cache.

    ``stack``: leading (n_blocks,) axis when layers are scanned.
    """
    n_pages = -(-max_len // page_size)
    lead = (stack,) if stack else ()
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    dt = jnp.dtype(cfg.activation_dtype)
    pool = lambda: ParamSpec(lead + (n_kv_layers, batch, n_pages, page_size, hkv, dh),
                             dt, P(*([None] * len(lead)), None, "batch", None, None, "tp", None))
    return PagedKV(
        k_pool=pool(), v_pool=pool(),
        block_table=ParamSpec(lead + (n_kv_layers, batch, n_pages), jnp.int32,
                              P(*([None] * len(lead)), None, "batch", None)),
        length=ParamSpec((), jnp.int32, P()),
    )


def gather_global_pages(kv: PagedKV):
    """Logical (B, P, page, Hkv, D) view of a GLOBAL shared pool through the
    per-slot block table — the IOVA translation for the shared-pool layout.
    NULL entries (>= total pages: unallocated table slots) read as exact
    zeros, matching a freshly zero-initialized per-slot pool bit-for-bit."""
    total = kv.k_pool.shape[0]
    tbl = kv.block_table
    null = (tbl >= total)[..., None, None, None]
    safe = jnp.where(tbl >= total, 0, tbl)
    k = jnp.where(null, 0, kv.k_pool[safe]).astype(kv.k_pool.dtype)
    v = jnp.where(null, 0, kv.v_pool[safe]).astype(kv.v_pool.dtype)
    return k, v


def gather_pages(pool: jax.Array, table: jax.Array) -> jax.Array:
    """(B, n_pages, page, H, D) gathered through (B, n_pages) -> (B, S, H, D).

    This gather is the IOVA translation step of the paper: every access to the
    paged pool goes through the block table.
    """
    B, n_pages, page, H, D = pool.shape
    g = jnp.take_along_axis(pool, table[:, :, None, None, None], axis=1)
    return g.reshape(B, n_pages * page, H, D)


def paged_decode_attention(q: jax.Array, kv: PagedKV, *,
                           softcap: Optional[float] = None,
                           backend: str = "jax") -> jax.Array:
    """One-token decode over the paged pool. q: (B, 1, Hq, D).

    ``backend="pallas"`` routes the step through the scalar-prefetch Pallas
    kernel (kernels/paged_attention): the block table lives in SMEM and
    drives the KV page DMAs directly — the paper's PTW-in-LLC realized on
    the serving hot path (interpret-mode off-TPU, compiled kernel on TPU).
    Both PagedKV layouts are supported; rare shapes the kernel does not
    cover (leading stacked-blocks axis outside a scan) fall back to the
    pure-JAX path.

    Sliding-window layers use a pool whose capacity equals the window; the
    rolling write in ``paged_append`` makes every slot valid once
    length >= capacity (attention is permutation-invariant over the KV set,
    and RoPE is applied at write time, so slot order does not matter).

    SHARDING (perf iteration 1): the (pages, page) dims are NEVER merged —
    a reshape merging an unsharded-major with a sharded-minor dim cannot
    keep the sharding and forced XLA to all-gather the whole pool (measured
    ~1 GiB/link per block on decode_32k). All einsums/reductions run on the
    2-D page layout; the softmax reduction psums across the sharded dim.

    ZERO-COPY (perf iteration 2): attention is permutation-invariant over
    the KV set, so we attend over the pool in PHYSICAL order and translate
    only the METADATA — per-page logical positions through the inverse
    block table (B x n_pages ints) — instead of gathering the pool data
    itself. This removes a full pool copy per layer per step (the paper's
    map-don't-copy insight applied to the kernel's own data movement).
    """
    B, _, Hq, D = q.shape
    if backend == "pallas" and kv.block_table.ndim == 2:
        from repro.kernels.paged_attention.ops import paged_decode
        lengths = jnp.broadcast_to(kv.length, (B,)).astype(jnp.int32)
        interpret = jax.default_backend() != "tpu"
        out = paged_decode(q[:, 0], kv.k_pool, kv.v_pool,
                           kv.block_table.astype(jnp.int32), lengths,
                           softcap=softcap, interpret=interpret)
        return out[:, None].astype(q.dtype)
    if is_global_layout(kv):
        # GLOBAL POOL: each sequence sees its pages in LOGICAL order through
        # its table row — the gather IS the IOVA translation. NULL entries
        # (unallocated) read as exact zeros, matching a freshly
        # zero-initialized per-slot pool bit-for-bit.
        T = kv.page_size
        P_ = kv.block_table.shape[1]
        k, v = gather_global_pages(kv)
        pos = (jnp.arange(P_)[:, None] * T
               + jnp.arange(T)[None, :])[None]             # logical (1,P,T)
        pos = jnp.broadcast_to(pos, (B, P_, T))
    else:
        k, v = kv.k_pool, kv.v_pool                        # physical order
        P_, T = k.shape[1], k.shape[2]
        inv = jnp.argsort(kv.block_table, axis=1)          # phys -> logical
        pos = inv[:, :, None] * T + jnp.arange(T)[None, None, :]   # (B,P,T)
    Hkv = k.shape[3]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bpthd->bhgpt", qg, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    s = _softcap(s, softcap)
    valid = pos < jnp.minimum(
        jnp.broadcast_to(kv.length, (B,))[:, None, None], kv.capacity)
    # Mask BEFORE the softmax max (like the Pallas kernel and
    # prefix_context_attention): an invalid slot must not contribute to
    # ``m``, or stale KV in a recycled page perturbs every valid
    # probability at the ULP level — outputs would depend on what a
    # page's PREVIOUS owner wrote (preempt/release recycling breaks
    # bit-identity even though the masked sum is mathematically the same).
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=(-2, -1), keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p_ = jnp.exp(s - m)
    p_ = jnp.where(valid[:, None, None], p_, 0.0)
    l = jnp.sum(p_, axis=(-2, -1), keepdims=True)
    o = jnp.einsum("bhgpt,bpthd->bhgd", p_.astype(jnp.float32),
                   v.astype(jnp.float32))
    o = o / jnp.maximum(l[..., 0], 1e-20)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def prefix_context_attention(q: jax.Array, k_suf: jax.Array, v_suf: jax.Array,
                             kv: PagedKV, prefix_lens: jax.Array,
                             suffix_lens: jax.Array, *,
                             softcap: Optional[float] = None) -> jax.Array:
    """Suffix-only prefill attention with a CACHED prefix (CoW prefix
    sharing): row b's queries are the ``suffix_lens[b]`` right-padded tokens
    at logical positions ``prefix_lens[b] + s``; keys/values are the union
    of (a) the prefix KV already resident in the GLOBAL paged pool — read
    through the row's block table, exactly the pages ``admit`` mapped via
    refcount++ — and (b) the suffix K/V computed by this very call.

    q/k_suf/v_suf: (B, S, H*, D); kv: global-layout PagedKV whose pool holds
    the shared prefix pages. Returns (B, S, Hq, D). Dense (one (S, P*T+S)
    score block in fp32): admission-path work where S is a padded suffix —
    tokens the prefix cache just SAVED from this matmul dwarf its cost.
    """
    assert is_global_layout(kv), "prefix continuation needs the global pool"
    B, S, Hq, D = q.shape
    T = kv.page_size
    P_ = kv.block_table.shape[1]
    k_pre, v_pre = gather_global_pages(kv)
    k_pre = k_pre.reshape(B, P_ * T, -1, D)
    v_pre = v_pre.reshape(B, P_ * T, -1, D)
    Hkv = k_pre.shape[2]
    G = Hq // Hkv
    if G > 1:
        k_pre = jnp.repeat(k_pre, G, axis=2)
        v_pre = jnp.repeat(v_pre, G, axis=2)
        k_suf = jnp.repeat(k_suf, G, axis=2)
        v_suf = jnp.repeat(v_suf, G, axis=2)
    k = jnp.concatenate([k_pre, k_suf.astype(k_pre.dtype)], axis=1)
    v = jnp.concatenate([v_pre, v_suf.astype(v_pre.dtype)], axis=1)
    # kv-position mask: prefix slot j is valid iff j < prefix_len (every
    # valid prefix position precedes every query); suffix slot s at
    # position prefix+s obeys the causal triangle and the real-token mask.
    pre_valid = jnp.arange(P_ * T)[None] < prefix_lens[:, None]   # (B, P*T)
    sidx = jnp.arange(S)
    suf_valid = (sidx[None, :] < suffix_lens[:, None])            # (B, S)
    causal = sidx[None, :] <= sidx[:, None]                       # (S, S)
    mask = jnp.concatenate(
        [jnp.broadcast_to(pre_valid[:, None], (B, S, P_ * T)),
         suf_valid[:, None] & causal[None]], axis=-1)             # (B,S,P*T+S)
    s = jnp.einsum("bshd,bthd->bsht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    s = _softcap(s, softcap)
    s = jnp.where(mask[:, :, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p_ = jnp.exp(s - m)
    p_ = jnp.where(mask[:, :, None, :], p_, 0.0)
    l = jnp.sum(p_, axis=-1, keepdims=True)
    o = jnp.einsum("bsht,bthd->bshd", p_, v.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-20)
    return o.astype(q.dtype)


def paged_append(kv: PagedKV, k_new: jax.Array, v_new: jax.Array) -> PagedKV:
    """Write one token's K/V at position ``length`` through the block table.

    ``length`` may be scalar (dry-run: uniform) or (B,) (serving engine).
    Writes are rolling modulo pool capacity (sliding-window layers use a
    pool whose capacity equals the window).

    SHARDING (perf iteration 1, EXPERIMENTS.md §Perf): the dynamic write
    index only touches the page axis (axis 1, unsharded) — gather the target
    page, masked-update the slot lane, scatter the page back. A direct
    dynamic_update_slice on the (possibly 'model'-sharded) within-page dim
    made XLA all-gather the whole pool every layer (~1 GiB/link/block on
    decode_32k).
    """
    B = k_new.shape[0]
    page = kv.page_size
    length_b = jnp.broadcast_to(kv.length, (B,)) % kv.capacity
    logical_page = length_b // page
    slot = length_b % page
    phys = jnp.take_along_axis(kv.block_table, logical_page[:, None],
                               axis=1)[:, 0]
    if is_global_layout(kv):
        # One scatter of B tokens into the shared pool; writes through NULL
        # table entries (inactive slots) are out-of-bounds and dropped.
        def write_g(pool, new):
            return pool.at[phys, slot].set(new[:, 0].astype(pool.dtype),
                                           mode="drop")
        return kv._replace(k_pool=write_g(kv.k_pool, k_new),
                           v_pool=write_g(kv.v_pool, v_new),
                           length=kv.length + 1)
    slot_mask = (jnp.arange(page)[None, :] ==
                 slot[:, None])[:, None, :, None, None]    # (B,1,page,1,1)

    def write(pool, new):
        # pool: (B, n_pages, page, H, D); new: (B, 1, H, D).
        # Dynamic index ONLY on the (unsharded) page axis; the sharded
        # within-page dim is covered in full with a static 0 start, so the
        # slice/update partitions without collectives.
        H, D = new.shape[-2], new.shape[-1]
        cur = jax.vmap(lambda pb, pg: jax.lax.dynamic_slice(
            pb, (pg, 0, 0, 0), (1, page, H, D)))(pool, phys)
        upd = jnp.where(slot_mask, new[:, :, None].astype(pool.dtype), cur)
        return jax.vmap(lambda pb, pg, u: jax.lax.dynamic_update_slice(
            pb, u, (pg, 0, 0, 0)))(pool, phys, upd)

    return kv._replace(k_pool=write(kv.k_pool, k_new),
                       v_pool=write(kv.v_pool, v_new),
                       length=kv.length + 1)


# ------------------------------------------------------------ SP decode

def sp_paged_decode(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                    kv: PagedKV, mesh, *,
                    softcap: Optional[float] = None,
                    seq_axis: str = "data"):
    """Sequence-parallel paged decode (long_500k): pages sharded on ``data``.

    Appends the new token's K/V on the owner shard, then each shard attends
    over its local pages (shard-local block table) and partial (m, l, acc)
    are merged with psum — flash-decoding on a pod. Page placement is
    sequence-affine: shard i owns logical pages [i*P/n, (i+1)*P/n), so the
    block-table walk never crosses a shard (paper: bursts never cross the
    translation cache).

    Returns (out (B,1,Hq,D), updated PagedKV).
    """
    B, _, Hq, D = q.shape
    n_shards = mesh.shape[seq_axis]
    n_pages_g = kv.k_pool.shape[1]
    page = kv.page_size
    local_pages = n_pages_g // n_shards
    local_tokens = local_pages * page

    def local_fn(q, kn, vn, kp, vp, tbl, length):
        shard = jax.lax.axis_index(seq_axis)
        # ---- append on the owner shard (rolling modulo pool capacity) ----
        wpos = length % (n_shards * local_tokens)
        owner = (wpos // local_tokens) == shard
        local_pos = wpos % local_tokens
        lpage, slot = local_pos // page, local_pos % page
        phys = jnp.take_along_axis(
            tbl, jnp.broadcast_to(lpage, (B,))[:, None], axis=1)[:, 0] % local_pages

        def write(pool, new):
            upd = jax.vmap(lambda pb, pg, nb: jax.lax.dynamic_update_slice(
                pb, nb[None, None], (pg, slot, 0, 0)))(pool, phys, new[:, 0])
            return jnp.where(owner, upd, pool)
        kp, vp = write(kp, kn), write(vp, vn)
        # ---- local partial attention ----
        k = gather_pages(kp, tbl % local_pages)            # shard-local translation
        v = gather_pages(vp, tbl % local_pages)
        Hkv = k.shape[2]
        G = q.shape[2] // Hkv
        qg = q.reshape(B, Hkv, G, D)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                       preferred_element_type=jnp.float32) * (D ** -0.5)
        s = _softcap(s, softcap)
        pos = shard * local_tokens + jnp.arange(k.shape[1])
        s = jnp.where((pos <= length)[None, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
        p_ = jnp.exp(s - m_safe[..., None])
        p_ = jnp.where(jnp.isfinite(s), p_, 0.0)
        l = jnp.sum(p_, axis=-1)
        acc = jnp.einsum("bhgs,bshd->bhgd", p_.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        # ---- flash-decoding merge across shards ----
        m_g = jax.lax.pmax(m_safe, seq_axis)
        corr = jnp.exp(m_safe - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        acc_g = jax.lax.psum(acc * corr[..., None], seq_axis)
        out = acc_g / jnp.maximum(l_g, 1e-20)[..., None]
        return out.reshape(B, 1, Hq, D).astype(q.dtype), kp, vp

    # Heads are replicated across 'model' inside this shard_map: decode-step
    # attention at B=1 is tiny compute, while the pools (the memory hog)
    # shard over 'data'. GQA head counts rarely divide the model axis.
    pool_spec = P(None, seq_axis, None, None, None)
    head_spec = P(None, None, None, None)
    out, kp, vp = shard_map(
        local_fn, mesh=mesh,
        in_specs=(head_spec, head_spec, head_spec, pool_spec, pool_spec,
                  P(None, seq_axis), P()),
        out_specs=(head_spec, pool_spec, pool_spec),
    )(q, k_new, v_new, kv.k_pool, kv.v_pool, kv.block_table, kv.length)
    return out, kv._replace(k_pool=kp, v_pool=vp, length=kv.length + 1)
