"""Per-layer-kind parameter specs and forward application.

A *block* is one repetition of ``cfg.block_pattern``; blocks are scanned.
``apply_block`` handles the three execution modes:

  train    full sequence, no cache I/O (SSM/RWKV states start at zero)
  prefill  full sequence, writes caches (paged KV pools via the block table)
  decode   one token, reads + updates caches (the paper's paged-SVA path)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import rwkv6 as rwkv
from repro.models.dist import MeshInfo, shard
from repro.models.layers import (glu_mlp, glu_mlp_specs, mlp2, mlp2_specs,
                                 rmsnorm, rmsnorm_spec, rope)
from repro.models.moe import moe_ffn, moe_specs
from repro.models.params import ParamSpec, tree_map_specs

ATTN_KINDS = {"attn_mlp", "attn_mlp_local", "attn_moe", "cross_mlp", "attn"}
MLP_KINDS = {"attn_mlp", "attn_mlp_local", "xattn_mlp", "cross_mlp", "mamba", "attn"}
MOE_KINDS = {"attn_moe", "mamba_moe"}
MAMBA_KINDS = {"mamba", "mamba_moe"}


@dataclass(frozen=True)
class FwdCtx:
    cfg: ModelConfig
    mi: MeshInfo
    mode: str                   # train | prefill | decode
    causal: bool = True
    q_offset: Any = 0           # rope/mask offset of token 0 (decode: cache len)
    cross_x: Optional[jax.Array] = None   # image / encoder embeddings (train, prefill)
    sp: bool = False            # sequence-parallel decode (long_500k)
    seq_lengths: Optional[jax.Array] = None  # (B,) real prompt lengths when a
                                # batched prefill carries right-padded rows
    # CoW prefix sharing (suffix-only prefill): tokens already resident in
    # the shared pool per row, and the scatter tables whose shared entries
    # are NULLed so the prefill write never touches a shared page.
    kv_prefix_lens: Optional[jax.Array] = None   # (B,) int32
    write_tables: Optional[jax.Array] = None     # (B, max_pages) int32


def _mlp_specs(cfg: ModelConfig):
    if cfg.act == "relu":            # seamless-style 2-layer MLP
        return mlp2_specs(cfg.d_model, cfg.d_ff, jnp.dtype(cfg.param_dtype))
    return glu_mlp_specs(cfg.d_model, cfg.d_ff, jnp.dtype(cfg.param_dtype))


def _apply_mlp(p, x, cfg):
    return mlp2(p, x, cfg.act) if "w1" in p else glu_mlp(p, x, cfg.act)


def layer_specs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    s: Dict[str, Any] = {"ln1": rmsnorm_spec(d, dt)}
    if kind in ATTN_KINDS:
        s["attn"] = attn.attention_specs(cfg)
    if kind == "xattn_mlp":
        s["xattn"] = attn.attention_specs(cfg, cross=True)
    if kind == "cross_mlp":
        s["lnx"] = rmsnorm_spec(d, dt)
        s["xattn"] = attn.attention_specs(cfg, cross=True)
    if kind in MAMBA_KINDS:
        s["mamba"] = mam.mamba_specs(cfg)
    if kind == "rwkv":
        s["tm"] = rwkv.rwkv_time_mix_specs(cfg)
        s["ln2"] = rmsnorm_spec(d, dt)
        s["cm"] = rwkv.rwkv_channel_mix_specs(cfg)
        return s
    s["ln2"] = rmsnorm_spec(d, dt)
    if kind in MOE_KINDS:
        s["moe"] = moe_specs(cfg)
    elif kind in MLP_KINDS:
        s["mlp"] = _mlp_specs(cfg)
    return s


def block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {str(i): layer_specs(cfg, kind)
            for i, kind in enumerate(cfg.block_pattern)}


def stack_specs(tree, n: int):
    def st(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + tuple(s.shape), s.dtype,
                         P(*((None,) + tuple(s.pspec))), s.init, s.scale)
    return tree_map_specs(st, tree)


# --------------------------------------------------------------- caches

def layer_cache_specs(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      page_size: int, src_len: int, stack=None,
                      per_seq: bool = False,
                      global_pages: "int | None" = None):
    """Cache spec pytree for one layer of ``kind`` (None if stateless).

    ``global_pages``: when set, full-attention layers use the shared global
    pool layout (one physical pool of that many pages per KV layer, per-slot
    tables into it — the zero-copy serving layout). Sliding-window layers
    keep the per-slot ring layout: their KV is a fixed-size rolling buffer,
    which needs no dynamic paging.
    """
    lead = (stack,) if stack else ()
    ld = (None,) * len(lead)
    dt = jnp.dtype(cfg.activation_dtype)
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    out: Dict[str, Any] = {}
    if kind in ATTN_KINDS:
        eff_len = max_len
        if kind == "attn_mlp_local" and cfg.sliding_window:
            eff_len = min(max_len, cfg.sliding_window)
        n_pages = -(-eff_len // page_size)
        if (global_pages is not None and eff_len == max_len
                and not _sp_mode(cfg, batch, max_len)):
            pool_spec = (P(*ld, None, None, "tp", None)
                         if cfg.n_kv_heads >= 16
                         else P(*ld, None, "tp", None, None))
            pool = lambda: ParamSpec(
                lead + (global_pages, page_size, hkv, dh), dt, pool_spec,
                init="zeros")
            out["kv"] = attn.PagedKV(
                k_pool=pool(), v_pool=pool(),
                block_table=ParamSpec(lead + (batch, n_pages), jnp.int32,
                                      P(*ld, "batch", None), init="zeros"),
                length=ParamSpec(lead + (batch,), jnp.int32,
                                 P(*ld, "batch"), init="zeros"))
        else:
            if _sp_mode(cfg, batch, max_len):
                # long-context decode: pages shard over 'data' (shard_map SP)
                pool_spec = P(*ld, None, "data", None, None, None)
                table_spec = P(*ld, None, "data")
            elif cfg.n_kv_heads >= 16:
                # KV heads divide the model axis: plain head TP
                pool_spec = P(*ld, "batch", None, None, "tp", None)
                table_spec = P(*ld, "batch", None)
            else:
                # GQA heads < model axis: shard the within-page token dim over
                # 'model' instead — block-table gathers stay shard-local and
                # the decode softmax merges partials over 'model'
                # (flash-decoding).
                pool_spec = P(*ld, "batch", None, "tp", None, None)
                table_spec = P(*ld, "batch", None)
            pool = lambda: ParamSpec(
                lead + (batch, n_pages, page_size, hkv, dh),
                dt, pool_spec, init="zeros")
            out["kv"] = attn.PagedKV(
                k_pool=pool(), v_pool=pool(),
                block_table=ParamSpec(lead + (batch, n_pages), jnp.int32,
                                      table_spec, init="zeros"),
                length=ParamSpec(lead + ((batch,) if per_seq else ()),
                                 jnp.int32,
                                 P(*ld, *(("batch",) if per_seq else ())),
                                 init="zeros"))
    if kind in ("xattn_mlp", "cross_mlp"):
        ck = lambda: ParamSpec(lead + (batch, src_len, hkv, dh), dt,
                               P(*ld, "batch", "tp", None, None), init="zeros")
        out["xkv"] = {"k": ck(), "v": ck()}
    if kind in MAMBA_KINDS:
        st = mam.mamba_state_specs(cfg, batch)
        out["ssm"] = tree_map_specs(
            lambda s: ParamSpec(lead + tuple(s.shape), s.dtype,
                                P(*((None,) * len(lead) + tuple(s.pspec))),
                                s.init, s.scale), st)
    if kind == "rwkv":
        st = rwkv.rwkv_state_specs(cfg, batch)
        out["rwkv"] = tree_map_specs(
            lambda s: ParamSpec(lead + tuple(s.shape), s.dtype,
                                P(*((None,) * len(lead) + tuple(s.pspec))),
                                s.init, s.scale), st)
    return out or None


def _sp_mode(cfg: ModelConfig, batch: int, max_len: int) -> bool:
    """Sequence-parallel cache layout when batch can't cover the data axis."""
    return batch == 1 and max_len >= 262144


# --------------------------------------------------------------- forward

def _self_attention(p, x, ctx: FwdCtx, cache, window):
    cfg, mi = ctx.cfg, ctx.mi
    B, S, _ = x.shape
    q, k, v = attn.qkv_proj(p, x)
    # explicit head sharding (q heads over 'model'); without this XLA's SPMD
    # falls back to replicated heads (measured: 4x activation memory).
    q = shard(q, mi, P("batch", None, "tp", None))
    if ctx.mode == "decode":
        pos = jnp.asarray(ctx.q_offset)         # scalar or (B,) lengths
        pos_b = (jnp.full((B,), pos) if pos.ndim == 0 else pos)[:, None]
        q = rope(q, pos_b, cfg.rope_theta)
        k = rope(k, pos_b, cfg.rope_theta)
        kv: attn.PagedKV = cache["kv"]
        if ctx.sp:
            o, kv_new = attn.sp_paged_decode(q, k, v, kv, mi.mesh,
                                             softcap=cfg.attn_softcap)
        else:
            kv_new = attn.paged_append(kv, k, v)
            o = attn.paged_decode_attention(q, kv_new,
                                            softcap=cfg.attn_softcap,
                                            backend=cfg.decode_backend)
        return attn.out_proj(p, o), {**cache, "kv": kv_new}
    if (ctx.kv_prefix_lens is not None and ctx.mode == "prefill"
            and cache is not None and "kv" in cache
            and attn.is_global_layout(cache["kv"])):
        # Suffix-only prefill (CoW prefix sharing): row b's token 0 sits at
        # logical position kv_prefix_lens[b]; the skipped prefix's KV is
        # read back from the shared pool pages instead of being recomputed.
        positions = jnp.arange(S)[None] + ctx.kv_prefix_lens[:, None]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kv: attn.PagedKV = cache["kv"]
        o = attn.prefix_context_attention(
            q, k, v, kv, ctx.kv_prefix_lens,
            jnp.broadcast_to(ctx.seq_lengths, (B,)),
            softcap=cfg.attn_softcap)
        o = shard(o, mi, P("batch", None, "tp", None))
        y = attn.out_proj(p, o)
        return y, {**cache, "kv": _prefill_write_global(kv, k, v, ctx, S)}
    positions = jnp.arange(S)[None] + ctx.q_offset
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    kr, vr = k, v
    if cfg.n_q_per_kv > 1:          # pre-repeat so head TP sharding applies
        kr = jnp.repeat(k, cfg.n_q_per_kv, axis=2)
        vr = jnp.repeat(v, cfg.n_q_per_kv, axis=2)
    kr = shard(kr, mi, P("batch", None, "tp", None))
    vr = shard(vr, mi, P("batch", None, "tp", None))
    o = attn.flash_attention(q, kr, vr, causal=ctx.causal, window=window,
                             softcap=cfg.attn_softcap,
                             q_chunk=cfg.flash_q_chunk,
                             kv_chunk=cfg.flash_kv_chunk,
                             unroll=cfg.unroll_scans)
    o = shard(o, mi, P("batch", None, "tp", None))
    y = attn.out_proj(p, o)
    if ctx.mode == "prefill" and cache is not None and "kv" in cache:
        kv: attn.PagedKV = cache["kv"]
        if attn.is_global_layout(kv):
            return y, {**cache, "kv": _prefill_write_global(kv, k, v, ctx, S)}
        n_pages, page = kv.k_pool.shape[1], kv.k_pool.shape[2]
        eff = n_pages * page

        def write(pool, kv_seq):
            # Ring alignment: token t lives at slot t % eff, so the decode
            # append (which writes position ``length % eff``) overwrites the
            # OLDEST resident token. Storing the tail at slot 0 instead
            # would desync the ring whenever prompt_len % eff != 0: the
            # append clobbers an in-window token while an out-of-window one
            # stays resident.
            if ctx.seq_lengths is not None:
                # Right-padded batched prefill: per row, keep each
                # sequence's LAST min(len, eff) REAL tokens ring-aligned
                # and zero the rest — slicing the padded tail would store
                # pad-token KV and drop real in-window tokens.
                lens = ctx.seq_lengths
                start = jnp.maximum(lens - eff, 0)[:, None]       # (B, 1)
                i = jnp.arange(eff)[None, :]
                idx = start + (i - start) % eff                   # (B, eff)
                valid = idx < lens[:, None]
                idx = jnp.minimum(idx, max(S - 1, 0))
                seg = jnp.take_along_axis(kv_seq, idx[:, :, None, None],
                                          axis=1)
                seg = jnp.where(valid[:, :, None, None], seg, 0)
            elif eff < S:                     # sliding-window pool: keep tail
                seg = jnp.roll(kv_seq[:, -eff:], (S - eff) % eff, axis=1)
            elif eff > S:                     # pool capacity > prompt: pad
                pad = jnp.zeros((B, eff - S, *kv_seq.shape[2:]), kv_seq.dtype)
                seg = jnp.concatenate([kv_seq, pad], axis=1)
            else:
                seg = kv_seq
            pages = seg.reshape(B, n_pages, page, *seg.shape[2:])
            inv = jnp.argsort(kv.block_table, axis=1)
            return jnp.take_along_axis(pages, inv[:, :, None, None, None], axis=1)
        kv = kv._replace(k_pool=write(kv.k_pool, k), v_pool=write(kv.v_pool, v),
                         length=jnp.full_like(kv.length, min(S, eff)))
        cache = {**cache, "kv": kv}
    return y, cache


def _prefill_write_global(kv: attn.PagedKV, k, v, ctx: FwdCtx, S: int
                          ) -> attn.PagedKV:
    """Scatter a batched prefill's KV through per-sequence block tables into
    the SHARED global pool — the zero-copy admission path: no staging cache,
    no post-hoc slot copy.

    Right-padded positions (>= seq_lengths) are zeroed before the scatter
    and EVERY table entry of each row is written (real KV first, then zero
    pages), so recycled physical pages are scrubbed and a sequence's mapped
    region is bit-identical to a freshly zero-initialized cache. Writes
    through NULL entries are out-of-bounds and dropped.

    Prefix continuation (``ctx.kv_prefix_lens`` set): row b's token s lives
    at logical position ``prefix[b] + s``, and the scatter goes through
    ``ctx.write_tables`` — the row tables with SHARED entries NULLed — so a
    shared page is never written (neither with recomputed KV nor with the
    zero scrub; its content is already exactly right, and other sequences
    still map it). Fresh pages keep the zero-scrub hygiene.
    """
    lens = ctx.seq_lengths
    assert lens is not None, \
        "global-layout prefill requires batch['lengths'] (per-seq prompt lengths)"
    B = k.shape[0]
    page = kv.page_size
    P_ = kv.block_table.shape[-1]
    prefix = ctx.kv_prefix_lens
    if prefix is None:
        scatter_tbl = kv.block_table
        total_len = lens
    else:
        scatter_tbl = jnp.broadcast_to(ctx.write_tables,
                                       kv.block_table.shape).astype(jnp.int32)
        total_len = lens + prefix
    keep = (jnp.arange(S)[None, :] < lens[:, None])[:, :, None, None]

    def write(pool, kv_seq):
        kw = jnp.where(keep, kv_seq, 0).astype(pool.dtype)
        feat = kv_seq.shape[2:]
        if prefix is None:
            pad = P_ * page - S
            if pad > 0:
                kw = jnp.concatenate(
                    [kw, jnp.zeros((B, pad, *feat), pool.dtype)], axis=1)
            elif pad < 0:
                kw = kw[:, :P_ * page]
        else:
            # Re-align each row so token s lands at logical slot
            # prefix[b] + s: gather with a shifted index (out-of-suffix
            # slots -> 0 = the scrub value). Slots belonging to shared
            # pages also read 0 here, but their writes are dropped by the
            # NULLed scatter table.
            sidx = jnp.arange(P_ * page)[None, :] - prefix[:, None]  # (B,P*T)
            valid = (sidx >= 0) & (sidx < lens[:, None])
            gidx = jnp.clip(sidx, 0, max(S - 1, 0))
            kw = jnp.take_along_axis(kw, gidx[:, :, None, None], axis=1)
            kw = jnp.where(valid[:, :, None, None], kw, 0)
        pages = kw.reshape(B, P_, page, *feat)
        return pool.at[scatter_tbl.reshape(-1)].set(
            pages.reshape(B * P_, page, *feat), mode="drop")

    return kv._replace(k_pool=write(kv.k_pool, k),
                       v_pool=write(kv.v_pool, v),
                       length=jnp.broadcast_to(total_len, kv.length.shape)
                       .astype(kv.length.dtype))


def _cross_attention(p, x, ctx: FwdCtx, cache):
    cfg = ctx.cfg
    if ctx.mode == "decode":
        xkv = cache["xkv"]
        k, v = xkv["k"], xkv["v"]
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        o = attn.flash_attention(q, k, v, causal=False,
                                 q_chunk=cfg.flash_q_chunk,
                                 kv_chunk=cfg.flash_kv_chunk,
                                 unroll=cfg.unroll_scans)
        return attn.out_proj(p, o), cache
    q, k, v = attn.qkv_proj(p, x, kv_x=ctx.cross_x.astype(x.dtype))
    o = attn.flash_attention(q, k, v, causal=False,
                             q_chunk=cfg.flash_q_chunk,
                             kv_chunk=cfg.flash_kv_chunk,
                             unroll=cfg.unroll_scans)
    y = attn.out_proj(p, o)
    if ctx.mode == "prefill" and cache is not None and "xkv" in cache:
        cache = {**cache, "xkv": {"k": k, "v": v}}
    return y, cache


def apply_layer(kind: str, p, x, ctx: FwdCtx, cache):
    cfg, mi = ctx.cfg, ctx.mi
    cache = cache if cache is not None else {}
    out_cache = dict(cache)
    window = cfg.sliding_window if kind == "attn_mlp_local" else None

    if kind == "rwkv":
        st: rwkv.RWKVState = cache["rwkv"] if "rwkv" in cache else \
            _zero_rwkv(cfg, x)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        h_prev, new_tm = rwkv.token_shift(h, st.shift_tm)
        if ctx.mode == "decode":
            y, wkv = rwkv.rwkv_time_mix_step(p["tm"], h, h_prev, cfg, st.wkv)
        else:
            y, wkv = rwkv.rwkv_time_mix(p["tm"], h, h_prev, cfg, mi, st.wkv)
        x = x + y
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        h_prev, new_cm = rwkv.token_shift(h, st.shift_cm)
        x = x + rwkv.rwkv_channel_mix(p["cm"], h, h_prev)
        if ctx.mode != "train":
            out_cache["rwkv"] = rwkv.RWKVState(wkv, new_tm, new_cm)
        return x, (out_cache or None)

    if kind in MAMBA_KINDS:
        st: mam.MambaState = cache["ssm"] if "ssm" in cache else \
            _zero_mamba(cfg, x)
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if ctx.mode == "decode":
            y, st_new = mam.mamba_mix_step(p["mamba"], h, cfg, st)
        else:
            y, st_new = mam.mamba_mix(p["mamba"], h, cfg, mi, st)
        x = x + y
        if ctx.mode != "train":
            out_cache["ssm"] = st_new
    elif kind == "xattn_mlp":
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, out_cache = _cross_attention(p["xattn"], h, ctx, out_cache)
        x = x + y
    else:
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, out_cache = _self_attention(p["attn"], h, ctx, out_cache, window)
        x = x + y
        if kind == "cross_mlp":
            h = rmsnorm(x, p["lnx"], cfg.norm_eps)
            y, out_cache = _cross_attention(p["xattn"], h, ctx, out_cache)
            x = x + y

    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind in MOE_KINDS:
        x = x + moe_ffn(p["moe"], h, cfg, mi)
    else:
        x = x + _apply_mlp(p["mlp"], h, cfg)
    return x, (out_cache or None)


def _zero_rwkv(cfg, x):
    B = x.shape[0]
    return rwkv.RWKVState(
        wkv=jnp.zeros((B, cfg.n_heads, cfg.d_head, cfg.d_head), jnp.float32),
        shift_tm=jnp.zeros((B, cfg.d_model), x.dtype),
        shift_cm=jnp.zeros((B, cfg.d_model), x.dtype))


def _zero_mamba(cfg, x):
    B = x.shape[0]
    d_in = cfg.ssm.expand * cfg.d_model
    return mam.MambaState(
        conv=jnp.zeros((B, cfg.ssm.d_conv - 1, d_in), x.dtype),
        ssm=jnp.zeros((B, d_in, cfg.ssm.d_state), jnp.float32))


def apply_block(p_blk, x, ctx: FwdCtx, cache_blk, pattern=None):
    """One repetition of a block pattern. cache_blk: dict pos->cache|None."""
    out_caches = {}
    pattern = pattern if pattern is not None else ctx.cfg.block_pattern
    for i, kind in enumerate(pattern):
        c_in = None if cache_blk is None else cache_blk.get(str(i))
        x, c_out = apply_layer(kind, p_blk[str(i)], x, ctx, c_in)
        if c_out is not None and cache_blk is not None:
            out_caches[str(i)] = c_out
    # Block-boundary activations shard d_model over 'model' as well: these are
    # the remat-saved tensors, so this is ZeRO-R-style activation partitioning
    # (16x smaller saved stack for one small all-gather per block).
    x = shard(x, ctx.mi, P("batch", None, "tp"))
    return x, (out_caches if cache_blk is not None else None)
