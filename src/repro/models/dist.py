"""Mesh-aware activation sharding helpers usable from model code.

``MeshInfo`` is threaded through the model; ``shard(x, spec)`` applies a
``with_sharding_constraint`` resolving logical names (batch/fsdp/tp) to mesh
axes, and is a no-op when no mesh is active (CPU smoke tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.params import logical_to_mesh, resolve_spec

# jax.shard_map only exists in newer JAX; fall back to the experimental home.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401


@dataclass(frozen=True)
class MeshInfo:
    mesh: Optional[jax.sharding.Mesh] = None

    @property
    def active(self) -> bool:
        return self.mesh is not None

    @property
    def multi_pod(self) -> bool:
        return self.active and "pod" in self.mesh.axis_names

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return self.mesh.axis_names if self.active else ()

    def size(self, axis: str) -> int:
        return self.mesh.shape[axis] if self.active else 1

    @property
    def batch_axes(self):
        return ("pod", "data") if self.multi_pod else ("data",)

    @property
    def all_axes(self):
        return self.mesh.axis_names if self.active else ()


NO_MESH = MeshInfo(None)


def shard(x: jax.Array, mi: MeshInfo, spec: P) -> jax.Array:
    if not mi.active:
        return x
    resolved = resolve_spec(spec, x.shape, mi.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mi.mesh, resolved))
