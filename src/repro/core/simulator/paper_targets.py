"""Ground truth from the paper — single source for calibration & validation.

Table II: total runtime in ACCELERATOR cycles per kernel x DRAM latency
{200,600,1000} x config {baseline, iommu, iommu_llc}; '% DMA' rows for
baseline and the IOMMU overhead percentages.

Note: the published IOMMU+LLC mergesort@200 entry reads "6.96e3" — an
obvious typo for 6.96e6 (it sits between baseline 6.94e6 and 8.00e6@600).
"""

TABLE2 = {
    # kernel: {config: {latency: total_cycles}}
    "gemm": {
        "baseline":  {200: 2.03e6, 600: 2.24e6, 1000: 2.45e6},
        "iommu":     {200: 2.12e6, 600: 2.50e6, 1000: 2.89e6},
        "iommu_llc": {200: 2.04e6, 600: 2.25e6, 1000: 2.47e6},
        "dma_pct":   {200: 7.3, 600: 16.0, 1000: 23.2},
    },
    "gesummv": {
        "baseline":  {200: 4.93e5, 600: 6.38e5, 1000: 9.16e5},
        "iommu":     {200: 5.20e5, 600: 1.08e6, 1000: 1.70e6},
        "iommu_llc": {200: 4.95e5, 600: 6.45e5, 1000: 9.29e5},
        "dma_pct":   {200: 1.4, 600: 23.5, 1000: 46.3},
    },
    "heat3d": {
        "baseline":  {200: 2.00e6, 600: 4.60e6, 1000: 7.21e6},
        "iommu":     {200: 2.84e6, 600: 7.09e6, 1000: 1.13e7},
        "iommu_llc": {200: 2.05e6, 600: 4.68e6, 1000: 7.30e6},
        "dma_pct":   {200: 36.3, 600: 71.9, 1000: 80.8},
    },
    "mergesort": {
        "baseline":  {200: 6.94e6, 600: 7.98e6, 1000: 9.05e6},
        "iommu":     {200: 7.67e6, 600: 1.08e7, 1000: 1.44e7},
        "iommu_llc": {200: 6.96e6, 600: 8.00e6, 1000: 9.07e6},  # 6.96e3 typo
        "dma_pct":   {200: 17.7, 600: 29.2, 1000: 38.3},
    },
}

SIZES = {"gemm": 128, "gesummv": 512, "heat3d": 64, "mergesort": 65536,
         "axpy": 32768}

CLAIMS = {
    # §IV-A / Fig. 2: zero-copy offload vs copy-based offload, axpy@32768
    "zero_copy_speedup_pct": 47.0,
    # Fig. 3: cost growth from 200 -> 1000 cycles DRAM latency
    "copy_time_ratio_1000_200": 3.4,
    "map_time_ratio_1000_200": 2.1,
    # Fig. 5: LLC effect on average PTW time
    "ptw_llc_speedup_x": 15.0,
    "ptw_llc_max_cycles": 200.0,        # host cycles, at L=1000 with LLC
    "ptw_interference_slowdown_pct": 20.0,
    # §IV-B headline numbers
    "gemm_overhead_low_pct": 4.2,       # IOMMU translation cost, low latency
    "gemm_overhead_high_pct": 17.6,     # and at high latency
    "llc_overhead_max_pct": 2.0,        # <2% for all kernels with LLC
}
