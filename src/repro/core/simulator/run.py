"""Simulator entry points: kernel runs (Table II / Fig. 4/5) and the
host-side offload model (Fig. 2/3: host exec, copy-based, zero-copy)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.paper_soc import PaperSoCConfig
from repro.core.simulator.kernels import FITTED, KernelParams, schedule
from repro.core.simulator.platform import (H2A, KernelResult, MemorySystem,
                                           SimConfig, Tile, run_kernel)

CONFIGS = ("baseline", "iommu", "iommu_llc")


def make_sim_config(config: str, dram_latency: int,
                    soc: Optional[PaperSoCConfig] = None,
                    host_interference: float = 0.0,
                    iotlb_policy: str = "lru", iotlb_ways: int = 0,
                    walk_cache_entries: int = 0, walk_cache_ways: int = 0,
                    walk_cache_policy: str = "lru",
                    iotlb_prefetch_policy: str = "none",
                    iotlb_prefetch_degree: int = 2,
                    iotlb_prefetch_distance: int = 4) -> SimConfig:
    soc = soc or PaperSoCConfig()
    return SimConfig(soc=soc, dram_latency=dram_latency,
                     iommu=config in ("iommu", "iommu_llc"),
                     llc=config == "iommu_llc",
                     host_interference=host_interference,
                     iotlb_policy=iotlb_policy, iotlb_ways=iotlb_ways,
                     walk_cache_entries=walk_cache_entries,
                     walk_cache_ways=walk_cache_ways,
                     walk_cache_policy=walk_cache_policy,
                     iotlb_prefetch_policy=iotlb_prefetch_policy,
                     iotlb_prefetch_degree=iotlb_prefetch_degree,
                     iotlb_prefetch_distance=iotlb_prefetch_distance)


def simulate_kernel(kernel: str, config: str, dram_latency: int,
                    params: Optional[KernelParams] = None,
                    host_interference: float = 0.0,
                    iotlb_policy: str = "lru", iotlb_ways: int = 0,
                    walk_cache_entries: int = 0, walk_cache_ways: int = 0,
                    walk_cache_policy: str = "lru",
                    iotlb_prefetch_policy: str = "none",
                    iotlb_prefetch_degree: int = 2,
                    iotlb_prefetch_distance: int = 4) -> KernelResult:
    tiles = schedule(kernel, params)
    cfg = make_sim_config(config, dram_latency,
                          host_interference=host_interference,
                          iotlb_policy=iotlb_policy, iotlb_ways=iotlb_ways,
                          walk_cache_entries=walk_cache_entries,
                          walk_cache_ways=walk_cache_ways,
                          walk_cache_policy=walk_cache_policy,
                          iotlb_prefetch_policy=iotlb_prefetch_policy,
                          iotlb_prefetch_degree=iotlb_prefetch_degree,
                          iotlb_prefetch_distance=iotlb_prefetch_distance)
    return run_kernel(tiles, cfg)


# ------------------------------------------------------------------ Fig 2/3
# Host-side cost models (CVA6 @50 MHz; results in HOST cycles).

@dataclass
class OffloadBreakdown:
    xfer: float        # copy or map time (host cycles)
    offload: float     # OpenMP fork/join + driver round trip
    compute: float     # device (or host) kernel time, converted to host cyc

    @property
    def total(self) -> float:
        return self.xfer + self.offload + self.compute


# CVA6 streaming: the store buffer + critical-word-first refill sustain
# ~2.5 outstanding line transactions (calibrated to Fig. 2/3 jointly).
_HOST_MLP = 2.53
_COPY_FIXED_PER_LINE = 98.3      # loop + store-buffer work per 64 B line
_MAP_PER_PAGE_CACHED = 1386.0    # get_user_pages + pte setup, cache-resident
_MAP_PER_PAGE_MEM = 4.3          # uncached struct-page/pte accesses per page


def host_copy_cycles(n_bytes: float, dram_latency: int,
                     soc: Optional[PaperSoCConfig] = None) -> float:
    """Copy to the reserved physically-contiguous region, one read miss per
    64 B line (destination is uncached). Fig. 3: 3.4x from 200->1000."""
    soc = soc or PaperSoCConfig()
    lines = n_bytes / soc.llc_line_bytes
    per_line = (dram_latency + soc.dram_base_latency
                + _COPY_FIXED_PER_LINE) / _HOST_MLP
    return lines * per_line


def host_map_cycles(n_bytes: float, dram_latency: int,
                    soc: Optional[PaperSoCConfig] = None) -> float:
    """Create IOVA mappings: ioctl + pinning + PTE writes. Most work is
    cache-resident; ~4 accesses/page touch DRAM. Fig. 3: 2.1x growth."""
    soc = soc or PaperSoCConfig()
    pages = -(-n_bytes // soc.page_bytes)
    per_page = _MAP_PER_PAGE_CACHED + _MAP_PER_PAGE_MEM * (
        dram_latency + soc.dram_base_latency)
    return soc.ioctl_overhead_cycles + pages * per_page


def host_axpy_cycles(n_elems: int, dram_latency: int,
                     soc: Optional[PaperSoCConfig] = None) -> float:
    """Single-threaded CVA6 axpy: 3 streamed arrays through the write-through
    D-cache — one miss per line per array, ~2.5 outstanding."""
    soc = soc or PaperSoCConfig()
    lines = 3 * n_elems * 4 / soc.llc_line_bytes
    return lines * (dram_latency + soc.dram_base_latency) / _HOST_MLP \
        + 6.0 * n_elems


def device_axpy_cycles_host(n_elems: int, dram_latency: int, config: str
                            ) -> float:
    """Cluster axpy runtime, converted to host cycles (for Fig. 2 stacking)."""
    res = simulate_kernel("axpy", config, dram_latency)
    return res.total / H2A


def offload_breakdown(mode: str, n_elems: int, dram_latency: int
                      ) -> OffloadBreakdown:
    """Fig. 2's three scenarios for axpy: host | copy | zero_copy."""
    soc = PaperSoCConfig()
    n_bytes = 3 * n_elems * 4            # x, y in; y out counted once staged
    fork_join = 130_000.0                # OpenMP target + mailbox round trip
    if mode == "host":
        return OffloadBreakdown(0.0, 0.0, host_axpy_cycles(n_elems, dram_latency))
    if mode == "copy":
        return OffloadBreakdown(host_copy_cycles(n_bytes, dram_latency),
                                fork_join,
                                device_axpy_cycles_host(n_elems, dram_latency,
                                                        "baseline"))
    if mode == "zero_copy":
        return OffloadBreakdown(host_map_cycles(n_bytes, dram_latency),
                                fork_join,
                                device_axpy_cycles_host(n_elems, dram_latency,
                                                        "iommu_llc"))
    raise ValueError(mode)


def table2_simulated() -> Dict[str, Dict[str, Dict[int, float]]]:
    out: Dict[str, Dict[str, Dict[int, float]]] = {}
    for kernel in ("gemm", "gesummv", "heat3d", "mergesort"):
        out[kernel] = {}
        for config in CONFIGS:
            out[kernel][config] = {}
            for lat in (200, 600, 1000):
                r = simulate_kernel(kernel, config, lat)
                out[kernel][config][lat] = r.total
        out[kernel]["dma_pct"] = {
            lat: simulate_kernel(kernel, "baseline", lat).dma_pct
            for lat in (200, 600, 1000)}
    return out
