"""One-shot calibration of per-kernel schedule constants against Table II.

Coordinate-descent / Nelder-Mead (dependency-free) over KernelParams,
minimizing mean squared relative error across the kernel's 9 Table II cells
(3 configs x 3 latencies) + the 3 baseline DMA%% values (down-weighted).

Run:  PYTHONPATH=src python -m repro.core.simulator.calibrate
then freeze the printed constants into kernels.FITTED.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List

from repro.core.simulator.kernels import FITTED, KernelParams, schedule
from repro.core.simulator.paper_targets import TABLE2
from repro.core.simulator.run import simulate_kernel

LATS = (200, 600, 1000)
FIELDS = ["n_tiles", "compute_per_tile", "heavy_frac", "bursts_heavy",
          "bursts_light", "bytes_total", "pages_unique", "revisit",
          "sync_bursts", "sync_bytes_total", "ptw_hidden_frac"]
INT_FIELDS = {"n_tiles", "pages_unique"}
BOUNDS = {
    "n_tiles": (4, 512), "compute_per_tile": (200.0, 3e5),
    "heavy_frac": (0.05, 1.0), "bursts_heavy": (0.0, 400.0),
    "bursts_light": (0.0, 100.0), "bytes_total": (1e5, 3e7),
    "pages_unique": (8, 4096), "revisit": (1.0, 60.0),
    "sync_bursts": (0.0, 200.0), "sync_bytes_total": (0.0, 3e7),
    "ptw_hidden_frac": (0.0, 1.0),
}


def loss(kernel: str, p: KernelParams) -> float:
    tgt = TABLE2[kernel]
    err = 0.0
    for config in ("baseline", "iommu", "iommu_llc"):
        for lat in LATS:
            sim = simulate_kernel(kernel, config, lat, params=p).total
            err += ((sim - tgt[config][lat]) / tgt[config][lat]) ** 2
    for lat in LATS:  # DMA% (down-weighted; percent-point error scale)
        sim = simulate_kernel(kernel, "baseline", lat, params=p).dma_pct
        err += 0.25 * ((sim - tgt["dma_pct"][lat]) / 100.0) ** 2 * 100
    return err


def _clip(f: str, v):
    lo, hi = BOUNDS[f]
    v = min(max(v, lo), hi)
    return int(round(v)) if f in INT_FIELDS else v


def coordinate_descent(kernel: str, p: KernelParams, iters: int = 30
                       ) -> KernelParams:
    best = loss(kernel, p)
    for it in range(iters):
        improved = False
        for f in FIELDS:
            v0 = getattr(p, f)
            for mult in (0.7, 0.85, 0.95, 1.05, 1.18, 1.4):
                v = _clip(f, v0 * mult if v0 else mult - 0.65)
                q = dataclasses.replace(p, **{f: v})
                l = loss(kernel, q)
                if l < best - 1e-9:
                    best, p, improved = l, q, True
        if not improved:
            break
    return p


def main():
    frozen: Dict[str, KernelParams] = {}
    for kernel in ("gemm", "gesummv", "heat3d", "mergesort"):
        p = coordinate_descent(kernel, FITTED[kernel])
        frozen[kernel] = p
        l = loss(kernel, p)
        print(f"\n{kernel}: loss={l:.5f}")
        print(f'    "{kernel}": {p},')
        tgt = TABLE2[kernel]
        for config in ("baseline", "iommu", "iommu_llc"):
            row = []
            for lat in LATS:
                sim = simulate_kernel(kernel, config, lat, params=p).total
                t = tgt[config][lat]
                row.append(f"{sim:.3g}/{t:.3g} ({100*(sim-t)/t:+.1f}%)")
            print(f"  {config:10s} " + "  ".join(row))
        row = []
        for lat in LATS:
            sim = simulate_kernel(kernel, "baseline", lat, params=p).dma_pct
            row.append(f"{sim:.1f}/{tgt['dma_pct'][lat]:.1f}")
        print(f"  {'dma_pct':10s} " + "  ".join(row))


if __name__ == "__main__":
    main()
