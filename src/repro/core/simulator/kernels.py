"""Tile schedules for the five RajaPERF kernels (§III-B).

Each kernel's double-buffered tiling is expressed as a stream of ``Tile``s:
per-tile compute cycles, DMA bursts, bytes, and the page-reference stream
seen by the IOMMU (page ids in touch order — revisits model the working-set
re-streaming that thrashes the 4-entry IOTLB).

The schedule SHAPES come from the kernels' actual tilings (input tiling +
double buffering into the 128 KiB TCDM, per §III-B); the free constants
(per-tile compute, exposed bursts, page revisit factor) are calibrated once
against Table II's baseline+IOMMU rows (see calibrate.py) and frozen here.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.simulator.platform import Tile

PAGE = 4096


@dataclass
class KernelParams:
    n_tiles: int              # double-buffer phases
    compute_per_tile: float   # accel cycles of PE work per phase
    heavy_frac: float         # fraction of phases with heavy (async) DMA
    bursts_heavy: float       # async DMA bursts in a heavy phase (hideable)
    bursts_light: float       # async DMA bursts in a light phase
    bytes_total: float        # total async bytes moved (in + out)
    pages_unique: int         # distinct data pages touched
    revisit: float            # page-reference stream length / unique pages
    sync_bursts: float = 0.0  # phase-boundary bursts per tile (never hidden)
    sync_bytes_total: float = 0.0
    ptw_hidden_frac: float = 0.0


# Calibrated against Table II (calibrate.py); see EXPERIMENTS.md §Paper-validation.
FITTED: Dict[str, KernelParams] = {
    # gemm-128: 64 K-chunk phases over 32x32 C-blocks; bulk A/B streaming is
    # hidden under the MACs, but ~20 boundary bursts/phase (C writeback +
    # next-chunk kickoff) serialize -> the linear Table II baseline growth.
    # Mean |err| over gemm's 9 Table II cells: 0.4% (max 1.4%).
    "gemm": KernelParams(n_tiles=64, compute_per_tile=29400.0,
                         heavy_frac=0.7, bursts_heavy=8.0,
                         bursts_light=4.7788, bytes_total=1.0e5,
                         pages_unique=115, revisit=3.0,
                         sync_bursts=20.5, sync_bytes_total=4.94e5,
                         ptw_hidden_frac=0.0),
    # gesummv-512: A and B streamed once; DMA crosses compute around L~300
    # (the sharp Table II nonlinearity). Mean |err| 0.8% (max 1.6%).
    "gesummv": KernelParams(n_tiles=23, compute_per_tile=21000.0,
                            heavy_frac=0.735, bursts_heavy=100.0,
                            bursts_light=13.625, bytes_total=1.8005e6,
                            pages_unique=312, revisit=2.205,
                            sync_bursts=0.0, sync_bytes_total=18955.3,
                            ptw_hidden_frac=0.86436),
    # heat3d-64: z-slab halos re-fetched -> highest bandwidth demand, the
    # paper's most DMA-bound kernel. Mean |err| 1.0% (max 2.2%).
    "heat3d": KernelParams(n_tiles=144, compute_per_tile=8954.0,
                           heavy_frac=0.817, bursts_heavy=133.6,
                           bursts_light=7.104, bytes_total=7.3316e6,
                           pages_unique=1189, revisit=3.0,
                           sync_bursts=0.269, sync_bytes_total=0.75,
                           ptw_hidden_frac=1.0),
    # mergesort-64k: ~16 merge passes re-stream the data; two read streams +
    # one write stream alternate pages, so nearly every burst misses the
    # 4-entry IOTLB (the paper's worst IOMMU case, 82.6% @1000).
    # Mean |err| 1.0% (max 2.1%).
    "mergesort": KernelParams(n_tiles=256, compute_per_tile=22300.0,
                              heavy_frac=0.8521, bursts_heavy=1.3554,
                              bursts_light=56.977, bytes_total=1.6995e6,
                              pages_unique=188, revisit=43.05,
                              sync_bursts=26.67, sync_bytes_total=1.178e7,
                              ptw_hidden_frac=0.618),
    "axpy": KernelParams(n_tiles=16, compute_per_tile=1400.0,
                         heavy_frac=1.0, bursts_heavy=24.0, bursts_light=0.0,
                         bytes_total=393216.0, pages_unique=96, revisit=1.0),
}


def schedule(kernel: str, params: KernelParams | None = None) -> List[Tile]:
    p = params or FITTED[kernel]
    n_heavy = round(p.n_tiles * p.heavy_frac)
    total_refs = int(p.pages_unique * p.revisit)
    refs_per_tile = max(total_refs // p.n_tiles, 1)
    # coarsen long reference streams (sim speed); each ref carries a weight
    capped = min(refs_per_tile, 8)
    weight = refs_per_tile / capped
    refs_per_tile = capped
    bytes_per_tile = p.bytes_total / p.n_tiles

    tiles: List[Tile] = []
    ref = 0
    for i in range(p.n_tiles):
        # evenly interleave heavy-DMA phases among light ones
        is_heavy = (i * n_heavy // p.n_tiles) != ((i + 1) * n_heavy // p.n_tiles)
        bursts = p.bursts_heavy if is_heavy else p.bursts_light
        # page-reference stream: sequential unique pages, wrapping to model
        # working-set revisits (B re-streamed per row-block, etc.)
        pages = tuple((ref + j) % p.pages_unique for j in range(refs_per_tile))
        ref += refs_per_tile
        tiles.append(Tile(compute=p.compute_per_tile, bursts=bursts,
                          bytes=bytes_per_tile, pages=pages,
                          sync_bursts=p.sync_bursts,
                          sync_bytes=p.sync_bytes_total / p.n_tiles,
                          ptw_hidden_frac=p.ptw_hidden_frac,
                          walk_weight=weight))
    return tiles
