"""Performance model of the paper's prototype platform (§III-A).

Cycle accounting is done in ACCELERATOR cycles (20 MHz Snitch domain), the
unit of the paper's Table II. Memory-system events happen in the 50 MHz host
domain and are converted with H2A = 20/50.

Components modeled:
  * DRAM with the parametrizable AXI delayer (+L cycles on b/r channels)
  * the 4-entry IOTLB + 3-level sequential PTW (RISC-V IOMMU, Sv39)
  * the 128 KiB shared LLC that caches ONLY host + PTW traffic (DMA bypasses
    via the address-offset muxes of Fig. 1) — modeled as a resident-set of
    PTE cache lines filled by the host mapping pass (paper Listing 1 flushes
    then maps, so PTEs are LLC-resident at offload time)
  * host-interference evictions (Fig. 5's concurrent-traffic experiment)
  * the Snitch cluster double-buffered DMA execution: per tile,
    runtime += max(compute, dma); exposed DMA is the paper's "DMA region".
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.configs.paper_soc import PaperSoCConfig
from repro.core.sva.tlb import TranslationCache

H2A = 20.0 / 50.0     # host-domain cycles -> accelerator cycles


@dataclass(frozen=True)
class SimConfig:
    soc: PaperSoCConfig
    dram_latency: int = 200           # delayer cycles (host domain)
    iommu: bool = False
    llc: bool = False
    host_interference: float = 0.0    # extra PTE-line eviction prob (Fig. 5)
    llc_hit_cycles: int = 10          # host cycles for an LLC hit
    pte_evict_prob: float = 0.10      # baseline leaf-PTE eviction (128 KiB LLC
                                      # shared with OS data between map & use)
    seed: int = 0


@dataclass
class KernelResult:
    total: float
    compute: float
    dma_exposed: float
    walks: float
    iotlb_hits: float
    ptw_cycles: float                 # total accel cycles spent walking
    n_tiles: int

    @property
    def dma_pct(self) -> float:
        return 100.0 * self.dma_exposed / max(self.total, 1e-9)

    @property
    def avg_ptw_host_cycles(self) -> float:
        """Average page-table-walk time in HOST cycles (Fig. 5 units)."""
        if self.walks == 0:
            return 0.0
        return self.ptw_cycles / H2A / self.walks


@dataclass
class Tile:
    compute: float                    # accel cycles of PE work
    bursts: float                     # async DMA bursts (double-buffered, hideable)
    bytes: float                      # async bytes moved for this tile
    sync_bursts: float = 0.0          # phase-boundary bursts (never overlapped)
    sync_bytes: float = 0.0
    pages: Tuple[int, ...] = ()       # page ids touched (IOVA translation)
    ptw_hidden_frac: float = 0.0      # fraction of walk latency on the async path
    walk_weight: float = 1.0          # pages represented per reference (coarsening)


class MemorySystem:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.soc = cfg.soc
        self.rng = np.random.default_rng(cfg.seed)
        self.iotlb = TranslationCache(self.soc.iotlb_entries)
        self.llc_resident: set = set()  # PTE line ids resident in LLC

    # ------------------------------------------------------------ basics
    def dram_access_host(self) -> float:
        return self.cfg.dram_latency + self.soc.dram_base_latency

    def burst_latency(self) -> float:
        """Accel cycles for one DMA burst's exposed latency."""
        return self.dram_access_host() * H2A

    def stream_cycles(self, n_bytes: float) -> float:
        """Pipelined data beats: 8 B per host cycle."""
        return n_bytes / self.soc.dram_bytes_per_cycle * H2A

    # ------------------------------------------------------------ mapping
    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Host creates IO mappings right before offload (Listing 1): the PTE
        cache lines land in the LLC (8 PTEs of 8 B per 64 B line)."""
        if self.cfg.llc:
            for p in set(pages):
                self.llc_resident.add(p // 8)

    # ------------------------------------------------------------ PTW
    def ptw_cost_accel(self, page: int) -> float:
        """One full page-table walk: up to 3 sequential accesses."""
        total_host = 0.0
        evict_p = self.cfg.pte_evict_prob + self.cfg.host_interference
        for level in range(self.soc.ptw_levels):
            line = page // 8 if level == self.soc.ptw_levels - 1 else -level
            cached = self.cfg.llc and (
                line in self.llc_resident or level < self.soc.ptw_levels - 1)
            if cached and level == self.soc.ptw_levels - 1 and \
                    self.rng.random() < evict_p:
                cached = False        # PTE line evicted between map and walk
            total_host += (self.cfg.llc_hit_cycles if cached
                           else self.dram_access_host())
        return total_host * H2A

    def translate(self, page: int) -> Tuple[float, bool]:
        """IOTLB lookup; returns (accel cycles, hit)."""
        _, hit = self.iotlb.lookup(page)
        if hit:
            return 0.0, True
        cost = self.ptw_cost_accel(page)
        self.iotlb.fill(page, page)
        return cost, False


def run_kernel(tiles: List[Tile], cfg: SimConfig,
               prologue_tiles: int = 1) -> KernelResult:
    """Double-buffered execution: total = dma_0 + sum max(c_t, d_t) + c_T."""
    mem = MemorySystem(cfg)
    if cfg.iommu:
        mem.host_map_pass([p for t in tiles for p in t.pages])

    total = 0.0
    compute_total = 0.0
    dma_exposed = 0.0
    walks = hits = 0
    ptw_cycles = 0.0

    def dma_time(tile: Tile) -> Tuple[float, float]:
        """Returns (hideable async DMA, synchronous DMA) for one tile."""
        nonlocal walks, hits, ptw_cycles
        d_async = tile.bursts * mem.burst_latency() \
            + mem.stream_cycles(tile.bytes)
        d_sync = tile.sync_bursts * mem.burst_latency() \
            + mem.stream_cycles(tile.sync_bytes)
        if cfg.iommu:
            w = tile.walk_weight
            for p in tile.pages:
                c, hit = mem.translate(p)
                if hit:
                    hits += w
                else:
                    walks += w
                    ptw_cycles += c * w
                    d_async += c * w * tile.ptw_hidden_frac
                    d_sync += c * w * (1.0 - tile.ptw_hidden_frac)
        return d_async, d_sync

    # prologue: first tile's DMA is never hidden
    da, ds = dma_time(tiles[0])
    total += da + ds
    dma_exposed += da + ds
    for i, tile in enumerate(tiles):
        c = tile.compute
        compute_total += c
        da, ds = dma_time(tiles[i + 1]) if i + 1 < len(tiles) else (0.0, 0.0)
        total += max(c, da) + ds
        dma_exposed += max(0.0, da - c) + ds
    return KernelResult(total=total, compute=compute_total,
                        dma_exposed=dma_exposed, walks=walks,
                        iotlb_hits=hits, ptw_cycles=ptw_cycles,
                        n_tiles=len(tiles))
