"""Performance model of the paper's prototype platform (§III-A).

Cycle accounting is done in ACCELERATOR cycles (20 MHz Snitch domain), the
unit of the paper's Table II. Memory-system events happen in the 50 MHz host
domain and are converted with H2A = 20/50.

Components modeled:
  * DRAM with the parametrizable AXI delayer (+L cycles on b/r channels)
  * translation is delegated ENTIRELY to the unified IOMMU front-end
    (core/sva/iommu.py): the 4-entry IOTLB is ``TLBConfig(4, policy)`` and
    the 3-level sequential PTW (RISC-V IOMMU, Sv39) with its LLC-aware walk
    costs is ``Sv39Walk`` — the 128 KiB shared LLC caches ONLY host + PTW
    traffic (DMA bypasses via the address-offset muxes of Fig. 1), modeled
    as a resident-set of PTE cache lines filled by the host mapping pass
    (paper Listing 1 flushes then maps, so PTEs are LLC-resident at offload
    time), with host-interference evictions (Fig. 5's concurrent-traffic
    experiment)
  * the Snitch cluster double-buffered DMA execution: per tile,
    runtime += max(compute, dma); exposed DMA is the paper's "DMA region".
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.configs.paper_soc import PaperSoCConfig
from repro.core.sva.iommu import (IOMMU, PrefetchConfig, Sv39Walk, TLBConfig,
                                  WalkCacheConfig)
from repro.core.sva.sanitizer import SVASanitizer
from repro.core.sva.sanitizer import resolve as _resolve_sanitize

H2A = 20.0 / 50.0     # host-domain cycles -> accelerator cycles


@dataclass(frozen=True)
class SimConfig:
    soc: PaperSoCConfig
    dram_latency: int = 200           # delayer cycles (host domain)
    iommu: bool = False
    llc: bool = False
    host_interference: float = 0.0    # extra PTE-line eviction prob (Fig. 5)
    llc_hit_cycles: int = 10          # host cycles for an LLC hit
    pte_evict_prob: float = 0.10      # baseline leaf-PTE eviction (128 KiB LLC
                                      # shared with OS data between map & use)
    iotlb_policy: str = "lru"         # IOTLB replacement (design-space axis)
    iotlb_ways: int = 0               # IOTLB associativity (0 = fully assoc)
    walk_cache_entries: int = 0       # non-leaf PTE walk cache (0 = off)
    walk_cache_ways: int = 0          # walk-cache associativity (0 = fully)
    walk_cache_policy: str = "lru"    # walk-cache replacement
    # IOTLB prefetching (Kurth et al. MMU-aware DMA engine): walks issued
    # ahead of the demand stream. "none" (default) is bit-identical to the
    # prefetch-less platform; a demand access that arrives while its
    # prefetch is in flight still pays the full walk cost (late prefetch).
    iotlb_prefetch_policy: str = "none"   # none | next_page | stream
    iotlb_prefetch_degree: int = 2
    iotlb_prefetch_distance: int = 4
    seed: int = 0
    # svasan (core/sva/sanitizer.py): attach the shadow-state checker to
    # this platform's IOMMU. False still honors REPRO_SVASAN=1.
    svasan: bool = False


@dataclass
class KernelResult:
    total: float
    compute: float
    dma_exposed: float
    walks: float
    iotlb_hits: float
    ptw_cycles: float                 # total accel cycles spent walking
    n_tiles: int

    @property
    def dma_pct(self) -> float:
        return 100.0 * self.dma_exposed / max(self.total, 1e-9)

    @property
    def avg_ptw_host_cycles(self) -> float:
        """Average page-table-walk time in HOST cycles (Fig. 5 units)."""
        if self.walks == 0:
            return 0.0
        return self.ptw_cycles / H2A / self.walks


@dataclass
class Tile:
    compute: float                    # accel cycles of PE work
    bursts: float                     # async DMA bursts (double-buffered, hideable)
    bytes: float                      # async bytes moved for this tile
    sync_bursts: float = 0.0          # phase-boundary bursts (never overlapped)
    sync_bytes: float = 0.0
    pages: Tuple[int, ...] = ()       # page ids touched (IOVA translation)
    ptw_hidden_frac: float = 0.0      # fraction of walk latency on the async path
    walk_weight: float = 1.0          # pages represented per reference (coarsening)


class MemorySystem:
    """DRAM timing + the platform's IOMMU (the unified front-end configured
    as the paper's hardware: 4-entry IOTLB, Sv39 walker with LLC-aware
    costs). Translation state lives in ``self.iommu``; this class only adds
    the DRAM/DMA cycle accounting around it."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.soc = cfg.soc
        self.iommu = IOMMU(
            walk_model=Sv39Walk(
                levels=self.soc.ptw_levels,
                dram_access_cycles=self.dram_access_host(),
                llc=cfg.llc,
                llc_hit_cycles=cfg.llc_hit_cycles,
                pte_evict_prob=cfg.pte_evict_prob,
                host_interference=cfg.host_interference,
                to_accel=H2A,
                seed=cfg.seed,
                walk_cache=WalkCacheConfig(cfg.walk_cache_entries,
                                           cfg.walk_cache_ways,
                                           cfg.walk_cache_policy,
                                           seed=cfg.seed)),
            tlb=TLBConfig(self.soc.iotlb_entries, cfg.iotlb_policy,
                          seed=cfg.seed, ways=cfg.iotlb_ways),
            prefetch=PrefetchConfig(cfg.iotlb_prefetch_policy,
                                    degree=cfg.iotlb_prefetch_degree,
                                    distance=cfg.iotlb_prefetch_distance))
        # svasan: opt-in shadow-state checking over this IOMMU's unmap/
        # prefetch discipline (the simulator drives identity translations,
        # so only the attached-space cross-checks are live).
        if _resolve_sanitize(True if cfg.svasan else None):
            san = SVASanitizer()
            self.iommu.sanitizer = san

    @property
    def iotlb(self):
        """The hardware IOTLB (the IOMMU's translation cache)."""
        return self.iommu.tlb

    # ------------------------------------------------------------ basics
    def dram_access_host(self) -> float:
        return self.cfg.dram_latency + self.soc.dram_base_latency

    def burst_latency(self) -> float:
        """Accel cycles for one DMA burst's exposed latency."""
        return self.dram_access_host() * H2A

    def stream_cycles(self, n_bytes: float) -> float:
        """Pipelined data beats: 8 B per host cycle."""
        return n_bytes / self.soc.dram_bytes_per_cycle * H2A

    # ------------------------------------------------------ translation
    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Host creates IO mappings right before offload (Listing 1): the PTE
        cache lines land in the LLC (8 PTEs of 8 B per 64 B line)."""
        self.iommu.host_map_pass(pages)

    def translate(self, page: int) -> Tuple[float, bool]:
        """IOTLB lookup; returns (accel cycles, hit)."""
        _, cost, hit = self.iommu.translate(0, page)
        return cost, hit


def run_kernel(tiles: List[Tile], cfg: SimConfig,
               prologue_tiles: int = 1) -> KernelResult:
    """Double-buffered execution: total = dma_0 + sum max(c_t, d_t) + c_T."""
    mem = MemorySystem(cfg)
    if cfg.iommu:
        mem.host_map_pass([p for t in tiles for p in t.pages])

    total = 0.0
    compute_total = 0.0
    dma_exposed = 0.0
    walks = hits = 0
    ptw_cycles = 0.0

    def dma_time(tile: Tile) -> Tuple[float, float]:
        """Returns (hideable async DMA, synchronous DMA) for one tile."""
        nonlocal walks, hits, ptw_cycles
        d_async = tile.bursts * mem.burst_latency() \
            + mem.stream_cycles(tile.bytes)
        d_sync = tile.sync_bursts * mem.burst_latency() \
            + mem.stream_cycles(tile.sync_bytes)
        if cfg.iommu:
            w = tile.walk_weight
            for p in tile.pages:
                c, hit = mem.translate(p)
                if hit:
                    hits += w
                else:
                    walks += w
                # A hit's cost is 0 unless the IOTLB prefetcher is on and
                # the prefetch was LATE (walk still in flight): that
                # exposed latency is charged like a demand walk's.
                if c:
                    ptw_cycles += c * w
                    d_async += c * w * tile.ptw_hidden_frac
                    d_sync += c * w * (1.0 - tile.ptw_hidden_frac)
        return d_async, d_sync

    # prologue: first tile's DMA is never hidden
    da, ds = dma_time(tiles[0])
    total += da + ds
    dma_exposed += da + ds
    for i, tile in enumerate(tiles):
        c = tile.compute
        compute_total += c
        da, ds = dma_time(tiles[i + 1]) if i + 1 < len(tiles) else (0.0, 0.0)
        total += max(c, da) + ds
        dma_exposed += max(0.0, da - c) + ds
    return KernelResult(total=total, compute=compute_total,
                        dma_exposed=dma_exposed, walks=walks,
                        iotlb_hits=hits, ptw_cycles=ptw_cycles,
                        n_tiles=len(tiles))
