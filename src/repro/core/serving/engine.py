"""Continuous-batching serving engine over the paged SVA layer.

Zero-copy offload at serving granularity (the paper's map-don't-copy
result applied to KV admission):

  zero_copy  ONE global physical page pool is shared by every batch slot
             (per KV layer). Admission writes block-table rows (ints) and a
             single batched/bucketed prefill call scatters KV **directly
             into the shared pool through those tables** — no per-request
             cache materialization, no staging copy, no slot-by-slot tree
             walk. Decode consumes **delta table uploads**: only rows whose
             tables changed since the last step are re-sent
             (``PagedKVManager.delta_rows()``), with a full-table upload
             only on epoch invalidation — the serving-level analogue of a
             warm IOTLB.

  copy       The staging baseline (paper Fig. 2's memcpy mode): every
             admission materializes a fresh single-sequence cache, prefills
             into it, physically duplicates it, and copies it leaf-by-leaf
             into the batch cache.

Copy-on-write prefix sharing (zero_copy, full-attention archs): admission
consults the manager's :class:`~repro.core.sva.kv_manager.PrefixIndex` and
maps a prompt's already-resident prefix pages via refcount++ — the batched
prefill then feeds ONLY each prompt's non-shared suffix (fewer tokens per
admission: a direct throughput win), reading the skipped prefix's KV back
out of the shared pool (``attention.prefix_context_attention``) and
scattering through ``write_tables`` whose shared entries are NULLed so a
shared page is never written. When a decode append lands in a page another
sequence still maps, the manager queues a CoW page duplication which
``_apply_cow`` executes device-side (one batched pool-to-pool page copy)
before the next prefill/decode touches the page. Completed requests leave
their prompt pages behind as a warm prefix cache (LRU-evicted under page
pressure).

The decode hot path can run through the Pallas scalar-prefetch kernel
(``decode_backend="pallas"`` — kernels/paged_attention, interpret-mode off
TPU): the per-slot block tables live in SMEM and drive the KV page DMAs,
so gathering through *shared* block tables costs the same as private ones.

Adaptive translation front-end (``ModelConfig.serve_tlb_prefetch_*`` /
``serve_tlb_autotune*``; both default-off): the engine arms the manager's
IOMMU with an IOTLB prefetcher and/or attaches the online geometry
auto-tuner. Auto-tuning implies running ``translate_step`` every decode
step (the tuner's only signal is live traffic); each geometry switch is a
flush + epoch bump, which this engine absorbs as one full table upload —
decode outputs are unaffected (placement-invariance, pinned by
``tests/test_adaptive_tlb.py``).

CPU-testable with reduced configs; the same engine drives TPU meshes by
passing a MeshInfo.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.serving.scheduler import Scheduler, SchedulerOutput
from repro.core.serving.sequence_buffer import SequenceBuffer
from repro.core.sva.iommu import (AutoTuneConfig, PrefetchConfig, TLBConfig,
                                  default_autotune_candidates)
from repro.core.sva.kv_manager import PagedKVManager
from repro.models import (MeshInfo, NO_MESH, forward_decode, forward_prefill,
                          init_cache)
from repro.models import attention as attn
from repro.models.blocks import MAMBA_KINDS, _sp_mode


@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_tokens: int
    tenant: Optional[str] = None   # owning TenantDomain (None = untenanted)
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    # step-counter stamps (steps-to-first-token is the wall-clock-free
    # latency proxy the benchmarks report)
    submitted_step: Optional[int] = None
    first_token_step: Optional[int] = None
    # first token produced by a DECODE step (== first_token_step + queueing
    # in a colocated engine; in a disaggregated engine the gap additionally
    # covers the prefill->decode KV transfer — the TTFDT metric)
    first_decode_step: Optional[int] = None


# ------------------------------------------------------------ cache walks

def _map_tables(cache, tables: np.ndarray, lengths: np.ndarray):
    """Install per-slot block tables + lengths into a PER-SLOT-layout cache
    pytree (the copy-baseline path). Rejects — instead of silently wrapping —
    table entries that exceed a leaf's pool (sliding-window leaves have
    fewer pages than the manager row): wrapping page indices aliases
    distinct logical pages onto one physical page and corrupts KV."""
    t_np = np.asarray(tables)
    ln = jnp.asarray(lengths)

    def walk(tree):
        if isinstance(tree, attn.PagedKV):
            bt = tree.block_table
            n_pages = bt.shape[-1]
            sub = t_np[..., :n_pages]
            if sub.size and int(sub.max()) >= n_pages:
                raise ValueError(
                    f"block-table entry {int(sub.max())} out of range for a "
                    f"{n_pages}-page pool (sliding-window leaf); refusing to "
                    "wrap page indices — serve this config in zero_copy "
                    "mode, which gives window layers per-slot ring buffers")
            tt = jnp.broadcast_to(jnp.asarray(sub), bt.shape).astype(jnp.int32)
            return tree._replace(block_table=tt,
                                 length=jnp.broadcast_to(ln, tree.length.shape)
                                 .astype(jnp.int32))
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return tree
    return walk(cache)


def _write_slot(batch_cache, single_cache, slot: int):
    """Copy one sequence's prefilled cache into batch slot ``slot`` (the
    staging-copy baseline's O(cache-size) admission walk).

    Leaves under 'blocks' carry a leading (n_blocks,) axis -> batch axis 1;
    everything else has batch axis 0.
    """
    def walk(bt, st, under_blocks):
        if isinstance(bt, dict):
            return {k: walk(bt[k], st[k], under_blocks or k == "blocks")
                    for k in bt}
        if isinstance(bt, attn.PagedKV):
            return attn.PagedKV(*(walk(b, s, under_blocks)
                                  for b, s in zip(bt, st)))
        if isinstance(bt, tuple) and hasattr(bt, "_fields"):
            return type(bt)(*(walk(b, s, under_blocks)
                              for b, s in zip(bt, st)))
        ax = 1 if under_blocks and bt.ndim >= 2 else 0
        if bt.ndim == st.ndim and bt.shape == st.shape:
            return bt                      # scalar-ish leaves (lengths handled separately)
        idx = (slice(None),) * ax + (slot,)
        src = jnp.take(st, 0, axis=ax) if st.shape[ax] == 1 else st
        return bt.at[idx].set(src.astype(bt.dtype))
    return walk(batch_cache, single_cache, False)


def _build_prefill_view(cache, tables: jax.Array, lengths: jax.Array):
    """Per-admission view of the shared batch cache for a batched prefill of
    ``Nb = tables.shape[0]`` new sequences.

    Global-pool leaves keep THE SAME pool arrays (KV lands in place through
    the tables — zero-copy); per-slot leaves (sliding-window rings,
    recurrent states, cross-KV) become fresh zero rows that are scattered
    back to their slots afterwards. All of this traces inside one jit: no
    host-side cache materialization per admission.
    """
    nb = tables.shape[0]

    def walk(tree, under_blocks):
        if isinstance(tree, attn.PagedKV):
            lead = tree.block_table.shape[:tree.block_table.ndim - 2]
            if attn.is_global_layout(tree):
                return tree._replace(
                    block_table=jnp.broadcast_to(tables, lead + tables.shape),
                    length=jnp.broadcast_to(lengths, lead + lengths.shape))
            n_pages = tree.block_table.shape[-1]
            pool_tail = tree.k_pool.shape[len(lead) + 1:]
            kz = jnp.zeros(lead + (nb,) + pool_tail, tree.k_pool.dtype)
            iota = jnp.broadcast_to(jnp.arange(n_pages, dtype=jnp.int32),
                                    lead + (nb, n_pages))
            return attn.PagedKV(
                k_pool=kz, v_pool=kz, block_table=iota,
                length=jnp.zeros(lead + (nb,), tree.length.dtype))
        if isinstance(tree, dict):
            return {k: walk(v, under_blocks or k == "blocks")
                    for k, v in tree.items()}
        if isinstance(tree, tuple) and hasattr(tree, "_fields"):
            return type(tree)(*(walk(v, under_blocks) for v in tree))
        ax = 1 if under_blocks and tree.ndim >= 2 else 0
        shape = tree.shape[:ax] + (nb,) + tree.shape[ax + 1:]
        return jnp.zeros(shape, tree.dtype)
    return walk(cache, False)


def _merge_prefill_view(cache, view, slots: jax.Array):
    """Fold a prefilled view back into the batch cache. Global-pool leaves
    were written in place (just adopt the updated pool arrays); per-slot
    leaves scatter their rows to ``slots`` (out-of-bounds padding rows are
    dropped)."""
    def walk(c, w, under_blocks):
        if isinstance(c, attn.PagedKV):
            if attn.is_global_layout(c):
                return c._replace(k_pool=w.k_pool, v_pool=w.v_pool)
            lead_n = c.block_table.ndim - 2
            def scat(dst, src):
                if lead_n:
                    return dst.at[:, slots].set(src.astype(dst.dtype),
                                                mode="drop")
                return dst.at[slots].set(src.astype(dst.dtype), mode="drop")
            return c._replace(k_pool=scat(c.k_pool, w.k_pool),
                              v_pool=scat(c.v_pool, w.v_pool))
        if isinstance(c, dict):
            return {k: walk(c[k], w[k], under_blocks or k == "blocks")
                    for k in c}
        if isinstance(c, tuple) and hasattr(c, "_fields"):
            return type(c)(*(walk(a, b, under_blocks) for a, b in zip(c, w)))
        ax = 1 if under_blocks and c.ndim >= 2 else 0
        if ax == 0:
            return c.at[slots].set(w.astype(c.dtype), mode="drop")
        return c.at[:, slots].set(w.astype(c.dtype), mode="drop")
    return walk(cache, view, False)


def _install_tables(cache, tables: jax.Array, lengths: jax.Array):
    """Per-decode-step install of the device-resident table array + current
    per-slot lengths into a GLOBAL-layout cache (pure leaf replacement
    inside jit — the host uploaded at most the delta rows)."""
    def walk(tree):
        if isinstance(tree, attn.PagedKV):
            ln = jnp.broadcast_to(lengths, tree.length.shape).astype(jnp.int32)
            if attn.is_global_layout(tree):
                bt = jnp.broadcast_to(tables, tree.block_table.shape) \
                    .astype(jnp.int32)
                return tree._replace(block_table=bt, length=ln)
            return tree._replace(length=ln)     # window ring: identity table
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return tree
    return walk(cache)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_len: int,
                 page_size: int = 8, mi: MeshInfo = NO_MESH,
                 offload_mode: str = "zero_copy", src_len: int = 16,
                 eos_token: Optional[int] = None,
                 prefix_sharing: bool = True,
                 decode_backend: Optional[str] = None,
                 record_translation_trace: bool = False,
                 translation_stats: bool = False,
                 scheduler: str = "fixed",
                 pool_pages: Optional[int] = None,
                 tenants: Optional[Dict[str, dict]] = None):
        if scheduler not in ("fixed", "continuous"):
            raise ValueError(f"scheduler={scheduler!r} "
                             "(expected 'fixed' or 'continuous')")
        if decode_backend is not None:
            cfg = dataclasses.replace(cfg, decode_backend=decode_backend)
        self.scheduler_mode = scheduler
        self.cfg, self.params, self.mi = cfg, params, mi
        self.n_slots, self.max_len, self.page_size = n_slots, max_len, page_size
        self.src_len = src_len
        self.eos = eos_token
        self.max_pages = -(-max_len // page_size)
        kv_bytes = (2 * cfg.n_kv_heads * cfg.d_head
                    * sum(1 for k in cfg.layer_kinds() if "attn" in k or k == "cross_mlp")
                    * jnp.dtype(cfg.activation_dtype).itemsize)
        self.offload_mode = offload_mode
        # Prefix sharing needs every stateful layer to live in the shared
        # global pool: sliding-window rings / recurrent states / cross-KV are
        # per-slot, so a suffix-only prefill could not reconstruct them.
        share_kinds = {"attn_mlp", "attn_moe", "attn"}
        self._can_share = (offload_mode == "zero_copy" and prefix_sharing
                           and not cfg.is_encdec and not cfg.n_image_tokens
                           and all(k in share_kinds for k in cfg.layer_kinds()))
        # Adaptive translation front-end (both default-off): IOTLB
        # prefetching on the decode gather stream, and online geometry
        # auto-tuning driven by the live hit-rate signal (which requires
        # translate_step to run — see _translation_stats below).
        prefetch = PrefetchConfig(cfg.serve_tlb_prefetch_policy,
                                  degree=cfg.serve_tlb_prefetch_degree,
                                  distance=cfg.serve_tlb_prefetch_distance)
        autotune = None
        if cfg.serve_tlb_autotune:
            base_tlb = TLBConfig(cfg.serve_tlb_entries, cfg.serve_tlb_policy,
                                 ways=cfg.serve_tlb_ways,
                                 ranges=cfg.serve_tlb_ranges)
            cands = tuple(TLBConfig(e, p, ways=w,
                                    ranges=cfg.serve_tlb_ranges)
                          for e, w, p
                          in cfg.serve_tlb_autotune_candidates) \
                or default_autotune_candidates(base_tlb)
            autotune = AutoTuneConfig(interval_steps=cfg.serve_tlb_autotune,
                                      candidates=cands)
        self.mgr = PagedKVManager(n_slots, self.max_pages, page_size,
                                  kv_bytes_per_token=kv_bytes,
                                  offload_mode=offload_mode,
                                  prefix_sharing=self._can_share,
                                  prefix_policy=cfg.prefix_cache_policy,
                                  prefix_cap_pages=cfg.prefix_cache_pages,
                                  tlb_entries=cfg.serve_tlb_entries,
                                  tlb_policy=cfg.serve_tlb_policy,
                                  tlb_ways=cfg.serve_tlb_ways,
                                  tlb_ranges=cfg.serve_tlb_ranges,
                                  # None defers to REPRO_SVASAN (svasan)
                                  sanitize=True if cfg.svasan else None,
                                  tlb_prefetch=prefetch,
                                  autotune=autotune,
                                  prefix_autotune=cfg.prefix_cache_autotune,
                                  pool_pages=pool_pages,
                                  # multi-tenant domains: per-tenant ASID
                                  # ownership, quotas, IOTLB way partitions
                                  tenants=tenants)
        # Translation trace: ("map", fresh_pages) at admission (Listing-1
        # host map pass) and ("step", accesses, tokens_read) per decode step
        # — replayable through any IOMMU walk model (see
        # benchmarks/paged_serving.py --translation-report).
        # ``translation_stats`` runs every decode step's page gathers
        # through the manager's IOMMU (live IOTLB hit/miss signal) — a
        # host-side Python sweep over resident pages, so it is opt-in and
        # implied by tracing; the default hot path pays nothing.
        self.translation_trace: Optional[List[tuple]] = \
            [] if record_translation_trace else None
        # The auto-tuner's only signal — and the prefetcher's only trigger —
        # is the live IOMMU demand traffic, so arming either implies
        # running translate_step each decode step (otherwise the knob
        # would be a silent no-op).
        self._translation_stats = (translation_stats
                                   or record_translation_trace
                                   or autotune is not None
                                   or prefetch.enabled)
        self.queue: deque = deque()
        self.active: Dict[int, Request] = {}
        # Continuous mode: requests submitted or preempted but not currently
        # holding a slot (their tokens live in the scheduler's waiting queue).
        self._waiting_reqs: Dict[int, Request] = {}
        self._next_id = 0
        self._step_count = 0
        # Recurrent layers (mamba/rwkv) scan left-to-right: right-padding
        # would corrupt their final states, so those archs prefill at exact
        # lengths (batching only same-length prompts).
        self._exact_prefill = any(k in MAMBA_KINDS or k == "rwkv"
                                  for k in cfg.layer_kinds())
        self.metrics = {"prefills": 0, "prefill_reqs": 0, "decode_steps": 0,
                        "tokens": 0, "staging_copies": 0, "prefill_s": 0.0,
                        "decode_s": 0.0, "admit_s": 0.0,
                        "table_uploads_full": 0, "table_uploads_delta": 0,
                        "table_rows_uploaded": 0, "table_upload_bytes": 0,
                        "admit_table_bytes": 0,
                        "prefill_tokens_saved": 0, "shared_admissions": 0,
                        "cow_page_copies": 0}

        if offload_mode == "zero_copy":
            if _sp_mode(cfg, n_slots, max_len):
                raise NotImplementedError(
                    "zero_copy serving does not support the SP cache layout")
            self.null_page = n_slots * self.max_pages
            self.cache = init_cache(cfg, n_slots, max_len, page_size,
                                    src_len=src_len, per_seq=True,
                                    global_pages=self.null_page)
            self._tables_dev = jnp.full((n_slots, self.max_pages),
                                        self.null_page, jnp.int32)
            self._epoch_seen = -1
            self._prefill = jax.jit(self._prefill_zero_copy,
                                    donate_argnums=(2,))
            self._decode = jax.jit(self._decode_zero_copy,
                                   donate_argnums=(4,))
            self._decode_m = jax.jit(self._decode_masked, donate_argnums=(5,))
            self._cow = jax.jit(self._cow_copy_pages, donate_argnums=(0,))
        else:
            if (cfg.sliding_window
                    and any(k == "attn_mlp_local" for k in cfg.layer_kinds())
                    and -(-min(max_len, cfg.sliding_window) // page_size)
                    < self.max_pages):
                # Fail fast: per-slot window leaves have fewer pages than a
                # manager table row, so _map_tables would reject every
                # admission mid-run (data-dependent) — reject at
                # construction instead.
                raise NotImplementedError(
                    "copy-mode serving cannot map block-table rows onto "
                    "sliding-window leaves (fewer pages than the slot "
                    "table); serve this config with offload_mode='zero_copy'")
            self.cache = init_cache(cfg, n_slots, max_len, page_size,
                                    src_len=src_len, per_seq=True)
            self._decode = jax.jit(
                lambda p, t, pos, c: forward_decode(cfg, p, t, pos, c, mi))
            self._prefill = jax.jit(
                lambda p, b, c: forward_prefill(cfg, p, b, c, mi))

        # Continuous-batching mode (core/serving/scheduler.py): dense
        # SequenceBuffer state + a token-budget scheduler composing mixed
        # decode/chunked-prefill steps, with preemption under pool
        # pressure. Chunked prefill scatters through write_tables and reads
        # earlier chunks back via the prefix path, so it needs every
        # stateful layer in the shared global pool — the same constraint as
        # prefix sharing, minus the sharing flag itself.
        self.buffer: Optional[SequenceBuffer] = None
        self.sched: Optional[Scheduler] = None
        if scheduler == "continuous":
            if offload_mode != "zero_copy":
                raise NotImplementedError(
                    "continuous scheduling requires offload_mode='zero_copy'")
            if (self._exact_prefill or cfg.is_encdec or cfg.n_image_tokens
                    or not all(k in share_kinds for k in cfg.layer_kinds())):
                raise NotImplementedError(
                    "continuous scheduling needs all KV state in the shared "
                    "global pool (full-attention archs only): chunked "
                    "prefill cannot reconstruct per-slot ring buffers, "
                    "recurrent states, or cross-KV")
            self.buffer = SequenceBuffer(n_slots,
                                         self.max_pages * page_size)
            self.sched = Scheduler(self.mgr, self.buffer,
                                   cfg.sched_token_budget,
                                   cfg.sched_prefill_chunk,
                                   share_tokens=self._can_share,
                                   on_event=self._trace_event)

    # --------------------------------------------------------------- API
    def submit(self, prompt: List[int], max_tokens: int = 16,
               tenant: Optional[str] = None) -> int:
        self.mgr._check_tenant_name(tenant)     # unknown tenant: fail here,
                                                # not steps later at admit
        # reject, never wrap (and never over a tenant's whole quota)
        self.mgr.ensure_fits(len(prompt), max_tokens, tenant=tenant)
        if self.sched is not None and not prompt:
            raise ValueError("continuous scheduling needs a non-empty prompt")
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, list(prompt), max_tokens,
                                  tenant=tenant,
                                  submitted_at=time.perf_counter(),
                                  submitted_step=self._step_count))
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active
                    or (self.sched is not None and self.sched.has_work))

    def step(self, finished: Dict[int, Request]) -> None:
        """Run ONE engine step (admission + compute + completion harvest)
        under the configured scheduler. Benchmarks drive this directly to
        inject arrivals between steps; :meth:`run` is the closed loop."""
        if self.sched is not None:
            self._continuous_step()
        else:
            self._admit()
            self._decode_step()
        self._step_count += 1
        self._release_done(finished)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        finished: Dict[int, Request] = {}
        steps = 0
        while self.has_work and steps < max_steps:
            self.step(finished)
            steps += 1
        return finished

    def _release_done(self, finished: Dict[int, Request]) -> None:
        for rid in [r for r, q in self.active.items()
                    if self.mgr.seqs[r].done]:
            req = self.active.pop(rid)
            req.done_at = time.perf_counter()
            st = self.mgr.seqs[rid]
            if self.sched is not None:
                # Generations preempted along the way were folded into
                # out_tokens at preemption time; append the rest.
                req.out_tokens.extend(st.tokens)
                self.sched.finish(rid)
            else:
                req.out_tokens = st.tokens
            if self.translation_trace is not None:
                self.translation_trace.append(
                    ("unmap", st.slot, len(st.pages)))
            self.mgr.release(rid)
            finished[rid] = req

    def _trace_event(self, ev: tuple) -> None:
        """Scheduler lifecycle events (map/unmap/preempt/resume) join the
        translation trace in order, keeping it replayable."""
        if self.translation_trace is not None:
            self.translation_trace.append(ev)

    def invalidate_epoch(self) -> None:
        """Flush every device translation (paper Listing 1); the next decode
        step performs a full-table upload."""
        self.mgr.invalidate_epoch()

    # --------------------------------------------------------------- admission
    def _admit(self):
        if self.offload_mode == "zero_copy":
            self._apply_cow()   # queued page copies must land before any
                                # new prefill can recycle their source pages
        admitted: List = []
        while self.queue:
            req = self.queue[0]
            t0 = time.perf_counter()
            st = self.mgr.admit(req.req_id, len(req.prompt), req.max_tokens,
                                tokens=req.prompt if self._can_share else None,
                                tenant=req.tenant)
            self.metrics["admit_s"] += time.perf_counter() - t0
            if st is None:
                break                      # no slot/pages: continuous batching waits
            self.queue.popleft()
            if self.offload_mode == "copy":
                self._prefill_into_slot(req, st.slot)
                self.active[req.req_id] = req
                continue
            if self.translation_trace is not None:
                # Listing-1 map pass over the freshly allocated pages
                # (shared prefix pages were mapped by their provider). The
                # extended fields — slot + the row's full logical->physical
                # table — let a replaying prefetcher resolve upcoming pages
                # the way the hardware reads the page table; replays of the
                # short ("map", pages) form stay supported (and replay
                # numbers without prefetching are identical either way).
                self.translation_trace.append(
                    ("map", list(st.pages[st.shared_pages:]),
                     st.slot, list(st.pages)))
            admitted.append((req, st))
        if not admitted:
            return
        # Prefill in dependency WAVES: a request whose shared prefix pages
        # were freshly allocated by another request admitted THIS round must
        # prefill after its provider (the prefix KV has to be resident in
        # the pool before a sharer's suffix-only prefill reads it). Wave of
        # a request = 1 + max wave over the providers of its shared pages.
        page_wave: Dict[int, int] = {}
        waves: Dict[int, list] = {}
        for req, st in admitted:
            w = 0
            for pg in st.pages[:st.shared_pages]:
                if pg in page_wave:
                    w = max(w, page_wave[pg] + 1)
            for pg in st.pages[st.shared_pages:]:
                page_wave[pg] = w
            waves.setdefault(w, []).append((req, st))
        for w in sorted(waves):
            wave = waves[w]
            if self._exact_prefill:
                groups: Dict[int, list] = {}
                for item in wave:
                    suf = len(item[0].prompt) - item[1].prefill_start
                    groups.setdefault(suf, []).append(item)
                for group in groups.values():
                    self._batched_prefill(group)
            else:
                self._batched_prefill(wave)
        for req, st in admitted:
            self.active[req.req_id] = req

    def _bucket_len(self, longest: int) -> int:
        """Power-of-two token bucket (stable jit cache keys), capped at slot
        capacity."""
        lb = self.page_size
        while lb < longest:
            lb *= 2
        return min(lb, self.max_pages * self.page_size)

    def _batched_prefill(self, group):
        """ONE padded prefill call for all newly admitted requests: KV is
        scattered straight into the shared global pool through the admitted
        rows' block tables. Admission's host->device traffic is the token
        ids plus int32 table entries — not KV bytes.

        Prefix-shared admissions feed ONLY the non-shared suffix (the
        bucket is sized on suffix lengths, so a 1000-token prompt with a
        992-token shared prefix prefills like an 8-token prompt): the
        skipped prefix's KV is read back from the shared pool, and the
        scatter runs through ``write_tables`` (shared entries NULLed) so
        shared pages are never written."""
        t0 = time.perf_counter()
        sufs = [len(req.prompt) - st.prefill_start for req, st in group]
        sharing = any(st.shared_pages for _, st in group)
        lb = max(sufs) if self._exact_prefill else self._bucket_len(max(sufs))
        nb = 1
        while nb < len(group):
            nb *= 2
        nb = max(min(nb, self.n_slots), len(group))
        tokens = np.zeros((nb, lb), np.int32)
        lengths = np.zeros((nb,), np.int32)
        prefix = np.zeros((nb,), np.int32)
        slots = np.full((nb,), self.n_slots, np.int32)   # OOB: scatter-dropped
        tables = np.full((nb, self.max_pages), self.mgr.null_page, np.int32)
        wtables = np.full((nb, self.max_pages), self.mgr.null_page, np.int32)
        for i, (req, st) in enumerate(group):
            tokens[i, :sufs[i]] = req.prompt[st.prefill_start:]
            lengths[i] = sufs[i]
            prefix[i] = st.prefill_start
            slots[i] = st.slot
            tables[i] = self.mgr.tables[st.slot]
            wtables[i] = tables[i]
            wtables[i, :st.shared_pages] = self.mgr.null_page
        # Admission upload accounting: only the REAL rows' table entries
        # (padding rows exist for jit-key stability, not data movement).
        self.metrics["admit_table_bytes"] += len(group) * self.max_pages * 4
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(lengths),
                 "tables": jnp.asarray(tables),
                 "slots": jnp.asarray(slots)}
        if sharing:
            # Separate trace: the non-shared path keeps its (cheaper) flash
            # prefill and its exact numerics.
            batch["prefix_lens"] = jnp.asarray(prefix)
            batch["write_tables"] = jnp.asarray(wtables)
        logits, self.cache = self._prefill(self.params, batch, self.cache)
        logits = np.asarray(logits)
        now = time.perf_counter()
        for i, (req, st) in enumerate(group):
            first = int(np.argmax(logits[i, -1]))
            self.mgr.append_token(req.req_id, first)
            req.first_token_at = now
            req.first_token_step = self._step_count
        self.metrics["prefills"] += 1
        self.metrics["prefill_reqs"] += len(group)
        self.metrics["prefill_s"] += time.perf_counter() - t0

    def _prefill_zero_copy(self, params, batch, cache):
        cfg = self.cfg
        view = _build_prefill_view(cache, batch["tables"], batch["lengths"])
        fb = {"tokens": batch["tokens"], "lengths": batch["lengths"]}
        if "prefix_lens" in batch:      # suffix-only prefill (prefix sharing)
            fb["prefix_lens"] = batch["prefix_lens"]
            fb["write_tables"] = batch["write_tables"]
        nb = batch["tokens"].shape[0]
        if cfg.is_encdec:
            fb["enc_x"] = jnp.zeros((nb, self.src_len, cfg.d_model),
                                    jnp.dtype(cfg.activation_dtype))
        elif cfg.n_image_tokens:
            fb["img_x"] = jnp.zeros((nb, cfg.n_image_tokens, cfg.d_model),
                                    jnp.dtype(cfg.activation_dtype))
        logits, view = forward_prefill(cfg, params, fb, view, self.mi)
        cache = _merge_prefill_view(cache, view, batch["slots"])
        return logits, cache

    def _prefill_into_slot(self, req: Request, slot: int):
        """Copy-mode baseline: materialize a fresh single-sequence cache,
        prefill it, physically duplicate it (the staging copy), then walk it
        leaf-by-leaf into the batch cache."""
        t0 = time.perf_counter()
        cfg = self.cfg
        single = init_cache(cfg, 1, self.max_len, self.page_size,
                            src_len=self.src_len, per_seq=True)
        row = self.mgr.tables[slot:slot + 1]
        single = _map_tables(single, row, np.zeros(1, np.int32))
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        batch = {"tokens": toks}
        if cfg.is_encdec:
            batch["enc_x"] = jnp.zeros((1, self.src_len, cfg.d_model),
                                       jnp.dtype(cfg.activation_dtype))
        elif cfg.n_image_tokens:
            batch["img_x"] = jnp.zeros((1, cfg.n_image_tokens, cfg.d_model),
                                       jnp.dtype(cfg.activation_dtype))
        logits, single = self._prefill(self.params, batch, single)
        # staging copy baseline: physically duplicate the KV pools once
        single = jax.tree.map(lambda x: x + 0, single)
        self.metrics["staging_copies"] += 1
        self.cache = _write_slot(self.cache, single, slot)
        first = int(jnp.argmax(logits[0, -1]))
        self.mgr.append_token(req.req_id, first)
        req.first_token_at = time.perf_counter()
        req.first_token_step = self._step_count
        self.metrics["prefills"] += 1
        self.metrics["prefill_reqs"] += 1
        self.metrics["prefill_s"] += time.perf_counter() - t0

    # --------------------------------------------------------------- CoW
    def _cow_copy_pages(self, cache, src, dst):
        """One batched physical page duplication in every global-pool KV
        leaf: ``pool[dst] = pool[src]`` (padding pairs carry dst == NULL and
        are scatter-dropped). This is the entire device-side cost of a CoW
        divergence — page_size tokens of KV per layer, instead of
        re-prefilling the whole shared prefix."""
        def walk(tree):
            if isinstance(tree, attn.PagedKV):
                if not attn.is_global_layout(tree):
                    return tree
                lead = tree.block_table.ndim - 2
                def cp(pool):
                    if lead:
                        return pool.at[:, dst].set(pool[:, src], mode="drop")
                    return pool.at[dst].set(pool[src], mode="drop")
                return tree._replace(k_pool=cp(tree.k_pool),
                                     v_pool=cp(tree.v_pool))
            if isinstance(tree, dict):
                return {k: walk(v) for k, v in tree.items()}
            if isinstance(tree, tuple) and hasattr(tree, "_fields"):
                return type(tree)(*(walk(v) for v in tree))
            return tree
        return walk(cache)

    def _apply_cow(self):
        """Execute queued copy-on-write page duplications (src -> dst)
        before the next device op reads a duplicated page or a new
        admission recycles a released source page."""
        pairs = self.mgr.drain_cow_copies()
        if not pairs:
            return
        if self.translation_trace is not None:
            # A CoW remap is a fresh mapping: the host map pass warms the
            # duplicated pages' PTE lines before the device touches them.
            self.translation_trace.append(("map", [d for _, d in pairs]))
        n = 1
        while n < len(pairs):
            n *= 2
        src = np.zeros((n,), np.int32)
        dst = np.full((n,), self.mgr.null_page, np.int32)  # pad: dropped
        for i, (s, d) in enumerate(pairs):
            src[i], dst[i] = s, d
        self.cache = self._cow(self.cache, jnp.asarray(src), jnp.asarray(dst))
        self.metrics["cow_page_copies"] += len(pairs)

    # --------------------------------------------------------------- decode
    def _upload_tables(self):
        """Delta table upload: send only rows that changed since last step;
        a full-table upload happens only after an epoch invalidation."""
        if self.mgr.epoch != self._epoch_seen:
            self.mgr.delta_rows()                    # superseded by the full upload
            self._tables_dev = jnp.asarray(self.mgr.tables)
            self._epoch_seen = self.mgr.epoch
            self.metrics["table_uploads_full"] += 1
            self.metrics["table_rows_uploaded"] += self.n_slots
            self.metrics["table_upload_bytes"] += int(self.mgr.tables.nbytes)
            return
        rows = self.mgr.delta_rows()
        if rows:
            idx = np.asarray(rows)
            sub = self.mgr.tables[idx]
            self._tables_dev = self._tables_dev.at[jnp.asarray(idx)].set(
                jnp.asarray(sub))
            self.metrics["table_uploads_delta"] += 1
            self.metrics["table_rows_uploaded"] += len(rows)
            self.metrics["table_upload_bytes"] += int(sub.nbytes)

    def _decode_zero_copy(self, params, tokens, kv_len, tables, cache):
        cache = _install_tables(cache, tables, kv_len)
        return forward_decode(self.cfg, params, tokens, kv_len, cache, self.mi)

    def _decode_step(self):
        if not self.active:
            return
        t0 = time.perf_counter()
        lengths = self.mgr.device_lengths()
        # KV length = tokens whose KV is in cache; exactly one token is
        # pending per active sequence (the one this step feeds in).
        kv_len = np.maximum(lengths - 1, 0).astype(np.int32)
        last = np.zeros((self.n_slots, 1), np.int32)
        for rid, req in self.active.items():
            st = self.mgr.seqs[rid]
            last[st.slot, 0] = st.tokens[-1] if st.tokens else \
                (req.prompt[-1] if req.prompt else 0)
        pos = jnp.asarray(kv_len)                       # write/rope position
        if self.offload_mode == "zero_copy":
            self._apply_cow()       # duplicated pages must exist before the
                                    # decode writes/reads through new tables
            self._upload_tables()
            if self._translation_stats:
                # Run this step's page gathers through the IOMMU front-end:
                # the live-traffic IOTLB hit/miss signal (CountingWalk), and
                # the trace --translation-report replays through Sv39Walk.
                accesses = self.mgr.translate_step()
                if self.translation_trace is not None:
                    self.translation_trace.append(
                        ("step", accesses, int(kv_len.sum())))
            logits, self.cache = self._decode(
                self.params, jnp.asarray(last), pos, self._tables_dev,
                self.cache)
        else:
            # copy baseline: full table re-upload + re-map every step
            tables = self.mgr.device_tables()
            self.cache = _map_tables(self.cache, tables, kv_len)
            self.metrics["table_uploads_full"] += 1
            self.metrics["table_rows_uploaded"] += self.n_slots
            self.metrics["table_upload_bytes"] += int(tables.nbytes)
            logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                              pos, self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for rid in list(self.active):
            st = self.mgr.seqs[rid]
            tok = int(nxt[st.slot])
            self.mgr.append_token(rid, tok)
            self.metrics["tokens"] += 1
            if self.eos is not None and tok == self.eos:
                st.done = True
        self.metrics["decode_steps"] += 1
        self.mgr.observe_step()
        self.metrics["decode_s"] += time.perf_counter() - t0

    # ------------------------------------------------- continuous batching
    def _continuous_step(self):
        # Queued CoW copies must land BEFORE the scheduler can preempt: a
        # preemption frees its sequence's pages, and a same-step resume or
        # chunk prefill could recycle a pending copy's source page.
        self._apply_cow()
        while self.queue:
            req = self.queue.popleft()
            self.sched.submit(req.req_id, req.prompt, req.max_tokens,
                              tenant=req.tenant)
            self._waiting_reqs[req.req_id] = req
        t0 = time.perf_counter()
        out = self.sched.schedule()
        self.metrics["admit_s"] += time.perf_counter() - t0
        for sid, folded in out.preempted:
            req = self.active.pop(sid)
            req.out_tokens.extend(folded)
            self._waiting_reqs[sid] = req
        for sid in out.admitted + out.resumed:
            self.active[sid] = self._waiting_reqs.pop(sid)
        if out.chunks:
            self._chunk_prefill(out.chunks)
        self._decode_continuous(out)

    def _chunk_prefill(self, chunks):
        """One padded prefill call for this step's chunk spans — the
        chunked-prefill counterpart of ``_batched_prefill``. Every chunk
        runs the prefix path: ``prefix_lens`` positions earlier chunks'
        (and shared prefixes') KV as context, and ``write_tables`` NULLs
        every page before the chunk's own span — the scatter zero-scrubs
        ALL non-NULL entries, so leaving an earlier chunk's page mapped
        would erase its KV. Pages past the span are harmlessly re-scrubbed
        (still unwritten). The final chunk's logits produce the first
        token (or re-inject a preempted sequence's pending token)."""
        t0 = time.perf_counter()
        sufs = [c.end - c.start for c in chunks]
        lb = self._bucket_len(max(sufs))
        nb = 1
        while nb < len(chunks):
            nb *= 2
        nb = max(min(nb, self.n_slots), len(chunks))
        tokens = np.zeros((nb, lb), np.int32)
        lengths = np.zeros((nb,), np.int32)
        prefix = np.zeros((nb,), np.int32)
        slots = np.full((nb,), self.n_slots, np.int32)  # OOB: scatter-dropped
        tables = np.full((nb, self.max_pages), self.mgr.null_page, np.int32)
        wtables = np.full((nb, self.max_pages), self.mgr.null_page, np.int32)
        for i, c in enumerate(chunks):
            st = self.mgr.seqs[c.seq_id]
            tokens[i, :sufs[i]] = self.buffer.chunk_tokens(c.slot, c.start,
                                                           c.end)
            lengths[i] = sufs[i]
            prefix[i] = c.start
            slots[i] = c.slot
            tables[i] = self.mgr.tables[c.slot]
            wtables[i] = tables[i]
            keep_from = max(st.shared_pages, c.start // self.page_size)
            wtables[i, :keep_from] = self.mgr.null_page
        self.metrics["admit_table_bytes"] += len(chunks) * self.max_pages * 4
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(lengths),
                 "tables": jnp.asarray(tables),
                 "slots": jnp.asarray(slots),
                 "prefix_lens": jnp.asarray(prefix),
                 "write_tables": jnp.asarray(wtables)}
        logits, self.cache = self._prefill(self.params, batch, self.cache)
        finals = [(i, c) for i, c in enumerate(chunks) if c.is_final]
        if finals:
            logits = np.asarray(logits)
        now = time.perf_counter()
        for i, c in enumerate(chunks):
            self.buffer.advance(c.slot, c.end)
            # Progressive prefix registration: the chunk's KV is resident
            # NOW, so its pages may join the index (an eager registration
            # at lazy admission would publish uncomputed pages).
            self.mgr.register_progress(c.seq_id,
                                       self.buffer.token_ids[c.slot], c.end)
        for i, c in finals:
            first = (c.pending if c.pending is not None
                     else int(np.argmax(logits[i, -1])))
            self.mgr.append_token(c.seq_id, first)
            self.buffer.append(c.slot, first)
            req = self.active[c.seq_id]
            if req.first_token_at is None:
                req.first_token_at = now
                req.first_token_step = self._step_count
        self.metrics["prefills"] += 1
        self.metrics["prefill_reqs"] += len(finals)
        self.metrics["prefill_s"] += time.perf_counter() - t0

    def _decode_masked(self, params, tokens, kv_len, tables, mask, cache):
        """Decode with non-decoding slots masked out: their table rows
        become all-NULL (KV writes dropped, gathers read zero) and their
        kv_len arrives pre-masked to 0, so a mid-prefill slot's pages are
        never touched. Masked rows compute garbage logits that nothing
        consumes — identical shapes every step, one jit trace."""
        tables = jnp.where(mask[:, None], tables, self.null_page)
        cache = _install_tables(cache, tables, kv_len)
        return forward_decode(self.cfg, params, tokens, kv_len, cache,
                              self.mi)

    def _resident_tokens(self) -> Dict[int, int]:
        """Per-sequence resident-token counts for this step's translation
        accounting: a decoding sequence gathers everything it has; a
        mid-prefill sequence only its computed chunks. (A disaggregated
        front-end extends this with its decode worker's sequences.)"""
        resident = {}
        for sid in self.sched.running:
            slot = self.buffer.slot_of(sid)
            resident[sid] = (self.mgr.seqs[sid].length
                             if self.buffer.is_decoding(slot)
                             else int(self.buffer.n_computed[slot]))
        return resident

    def _decode_continuous(self, out: SchedulerOutput):
        t0 = time.perf_counter()
        self._apply_cow()       # final-chunk first tokens may queue CoW
        self._upload_tables()
        if self._translation_stats:
            resident = self._resident_tokens()
            if resident:
                accesses = self.mgr.translate_step(resident=resident)
                if self.translation_trace is not None:
                    self.translation_trace.append(
                        ("step", accesses, int(sum(resident.values()))))
        if not out.decode_slots:
            return
        lengths = self.mgr.device_lengths()
        mask = np.zeros((self.n_slots,), bool)
        mask[out.decode_slots] = True
        kv_len = np.where(mask, np.maximum(lengths - 1, 0), 0) \
            .astype(np.int32)
        last = np.where(mask, self.buffer.last_tokens(), 0) \
            .astype(np.int32)[:, None]
        logits, self.cache = self._decode_m(
            self.params, jnp.asarray(last), jnp.asarray(kv_len),
            self._tables_dev, jnp.asarray(mask), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for slot in out.decode_slots:
            sid = int(self.buffer.seq_ids[slot])
            tok = int(nxt[slot])
            self.mgr.append_token(sid, tok)
            self.buffer.append(slot, tok)
            self.metrics["tokens"] += 1
            req = self.active.get(sid)
            if req is not None and req.first_decode_step is None:
                req.first_decode_step = self._step_count
            if self.eos is not None and tok == self.eos:
                self.mgr.seqs[sid].done = True
        self.metrics["decode_steps"] += 1
        self.mgr.observe_step()
        self.metrics["decode_s"] += time.perf_counter() - t0

    def stats(self) -> dict:
        s = self.mgr.stats()
        m = dict(self.metrics)
        # Single source of truth is the manager's prefix index; the engine
        # keys are kept as the stable serving-level aliases
        # (``cow_page_copies`` stays engine-owned: copies EXECUTED
        # device-side, vs the manager's queued count).
        pf = s.get("prefix")
        if pf is not None:
            m["prefill_tokens_saved"] = pf["tokens_saved"]
            m["shared_admissions"] = pf["hits"]
        if self.sched is not None:
            m["sched"] = self.sched.stats()
        return {**m, **s}
