"""Continuous-batching serving engine over the paged SVA layer.

Zero-copy offload at serving granularity: admission writes block-table rows
(ints), prefill produces KV directly into the mapped pages through the block
table, decode walks the same tables. ``offload_mode="copy"`` instead pays a
modeled staging copy per admission (the paper's baseline), so the two modes
can be benchmarked against each other like Fig. 2.

CPU-testable with reduced configs; the same engine drives TPU meshes by
passing a MeshInfo.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sva.kv_manager import PagedKVManager
from repro.models import (MeshInfo, NO_MESH, forward_decode, forward_prefill,
                          init_cache)
from repro.models import attention as attn
from repro.models.model import set_cache_length


@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


def _map_tables(cache, tables: np.ndarray, lengths: np.ndarray):
    """Install manager block tables + per-seq lengths into a cache pytree."""
    t = jnp.asarray(tables)
    ln = jnp.asarray(lengths)

    def walk(tree):
        if isinstance(tree, attn.PagedKV):
            bt = tree.block_table
            n_pages = bt.shape[-1]
            tt = t[..., :n_pages] % max(n_pages, 1)
            tt = jnp.broadcast_to(tt, bt.shape).astype(jnp.int32)
            return tree._replace(block_table=tt,
                                 length=jnp.broadcast_to(ln, tree.length.shape)
                                 .astype(jnp.int32))
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return tree
    return walk(cache)


def _write_slot(batch_cache, single_cache, slot: int):
    """Copy one sequence's prefilled cache into batch slot ``slot``.

    Leaves under 'blocks' carry a leading (n_blocks,) axis -> batch axis 1;
    everything else has batch axis 0.
    """
    def walk(bt, st, under_blocks):
        if isinstance(bt, dict):
            return {k: walk(bt[k], st[k], under_blocks or k == "blocks")
                    for k in bt}
        if isinstance(bt, attn.PagedKV):
            return attn.PagedKV(*(walk(b, s, under_blocks)
                                  for b, s in zip(bt, st)))
        if isinstance(bt, tuple) and hasattr(bt, "_fields"):
            return type(bt)(*(walk(b, s, under_blocks)
                              for b, s in zip(bt, st)))
        ax = 1 if under_blocks and bt.ndim >= 2 else 0
        if bt.ndim == st.ndim and bt.shape == st.shape:
            return bt                      # scalar-ish leaves (lengths handled separately)
        idx = (slice(None),) * ax + (slot,)
        src = jnp.take(st, 0, axis=ax) if st.shape[ax] == 1 else st
        return bt.at[idx].set(src.astype(bt.dtype))
    return walk(batch_cache, single_cache, False)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_len: int,
                 page_size: int = 8, mi: MeshInfo = NO_MESH,
                 offload_mode: str = "zero_copy", src_len: int = 16,
                 eos_token: Optional[int] = None):
        self.cfg, self.params, self.mi = cfg, params, mi
        self.n_slots, self.max_len, self.page_size = n_slots, max_len, page_size
        self.src_len = src_len
        self.eos = eos_token
        kv_bytes = (2 * cfg.n_kv_heads * cfg.d_head
                    * sum(1 for k in cfg.layer_kinds() if "attn" in k or k == "cross_mlp")
                    * jnp.dtype(cfg.activation_dtype).itemsize)
        self.mgr = PagedKVManager(n_slots, -(-max_len // page_size), page_size,
                                  kv_bytes_per_token=kv_bytes,
                                  offload_mode=offload_mode)
        self.cache = init_cache(cfg, n_slots, max_len, page_size,
                                src_len=src_len, per_seq=True)
        self.queue: deque = deque()
        self.active: Dict[int, Request] = {}
        self._next_id = 0
        self.offload_mode = offload_mode
        self.metrics = {"prefills": 0, "decode_steps": 0, "tokens": 0,
                        "staging_copies": 0, "prefill_s": 0.0, "decode_s": 0.0,
                        "admit_s": 0.0}

        self._decode = jax.jit(
            lambda p, t, pos, c: forward_decode(cfg, p, t, pos, c, mi))
        self._prefill = jax.jit(
            lambda p, b, c: forward_prefill(cfg, p, b, c, mi))

    # --------------------------------------------------------------- API
    def submit(self, prompt: List[int], max_tokens: int = 16) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, list(prompt), max_tokens,
                                  submitted_at=time.perf_counter()))
        return rid

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        finished: Dict[int, Request] = {}
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self._admit()
            self._decode_step()
            steps += 1
            for rid in [r for r, q in self.active.items()
                        if self.mgr.seqs[r].done]:
                req = self.active.pop(rid)
                req.done_at = time.perf_counter()
                req.out_tokens = self.mgr.seqs[rid].tokens
                self.mgr.release(rid)
                finished[rid] = req
        return finished

    # --------------------------------------------------------------- internals
    def _admit(self):
        while self.queue:
            req = self.queue[0]
            t0 = time.perf_counter()
            st = self.mgr.admit(req.req_id, len(req.prompt), req.max_tokens)
            if st is None:
                break                      # no slot/pages: continuous batching waits
            self.queue.popleft()
            self.metrics["admit_s"] += time.perf_counter() - t0
            self._prefill_into_slot(req, st.slot)
            self.active[req.req_id] = req

    def _prefill_into_slot(self, req: Request, slot: int):
        t0 = time.perf_counter()
        cfg = self.cfg
        single = init_cache(cfg, 1, self.max_len, self.page_size,
                            src_len=self.src_len, per_seq=True)
        # install this sequence's REAL page mapping before prefill: the
        # prefill scatter writes KV through the block table (zero-copy).
        row = self.mgr.tables[slot:slot + 1]
        single = _map_tables(single, row, np.zeros(1, np.int32))
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        batch = {"tokens": toks}
        if cfg.is_encdec:
            batch["enc_x"] = jnp.zeros((1, self.src_len, cfg.d_model),
                                       jnp.dtype(cfg.activation_dtype))
        elif cfg.n_image_tokens:
            batch["img_x"] = jnp.zeros((1, cfg.n_image_tokens, cfg.d_model),
                                       jnp.dtype(cfg.activation_dtype))
        logits, single = self._prefill(self.params, batch, single)
        if self.offload_mode == "copy":
            # staging copy baseline: physically duplicate the KV pools once
            single = jax.tree.map(lambda x: x + 0, single)
            self.metrics["staging_copies"] += 1
        self.cache = _write_slot(self.cache, single, slot)
        first = int(jnp.argmax(logits[0, -1]))
        self.mgr.append_token(req.req_id, first)
        req.first_token_at = time.perf_counter()
        self.metrics["prefills"] += 1
        self.metrics["prefill_s"] += time.perf_counter() - t0

    def _decode_step(self):
        if not self.active:
            return
        t0 = time.perf_counter()
        lengths = self.mgr.device_lengths()
        tables = self.mgr.device_tables()
        # KV length = tokens whose KV is in cache; exactly one token is
        # pending per active sequence (the one this step feeds in).
        kv_len = np.maximum(lengths - 1, 0).astype(np.int32)
        self.cache = _map_tables(self.cache, tables, kv_len)
        last = np.zeros((self.n_slots, 1), np.int32)
        for rid, req in self.active.items():
            st = self.mgr.seqs[rid]
            last[st.slot, 0] = st.tokens[-1] if st.tokens else \
                (req.prompt[-1] if req.prompt else 0)
        pos = jnp.asarray(kv_len)                       # write/rope position
        logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                          pos, self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for rid in list(self.active):
            st = self.mgr.seqs[rid]
            tok = int(nxt[st.slot])
            self.mgr.append_token(rid, tok)
            self.metrics["tokens"] += 1
            if self.eos is not None and tok == self.eos:
                st.done = True
        self.metrics["decode_steps"] += 1
        self.metrics["decode_s"] += time.perf_counter() - t0

    def stats(self) -> dict:
        return {**self.metrics, **self.mgr.stats()}
