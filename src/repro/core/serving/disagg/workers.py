"""Worker-side pieces of the disaggregated engine: the prefill worker's
scheduler specialization, the decode worker's run list, and the transfer
engine that moves finished prefills between them.

All three operate on state OWNED elsewhere (the manager, the shared
``SequenceBuffer``, the engine's request map) — they partition
responsibility, not data: one pool, one IOMMU, one buffer, split by slot
range. Page-pool verbs stay inside :class:`PagedKVManager` (svalint
R002); the transfer engine only sequences ``DisaggEngine._migrate``
calls."""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Sequence, Set

from repro.core.serving.scheduler import Scheduler
from repro.core.serving.sequence_buffer import SequenceBuffer
from repro.core.sva.kv_manager import PagedKVManager
from repro.core.sva.page_pool import OutOfPages


class PrefillScheduler(Scheduler):
    """The colocated scheduler minus decode: every token-budget point goes
    to chunked prefill. A sequence that finishes its prompt (buffer says
    decoding) is NOT stepped here — it parks, still preemptible under pool
    pressure, until the :class:`KVTransferEngine` migrates it out. The
    preemption floor drops to 0 because forward progress belongs to the
    decode worker (see ``Scheduler.min_running``)."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.min_running = 0

    def _decodes_here(self, seq_id: int, slot: int) -> bool:
        return False


class PrefillWorker:
    """Admission + chunked prefill over the prefill slot range. Thin facade:
    the scheduler does the work; the worker adds hand-off detection."""

    def __init__(self, slots: Sequence[int], sched: Scheduler,
                 buffer: SequenceBuffer, mgr: PagedKVManager):
        self.slots = list(slots)
        self.sched = sched
        self.buffer = buffer
        self.mgr = mgr

    def ready_for_handoff(self) -> List[int]:
        """Sequences whose prefill completed this step (first token
        appended, buffer row decoding) and that still have decoding left
        to do — a prompt whose budget was exactly one token completes in
        place and never transfers."""
        out = []
        for sid in list(self.sched.running):
            slot = self.buffer.slot_of(sid)
            if self.buffer.is_decoding(slot) and not self.mgr.seqs[sid].done:
                out.append(sid)
        return out


class DecodeWorker:
    """The masked decode loop's run list over the decode slot range. The
    engine composes its ``decode_slots()`` into the step; completion
    teardown mirrors ``Scheduler.finish``."""

    def __init__(self, slots: Sequence[int], buffer: SequenceBuffer):
        self.slots = list(slots)
        self.buffer = buffer
        self.running: List[int] = []          # arrival order

    def decode_slots(self) -> List[int]:
        return [self.buffer.slot_of(sid) for sid in self.running]

    def finish(self, seq_id: int) -> None:
        """A decode-side sequence completed (the engine releases it):
        drop run-list + buffer state. Called BEFORE ``release``."""
        slot = self.buffer.slot_of(seq_id)
        self.running.remove(seq_id)
        self.buffer.detach(slot)


class KVTransferEngine:
    """FIFO of finished prefills awaiting migration to a free decode slot.

    ``pump()`` drains the queue head-first each step through
    ``DisaggEngine._migrate`` (which prices the hand-off through the
    transfer IOMMU and re-attaches pages/tables/buffer row). A copy-mode
    transfer that cannot back its fresh pages — the pool raises
    ``OutOfPages``, or the duplicate would eat the headroom this step's
    decode growth needs — defers WITHOUT mutating anything; the engine
    breaks a true deadlock (blocked transfer + idle decode worker) by
    force-preempting the newest prefill. A preempted sequence's queued
    transfer is cancelled (the engine's trace hook calls :meth:`cancel`)
    and re-queued when its resume finishes prefill again."""

    def __init__(self, engine, mode: str, decode_slots: Sequence[int]):
        self.engine = engine
        self.mode = mode
        self.queue: Deque[int] = deque()
        self._queued: Set[int] = set()
        self.free_decode = list(decode_slots)  # pop from the tail
        self.blocked = False                   # last pump hit OutOfPages
        self.transfers = 0
        self.deferred = 0
        self.cancelled = 0

    def enqueue(self, seq_id: int) -> None:
        if seq_id not in self._queued:
            self._queued.add(seq_id)
            self.queue.append(seq_id)

    def cancel(self, seq_id: int) -> None:
        """The prefill worker preempted a sequence with a pending
        transfer: its KV is gone, so the transfer must not run. The
        resume's hand-off detection re-queues it."""
        if seq_id in self._queued:
            self._queued.discard(seq_id)
            self.queue.remove(seq_id)
            self.cancelled += 1

    def pump(self) -> None:
        mgr = self.engine.mgr
        self.blocked = False
        while self.queue and self.free_decode:
            sid = self.queue[0]
            if self.mode == "copy":
                # Don't let the duplicate starve this step's decode
                # appends: they cannot wait (OutOfPages mid-step), a
                # transfer can.
                need = len(mgr.seqs[sid].pages)
                if (mgr.free_page_headroom() - need
                        < mgr.next_step_page_demand()):
                    self.blocked = True
                    self.deferred += 1
                    break
            try:
                self.engine._migrate(sid, self.free_decode[-1])
            except OutOfPages:
                self.blocked = True
                self.deferred += 1
                break
            self.queue.popleft()
            self._queued.discard(sid)
            self.free_decode.pop()
            self.transfers += 1

    def stats(self) -> dict:
        return {"transfers": self.transfers, "deferred": self.deferred,
                "cancelled": self.cancelled, "pending": len(self.queue),
                "free_decode_slots": len(self.free_decode)}
