"""Disaggregated prefill/decode serving over the shared SVA layer.

Production serving splits prefill (compute-bound, bursty) and decode
(memory-bound, steady) onto separate workers; the cost of the split is
moving each finished prompt's paged KV from the prefill worker's address
space to the decode worker's. This package models that hand-off the way
the paper's SVA argument says it should be modeled: as virtual-address
remote DMA through an IOMMU — the transfer's cost is per-page
TRANSLATION (PTW/IOTLB under the existing walk models) plus, only in the
copy baseline, the full KV payload. Under shared virtual addressing the
payload term vanishes (``share`` mode: refcount + table hand-off), which
is exactly the zero-copy-offload result at cross-worker scale.

Single-process model: both workers live in one engine over ONE
``PagePool``/``IOMMU`` namespace, partitioned by slot (ASID). See
:mod:`repro.core.serving.disagg.engine` for the step pipeline and
ARCHITECTURE.md "Disaggregated serving" for the design notes.
"""
from repro.core.serving.disagg.engine import DisaggEngine
from repro.core.serving.disagg.workers import (DecodeWorker, KVTransferEngine,
                                               PrefillScheduler, PrefillWorker)

__all__ = ["DisaggEngine", "PrefillWorker", "DecodeWorker",
           "KVTransferEngine", "PrefillScheduler"]
