"""Disaggregated serving front-end: one engine, two workers, one pool.

``DisaggEngine`` splits the continuous engine's slot range into a prefill
worker (slots ``[0, n_prefill)``, driven by a :class:`PrefillScheduler`)
and a decode worker (slots ``[n_prefill, n_slots)``, reserved away from
admission), connected by a :class:`KVTransferEngine`. Each step runs the
pipeline

    admit/resume -> chunked prefill -> detect finished prefills ->
    pump transfers (migrate KV prefill-ASID -> decode-ASID) ->
    masked decode over the decode worker's slots

Migration goes through ``PagedKVManager.migrate``: the source ASID
translates every page through the transfer IOMMU (modeled remote DMA —
PTW/IOTLB cost in the ``transfer:`` stats block), then either re-attaches
the pages zero-copy (``share``: ``PagePool.share`` + table hand-off) or
duplicates them device-side (``copy``: batched through the engine's CoW
kernel). Because the device batch runs at FULL slot width with
non-decoding rows masked, and chunk composition/slot placement never
change token values, the disaggregated engine's outputs are bit-identical
to the colocated continuous engine at equal total width — asserted by
``benchmarks/disagg_serving.py`` and ``tests/test_disagg.py``.

Trace: migrations append ``("xfer", sid, n_pages, mode)`` followed by the
source ``unmap`` and destination ``map`` events, so a recorded trace
replays through ``benchmarks/trace_replay.py`` unchanged.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.core.serving.disagg.workers import (DecodeWorker, KVTransferEngine,
                                               PrefillScheduler, PrefillWorker)
from repro.core.serving.engine import Request, ServingEngine
from repro.core.serving.scheduler import SchedulerOutput, WaitingSeq
from repro.core.sva.iommu import IOMMU
from repro.models import MeshInfo, NO_MESH


class DisaggEngine(ServingEngine):
    """Prefill/decode-disaggregated continuous engine (single process)."""

    def __init__(self, cfg: ModelConfig, params, n_prefill_slots: int,
                 n_decode_slots: int, max_len: int, page_size: int = 8,
                 mi: MeshInfo = NO_MESH, disagg_mode: str = "share",
                 xfer_iommu: Optional[IOMMU] = None, **kw):
        if disagg_mode not in ("share", "copy"):
            raise ValueError(f"disagg_mode={disagg_mode!r} "
                             "(expected 'share' or 'copy')")
        if n_prefill_slots < 1 or n_decode_slots < 1:
            raise ValueError("need >= 1 prefill and >= 1 decode slot "
                             f"(got {n_prefill_slots}/{n_decode_slots})")
        self.disagg_mode = disagg_mode
        # The transfer fabric's IOMMU (e.g. a 4-entry IOTLB over Sv39Walk)
        # prices migrations; None prices them through the manager's own.
        self.xfer_iommu = xfer_iommu
        self.xfer_engine: Optional[KVTransferEngine] = None
        super().__init__(cfg, params,
                         n_slots=n_prefill_slots + n_decode_slots,
                         max_len=max_len, page_size=page_size, mi=mi,
                         scheduler="continuous", **kw)
        self.n_prefill_slots = n_prefill_slots
        self.n_decode_slots = n_decode_slots
        prefill_slots = list(range(n_prefill_slots))
        decode_slots = list(range(n_prefill_slots, self.n_slots))
        # The prefill worker's scheduler replaces the colocated one: same
        # admission/preemption machinery, no decode composition, preemption
        # floor 0 (decode growth may reclaim every prefill page).
        self.sched = PrefillScheduler(self.mgr, self.buffer,
                                      cfg.sched_token_budget,
                                      cfg.sched_prefill_chunk,
                                      share_tokens=self._can_share,
                                      on_event=self._trace_event)
        # Decode slots never appear in admission: migration targets them.
        self.mgr.reserve_slots(decode_slots)
        self.prefill_worker = PrefillWorker(prefill_slots, self.sched,
                                            self.buffer, self.mgr)
        self.decode_worker = DecodeWorker(decode_slots, self.buffer)
        self.xfer_engine = KVTransferEngine(self, disagg_mode, decode_slots)

    # ------------------------------------------------------------ step
    def _continuous_step(self):
        # Pending device page copies (CoW divergences AND copy-mode
        # transfer payloads) must land before anything can recycle their
        # source pages — same invariant as the colocated step.
        self._apply_cow()
        while self.queue:
            req = self.queue.popleft()
            self.sched.submit(req.req_id, req.prompt, req.max_tokens,
                              tenant=req.tenant)
            self._waiting_reqs[req.req_id] = req
        t0 = time.perf_counter()
        out = self.sched.schedule()
        self.metrics["admit_s"] += time.perf_counter() - t0
        for sid, folded in out.preempted:
            req = self.active.pop(sid)
            req.out_tokens.extend(folded)
            self._waiting_reqs[sid] = req
        for sid in out.admitted + out.resumed:
            self.active[sid] = self._waiting_reqs.pop(sid)
        if out.chunks:
            self._chunk_prefill(out.chunks)
        # Prefill-complete sequences queue for migration; the pump moves
        # as many as free decode slots (and, copy mode, pool headroom)
        # allow this step.
        for sid in self.prefill_worker.ready_for_handoff():
            self.xfer_engine.enqueue(sid)
        self.xfer_engine.pump()
        # Copy-mode deadlock break: a blocked transfer with an IDLE decode
        # worker can never unblock on its own (nothing downstream will
        # free pages) — force-preempt the newest prefill until the oldest
        # queued transfer fits. Terminates: each preempt shrinks running.
        while (self.xfer_engine.blocked and not self.decode_worker.running
               and len(self.sched.running) > 1):
            sid, folded = self.sched._preempt_one()
            req = self.active.pop(sid)
            req.out_tokens.extend(folded)
            self._waiting_reqs[sid] = req
            self.xfer_engine.pump()
        # Decode-side preemption: the prefill scheduler's pressure loop
        # only sees ITS running sequences, but decode growth (page-boundary
        # appends, CoW divergences) draws on the same pool. When demand
        # still exceeds headroom after the prefill side yielded everything
        # it can, the newest decode sequence preempts back to the waiting
        # queue (same fold/pending/rebase discipline as the scheduler's) —
        # it re-prefills from warm prefix pages and transfers again.
        while (self.decode_worker.running
               and len(self.decode_worker.running)
               + len(self.sched.running) > 1
               and self.mgr.next_step_page_demand()
               > self.mgr.free_page_headroom()):
            self._preempt_decode_one()
        dec = SchedulerOutput(decode_slots=self.decode_worker.decode_slots())
        dec.n_decode_tokens = len(dec.decode_slots)
        self._decode_continuous(dec)

    def _preempt_decode_one(self) -> None:
        """Preempt the newest decode-worker sequence under pool pressure:
        exactly one token is pending (never KV-written) — it becomes the
        resume's re-injected first token; every other known token is
        KV-resident and becomes the resume prompt. The freed decode slot
        returns to the transfer engine."""
        sid = self.decode_worker.running[-1]
        slot = self.buffer.slot_of(sid)
        st = self.mgr.seqs[sid]
        toks = self.buffer.tokens(slot)
        resident = toks[:-1]
        ws = WaitingSeq(sid, resident, st.max_tokens - len(st.tokens) + 1,
                        pending=toks[-1], preempted=True)
        folded = list(st.tokens[:-1])
        self._trace_event(("preempt", sid))
        n_pages = len(st.pages)
        self.mgr.preempt(sid, resident)
        self._trace_event(("unmap", slot, n_pages))
        self.decode_worker.running.pop()
        self.buffer.detach(slot)
        self.sched.waiting.appendleft(ws)
        self.sched.preemptions += 1
        req = self.active.pop(sid)
        req.out_tokens.extend(folded)
        self._waiting_reqs[sid] = req
        # preempt() returned the slot to general admission; reclaim it as
        # a migration target.
        self.mgr.reserve_slots([slot])
        self.xfer_engine.free_decode.append(slot)

    # ------------------------------------------------------------ migrate
    def _migrate(self, seq_id: int, dst_slot: int) -> None:
        """Move one finished prefill to the decode worker: manager-level
        page/ASID migration (priced through the transfer IOMMU), then the
        buffer row re-attaches on the decode side with the prompt resident
        and exactly the first generated token pending — the same decoding
        invariant a colocated sequence has after its final chunk."""
        st = self.mgr.seqs[seq_id]
        src_slot = st.slot
        toks = self.buffer.tokens(src_slot)
        n_pages = len(st.pages)
        # Raises OutOfPages (copy mode) with nothing mutated; pump defers.
        self.mgr.migrate(seq_id, dst_slot, mode=self.disagg_mode,
                         xfer_iommu=self.xfer_iommu)
        self.sched.handoff(seq_id)
        self.buffer.detach(src_slot)
        self.buffer.attach(dst_slot, seq_id, toks[:-1],
                           prefill_start=len(toks) - 1)
        self.buffer.append(dst_slot, toks[-1])
        self.decode_worker.running.append(seq_id)
        if self.translation_trace is not None:
            new_pages = list(self.mgr.seqs[seq_id].pages)
            fresh = new_pages if self.disagg_mode == "copy" else []
            self.translation_trace.append(
                ("xfer", seq_id, n_pages, self.disagg_mode))
            self.translation_trace.append(("unmap", src_slot, n_pages))
            self.translation_trace.append(("map", fresh, dst_slot,
                                           new_pages))

    # ------------------------------------------------------------ hooks
    def _trace_event(self, ev: tuple) -> None:
        # A preempted sequence's KV is gone: cancel its queued transfer
        # (it re-queues when the resume finishes prefill). This must run
        # whether or not a trace is being recorded.
        if ev and ev[0] == "preempt" and self.xfer_engine is not None:
            self.xfer_engine.cancel(ev[1])
        super()._trace_event(ev)

    def _resident_tokens(self) -> Dict[int, int]:
        resident = super()._resident_tokens()
        for sid in self.decode_worker.running:
            resident[sid] = self.mgr.seqs[sid].length
        return resident

    def _release_done(self, finished: Dict[int, Request]) -> None:
        for rid in [r for r, q in self.active.items()
                    if self.mgr.seqs[r].done]:
            req = self.active.pop(rid)
            req.done_at = time.perf_counter()
            st = self.mgr.seqs[rid]
            slot = st.slot
            req.out_tokens.extend(st.tokens)
            if rid in self.decode_worker.running:
                self.decode_worker.finish(rid)
            else:
                # Completed at prefill (max_tokens == 1 / EOS first token):
                # never migrated, still the prefill scheduler's.
                self.sched.finish(rid)
            if self.translation_trace is not None:
                self.translation_trace.append(
                    ("unmap", slot, len(st.pages)))
            self.mgr.release(rid)
            finished[rid] = req
            if slot in self.decode_worker.slots:
                # release() returned the slot to general admission; pull it
                # back out — decode slots are only ever migration targets.
                self.mgr.reserve_slots([slot])
                self.xfer_engine.free_decode.append(slot)

    def stats(self) -> dict:
        s = super().stats()
        block = {"mode": self.disagg_mode,
                 "prefill_slots": self.n_prefill_slots,
                 "decode_slots": self.n_decode_slots,
                 "decoding": len(self.decode_worker.running),
                 **self.xfer_engine.stats()}
        if self.xfer_iommu is not None:
            block["xfer_iommu"] = self.xfer_iommu.stats()
        s["disagg"] = block
        return s
