"""Token-budget continuous-batching scheduler over the paged SVA layer.

Every engine step the scheduler composes ONE mixed batch: all decoding
sequences contribute their next token, and remaining budget is spent on
chunked-prefill slices of sequences still computing their prompt KV
(vLLM/eSurge-style continuous batching). The composition is driven by two
knobs (``ModelConfig.sched_*``):

  token_budget    target tokens processed per step (decodes count 1 each;
                  chunks consume the rest — decodes are never dropped, so
                  a step with more decoding sequences than budget still
                  decodes them all in the one batched call)
  prefill_chunk   per-sequence cap on prompt tokens prefilled per step

Chunk spans are PAGE-ALIGNED at non-final boundaries: the suffix-prefill
scatter writes whole pages, and the next chunk's prefix-read then never
straddles a half-written page. The final chunk ends exactly at the prompt
length and produces the sequence's first token.

Admission is LAZY (``PagedKVManager.admit(lazy=True)``): only the prompt's
pages are allocated up front; decode growth allocates page-by-page. That
admits more concurrent sequences than the fixed-slot engine's full
``prompt+max_tokens`` reservation — the continuous engine's throughput win
— at the cost of possible pool exhaustion mid-decode, which preemption
resolves:

  preempt   when the next step's page demand (decode appends crossing page
            boundaries, CoW divergences) exceeds the pool's headroom (free
            pages + evictable warm prefix-cache pages), the NEWEST-admitted
            running sequence is preempted: its computed KV is registered in
            the prefix index (warm pages an immediate resume re-matches),
            its slot/pages/ASID are torn down exactly like a release, and
            its known tokens go back to the FRONT of the waiting queue.
  resume    re-admission of a preempted sequence: the prompt becomes every
            KV-resident token it had (original prompt + generated tokens
            minus the one pending token), ``max_tokens`` is rebased so the
            generation budget is unchanged, and the pending token is
            re-injected by the final chunk instead of an argmax — so a
            preempt/resume round-trip is bit-identical to never having
            been preempted, whether the KV re-matches warm pages or is
            recomputed from tokens. Re-admission goes through the same
            ``PagedKVManager.admit`` path as a fresh sequence, so its
            fresh pages are re-allocated with the contiguity hint
            (``PagePool.alloc_run``) — a resumed sequence re-tries
            contiguous placement and stays eligible for range-coalesced
            IOTLB entries even after its original run was torn down.

The scheduler mutates manager state (admit/preempt/resume) and the
:class:`~repro.core.serving.sequence_buffer.SequenceBuffer`, and returns a
:class:`SchedulerOutput` that drives the engine's device step. Page-pool
verbs stay inside the manager (svalint R002); translation-trace events
(``preempt``/``resume``/``map``/``unmap``) are emitted through ``on_event``
so the engine's recorded trace stays replayable across preemptions.

Same-step sharing note: under chunked prefill the prefix index is fed
PROGRESSIVELY (pages register as their chunks complete — see
``PagedKVManager.register_progress``), so an admission can only match KV
that is actually resident. Two identical prompts admitted in the SAME
schedule() call therefore do not share (the fixed engine's admission waves
would); they share from the next step on.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core.serving.sequence_buffer import SequenceBuffer
from repro.core.sva.kv_manager import PagedKVManager


@dataclass
class WaitingSeq:
    """One queued (or preempted-awaiting-resume) sequence."""
    seq_id: int
    tokens: List[int]             # prompt; for a preempted decoding seq,
                                  # every KV-resident token (prompt + gen[:-1])
    max_tokens: int               # remaining generation budget (rebased)
    pending: Optional[int] = None  # decode token to re-inject on resume
    preempted: bool = False
    tenant: Optional[str] = None   # owning TenantDomain (None = untenanted)


@dataclass
class ChunkSpan:
    """One chunked-prefill slice: prompt positions ``[start, end)`` of the
    sequence in ``slot``. ``pending`` (final chunks of resumed sequences
    only) replaces the argmax first token."""
    seq_id: int
    slot: int
    start: int
    end: int
    is_final: bool
    pending: Optional[int] = None


@dataclass
class SchedulerOutput:
    """What one step runs — scheduled ids, chunk spans, preempted/resumed
    ids — consumed by ``ServingEngine._continuous_step``."""
    decode_slots: List[int] = field(default_factory=list)
    chunks: List[ChunkSpan] = field(default_factory=list)
    admitted: List[int] = field(default_factory=list)
    resumed: List[int] = field(default_factory=list)
    # (seq_id, generated tokens folded into the resume prompt) — the engine
    # moves the folded tokens to Request.out_tokens at preemption time
    preempted: List[Tuple[int, List[int]]] = field(default_factory=list)
    n_decode_tokens: int = 0
    n_chunk_tokens: int = 0


class Scheduler:
    """Token-budget step composer (see module docstring)."""

    def __init__(self, mgr: PagedKVManager, buffer: SequenceBuffer,
                 token_budget: int, prefill_chunk: int,
                 share_tokens: bool = True,
                 on_event: Optional[Callable[[tuple], None]] = None):
        if token_budget < 1:
            raise ValueError(f"token_budget={token_budget} (need >= 1)")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk={prefill_chunk} (need >= 1)")
        self.mgr = mgr
        self.buffer = buffer
        self.token_budget = token_budget
        self.prefill_chunk = prefill_chunk
        self.share_tokens = share_tokens
        self.on_event = on_event
        self.waiting: Deque[WaitingSeq] = deque()
        self.running: List[int] = []          # admission order = priority
        self._pending_tok: Dict[int, Optional[int]] = {}
        # Preemption floor: the colocated scheduler never preempts its
        # oldest running sequence (IT is the forward progress). A
        # disaggregated prefill worker lowers this to 0 — there the decode
        # worker carries forward progress, and under pool pressure every
        # prefill must be able to yield its pages to decode growth.
        self.min_running = 1
        self.preemptions = 0
        self.resumes = 0

    # ----------------------------------------------------------------- API
    def submit(self, seq_id: int, prompt: List[int], max_tokens: int,
               tenant: Optional[str] = None) -> None:
        if not prompt:
            raise ValueError("continuous scheduling needs a non-empty prompt")
        # reject-never-wrap; with a tenant this also rejects requests that
        # can never fit the tenant's page quota
        self.mgr.ensure_fits(len(prompt), max_tokens, tenant=tenant)
        self.waiting.append(WaitingSeq(seq_id, list(prompt), max_tokens,
                                       tenant=tenant))

    def finish(self, seq_id: int) -> None:
        """A sequence completed (the engine releases it): drop scheduler +
        buffer state. Called BEFORE ``PagedKVManager.release``."""
        self.running.remove(seq_id)
        self._pending_tok.pop(seq_id, None)
        self.buffer.detach(self.buffer.slot_of(seq_id))

    def handoff(self, seq_id: int) -> None:
        """Forget a sequence WITHOUT touching manager or buffer state — the
        disaggregated front-end migrates its KV to a decode worker and
        re-attaches the buffer row itself. The sequence simply stops being
        this scheduler's to run."""
        self.running.remove(seq_id)
        self._pending_tok.pop(seq_id, None)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ schedule
    def schedule(self) -> SchedulerOutput:
        out = SchedulerOutput()
        # 1. Guarantee the step's page demand: every decoding sequence
        #    appends one token (possible page growth / CoW allocation).
        #    Preempt newest-first until the pool (after prefix-cache
        #    eviction) can satisfy it; the oldest running sequence is never
        #    preempted (guaranteed forward progress).
        while (len(self.running) > self.min_running
               and self.mgr.next_step_page_demand()
               > self.mgr.free_page_headroom()):
            out.preempted.append(self._preempt_one())
        # 1b. Quota pressure (multi-tenant only): decode appends are never
        #     blocked on a quota — a mid-step allocation can't wait — so a
        #     tenant can drift over its page quota through decode growth
        #     and CoW. Shed the over-quota tenant's NEWEST sequences until
        #     it is back under, always sparing its oldest running sequence
        #     (whose completions are what drain the debt; preempting the
        #     last one would just thrash preempt/resume).
        if self.mgr.tenant_specs:
            for t in self.mgr.tenants_over_quota():
                mine = [sid for sid in self.running
                        if self.mgr.seqs[sid].tenant == t]
                for sid in reversed(mine[1:]):
                    if (len(self.running) <= self.min_running
                            or self.mgr.tenant_pages_used(t)
                            <= self.mgr.tenant_quota(t)):
                        break
                    out.preempted.append(self._preempt_sid(sid))
        # 2. Resume/admit from the waiting queue (preempted sequences sit at
        #    the front). Don't admit into the headroom the running
        #    sequences' growth needs — that admission would be preempted
        #    right back next step. With tenants, quota-blocked entries are
        #    skipped (not head-of-line blocking the other tenants) — FIFO
        #    order is preserved among the eligible.
        while self.waiting:
            idx = 0
            if self.mgr.tenant_specs:
                idx = next((i for i, w in enumerate(self.waiting)
                            if not self._quota_blocked(w)), -1)
                if idx < 0:
                    break       # everyone waiting is over quota: wait
            ws = self.waiting[idx]
            need = -(-len(ws.tokens) // self.mgr.page_size)
            if len(ws.tokens) % self.mgr.page_size == 0:
                # The final chunk's first-token append lands one past the
                # prompt: when the prompt exactly fills its pages that
                # append allocates ANOTHER page the ceil above misses —
                # admitting against it drains the pool mid-step (decode
                # appends can't wait; they'd hit OutOfPages).
                need += 1
            if (self.running
                    and self.mgr.free_page_headroom() - need
                    < self.mgr.next_step_page_demand()):
                break
            if ws.preempted:
                st = self.mgr.resume(
                    ws.seq_id, len(ws.tokens), ws.max_tokens,
                    tokens=ws.tokens if self.share_tokens else None,
                    tenant=ws.tenant)
            else:
                st = self.mgr.admit(
                    ws.seq_id, len(ws.tokens), ws.max_tokens,
                    tokens=ws.tokens if self.share_tokens else None,
                    lazy=True, tenant=ws.tenant)
            if st is None:
                break                       # no slot/pages: keep waiting
            del self.waiting[idx]           # idx==0 unless quota-skipping
            self.buffer.attach(st.slot, ws.seq_id, ws.tokens,
                               st.prefill_start)
            self.running.append(ws.seq_id)
            self._pending_tok[ws.seq_id] = ws.pending
            if ws.preempted:
                self.resumes += 1
                out.resumed.append(ws.seq_id)
                self._emit(("resume", ws.seq_id, list(st.pages)))
            else:
                out.admitted.append(ws.seq_id)
            self._emit(("map", list(st.pages[st.shared_pages:]),
                        st.slot, list(st.pages)))
        # 3. Compose the mixed step under the token budget.
        for sid in self.running:
            slot = self.buffer.slot_of(sid)
            if self._decodes_here(sid, slot):
                out.decode_slots.append(slot)
        out.n_decode_tokens = len(out.decode_slots)
        budget = self.token_budget - out.n_decode_tokens
        p = self.mgr.page_size
        for sid in self.running:
            if budget <= 0:
                break
            slot = self.buffer.slot_of(sid)
            if self.buffer.is_decoding(slot):
                continue
            s = int(self.buffer.n_computed[slot])
            prompt_len = int(self.buffer.prompt_lens[slot])
            remaining = prompt_len - s
            take = min(budget, self.prefill_chunk, remaining)
            if take == remaining:
                e = prompt_len
            else:
                e = ((s + take) // p) * p    # non-final chunks end on a page
                if e <= s:
                    continue                 # no budget for a full page
            pend = self._pending_tok.pop(sid, None) if e == prompt_len \
                else None
            out.chunks.append(ChunkSpan(sid, slot, s, e, e == prompt_len,
                                        pend))
            budget -= e - s
            out.n_chunk_tokens += e - s
        return out

    def _decodes_here(self, seq_id: int, slot: int) -> bool:
        """Does this sequence decode on THIS scheduler's worker? The base
        (colocated) scheduler decodes every sequence that finished prefill;
        a disaggregated prefill worker overrides this to False — finished
        prefills wait (still preemptible) for the transfer engine to
        migrate them to the decode worker."""
        return self.buffer.is_decoding(slot)

    def _quota_blocked(self, ws: WaitingSeq) -> bool:
        """Would admitting ``ws`` right now push its tenant over quota?
        Mirrors ``admit``'s transient quota gate for the lazy page need, so
        the waiting-queue scan skips entries that would just bounce."""
        quota = self.mgr.tenant_quota(ws.tenant)
        if not quota:
            return False
        need = max(-(-len(ws.tokens) // self.mgr.page_size), 1)
        return self.mgr.tenant_pages_used(ws.tenant) + need > quota

    # ------------------------------------------------------------ preempt
    def _preempt_one(self) -> Tuple[int, List[int]]:
        """Preempt the newest-admitted running sequence: register its
        computed KV for re-match, tear down its slot/pages/ASID, and queue
        it (front) for resume. Returns (seq_id, folded generated tokens)."""
        return self._preempt_sid(self.running[-1])

    def _preempt_sid(self, sid: int) -> Tuple[int, List[int]]:
        """Preempt a specific running sequence (quota preemption picks by
        tenant, not strictly newest-overall)."""
        self.running.remove(sid)
        slot = self.buffer.slot_of(sid)
        st = self.mgr.seqs[sid]
        pending = self._pending_tok.pop(sid, None)
        if self.buffer.is_decoding(slot):
            # Exactly one token is pending (never KV-written): it becomes
            # the resume's re-injected first token; every other known token
            # is KV-resident and becomes the resume prompt.
            toks = self.buffer.tokens(slot)
            resident = toks[:-1]
            ws = WaitingSeq(sid, resident,
                            st.max_tokens - len(st.tokens) + 1,
                            pending=toks[-1], preempted=True)
            folded = list(st.tokens[:-1])
        else:
            # Mid-prefill: KV is resident for the computed chunk prefix
            # only; the resume re-admits the original prompt (re-matching
            # the registered chunks) with its budget untouched. ``pending``
            # survives a second preemption of a not-yet-resumed sequence.
            prompt = self.buffer.tokens(slot)
            resident = prompt[:int(self.buffer.n_computed[slot])]
            ws = WaitingSeq(sid, prompt, st.max_tokens,
                            pending=pending, preempted=True)
            folded = []
        self._emit(("preempt", sid))
        n_pages = len(st.pages)
        self.mgr.preempt(sid, resident)
        self._emit(("unmap", slot, n_pages))
        self.buffer.detach(slot)
        self.waiting.appendleft(ws)
        self.preemptions += 1
        return sid, folded

    def _emit(self, ev: tuple) -> None:
        if self.on_event is not None:
            self.on_event(ev)

    def stats(self) -> dict:
        return {"preemptions": self.preemptions, "resumes": self.resumes,
                "waiting": len(self.waiting), "running": len(self.running)}
