"""Dense per-slot sequence state for the continuous-batching engine.

The fixed-slot engine keeps request state in per-``Request`` Python lists;
every step walks dicts to assemble the decode batch. Continuous batching
(``core/serving/scheduler.py``) composes a *mixed* step — decode tokens
plus chunked-prefill slices — every iteration, so step assembly must be
vectorized: this buffer holds one contiguous numpy row per batch slot
(token ids, counts, prefill progress, slot-mapping metadata) and derives
the per-step arrays (last decode token per slot, decode mask, chunk token
slices) with array ops instead of Python-object walks.

Layout (all arrays indexed by SLOT, the same index as the manager's block
tables and the device cache rows — one slot == one ASID):

  token_ids   (n_slots, max_len) int32   prompt then generated tokens
  n_tokens    (n_slots,) int32           known tokens (prompt + generated)
  n_computed  (n_slots,) int32           prompt positions whose KV is
                                         resident (chunked-prefill progress;
                                         == prompt_lens once decoding)
  prompt_lens (n_slots,) int32           prompt length of the resident seq
  seq_ids     (n_slots,) int64           owning sequence id, -1 = free

The jit'd step functions never see this object — the engine feeds them
padded arrays derived here, so precompiled shapes stay stable (power-of-two
token buckets, fixed batch width). Host-side only: nothing in this module
is jit-traced.

Invariants (pinned by ``tests/test_scheduler.py``):
  * a DECODING slot (``n_computed == prompt_lens``) has ``n_tokens >=
    prompt_lens + 1``: exactly one token is pending (fed to the next decode
    step), matching the manager's ``SeqState.length`` bookkeeping;
  * a PREFILLING slot has ``n_tokens == prompt_lens`` (no appends until the
    final chunk produces the first token);
  * ``detach`` zeroes the row, so a recycled slot can never leak a dead
    sequence's tokens into a padded batch.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class SequenceBuffer:
    """Contiguous per-slot sequence state (see module docstring)."""

    def __init__(self, n_slots: int, max_len: int):
        if n_slots < 1:
            raise ValueError(f"n_slots={n_slots} (need >= 1)")
        if max_len < 1:
            raise ValueError(f"max_len={max_len} (need >= 1)")
        self.n_slots = n_slots
        self.max_len = max_len
        self.token_ids = np.zeros((n_slots, max_len), np.int32)
        self.n_tokens = np.zeros((n_slots,), np.int32)
        self.n_computed = np.zeros((n_slots,), np.int32)
        self.prompt_lens = np.zeros((n_slots,), np.int32)
        self.seq_ids = np.full((n_slots,), -1, np.int64)
        self._slot_by_seq: Dict[int, int] = {}

    # ------------------------------------------------------------ lifecycle
    def attach(self, slot: int, seq_id: int, tokens: List[int],
               prefill_start: int = 0) -> None:
        """Bind ``seq_id`` to ``slot`` with its prompt tokens. A prefix-cache
        match sets ``prefill_start`` (leading positions whose KV is already
        resident — chunked prefill starts there)."""
        if self.seq_ids[slot] >= 0:
            raise ValueError(f"slot {slot} already holds seq "
                             f"{int(self.seq_ids[slot])}")
        n = len(tokens)
        if n > self.max_len:
            raise ValueError(f"prompt of {n} tokens exceeds max_len="
                             f"{self.max_len}")
        self.token_ids[slot, :n] = tokens
        self.token_ids[slot, n:] = 0
        self.n_tokens[slot] = n
        self.n_computed[slot] = prefill_start
        self.prompt_lens[slot] = n
        self.seq_ids[slot] = seq_id
        self._slot_by_seq[seq_id] = slot

    def detach(self, slot: int) -> None:
        sid = int(self.seq_ids[slot])
        if sid >= 0:
            self._slot_by_seq.pop(sid, None)
        self.token_ids[slot] = 0
        self.n_tokens[slot] = 0
        self.n_computed[slot] = 0
        self.prompt_lens[slot] = 0
        self.seq_ids[slot] = -1

    # ------------------------------------------------------------- updates
    def append(self, slot: int, token: int) -> None:
        """Record one generated token (decode output, or the final chunk's
        first token)."""
        n = int(self.n_tokens[slot])
        if n >= self.max_len:
            raise ValueError(f"slot {slot} overflows max_len={self.max_len}")
        self.token_ids[slot, n] = token
        self.n_tokens[slot] = n + 1

    def advance(self, slot: int, computed: int) -> None:
        """Mark prompt positions ``[0, computed)`` as KV-resident (a chunk
        completed). Monotonic; capped by the prompt length."""
        cur = int(self.n_computed[slot])
        if computed < cur or computed > int(self.prompt_lens[slot]):
            raise ValueError(
                f"slot {slot}: advance to {computed} out of range "
                f"[{cur}, {int(self.prompt_lens[slot])}]")
        self.n_computed[slot] = computed

    # -------------------------------------------------------------- queries
    def slot_of(self, seq_id: int) -> int:
        return self._slot_by_seq[seq_id]

    def is_decoding(self, slot: int) -> bool:
        return (self.seq_ids[slot] >= 0
                and self.n_computed[slot] >= self.prompt_lens[slot])

    def tokens(self, slot: int) -> List[int]:
        """All known tokens of the resident sequence (prompt + generated)."""
        return self.token_ids[slot, :int(self.n_tokens[slot])].tolist()

    def chunk_tokens(self, slot: int, start: int, end: int) -> np.ndarray:
        """Prompt token slice ``[start, end)`` for a chunked-prefill span."""
        return self.token_ids[slot, start:end]

    # -------------------------------------------------- step assembly (vec)
    def last_tokens(self) -> np.ndarray:
        """(n_slots,) int32: each slot's latest known token (0 for free
        slots) — the decode step's input token, gathered in one op."""
        idx = np.maximum(self.n_tokens - 1, 0)
        out = self.token_ids[np.arange(self.n_slots), idx]
        return np.where(self.n_tokens > 0, out, 0).astype(np.int32)

    def decode_mask(self) -> np.ndarray:
        """(n_slots,) bool: slots whose sequence finished prefill (decode
        candidates)."""
        return (self.seq_ids >= 0) & (self.n_computed >= self.prompt_lens)

    @property
    def n_active(self) -> int:
        return int((self.seq_ids >= 0).sum())
