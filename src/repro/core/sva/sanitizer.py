"""svasan — an ASan-style shadow-state sanitizer for the paged SVA stack.

The paper's zero-copy argument only holds if translation state and page
ownership never diverge: an IOTLB entry that outlives its unmap, a write
into a still-shared page, or a double-freed pool page silently corrupts the
very KV data the PTW numbers are measured over. The tier-1 tests pin these
invariants down at the API level; svasan checks them *during every
operation* with an independent shadow copy of the state, so a future
refactor (the continuous-batching scheduler is next) that breaks the
discipline fails loudly at the faulting operation, not three layers later.

Shadow model — one record per physical page, per attached pool:

    FREE   --alloc-->  OWNED  --share-->  SHARED
    FREE  <--free(rc->0)--  OWNED  <--free(rc->1)--  SHARED

with a shadow refcount mirroring (never reading) ``PagePool._ref``.

Detectors (each has an injected-bug test in tests/test_svasan.py):

  double-free               ``free()`` of a page whose shadow state is FREE
  translate-after-unmap     a TLB *hit* for an attached ASID whose live
                            table no longer maps the page (the entry
                            outlived its invalidation), or whose table
                            disagrees with the cached physical page (a
                            remap's invalidation was skipped)
  cow-bypass-write          a decode append about to write a page whose
                            shadow state is SHARED (CoW/steal didn't run)
  stale-prefetch            a prefetch fill installed for, or surviving
                            past, a dead mapping (in-flight fills must die
                            with their unmap/detach)
  stale-range               a range-coalesced IOTLB entry ``(asid, base,
                            n)`` still *covers* a logical page after its
                            unmap — the range outlived the split that the
                            partial invalidation should have forced
  leak-at-release           ``PagedKVManager.release`` returned without
                            dropping the sequence's reference on one of its
                            pages
  cross-tenant-translate    a translation reached the TLB under a tenant
                            identity that does not own the ASID — the
                            multi-tenant isolation gate in
                            ``IOMMU.translate`` was bypassed (svasan
                            re-derives ownership from the tenant registry
                            independently, so a patched-out gate is still
                            caught)

Enabling: set ``REPRO_SVASAN=1`` in the environment (the CI tier-1 job
does), or pass the explicit knobs — ``PagedKVManager(sanitize=True)`` /
``SVASpace(sanitize=True)`` / ``ModelConfig(svasan=True)`` /
``SimConfig(svasan=True)``. Off (the default) the hook sites reduce to one
``is not None`` test each and the stack is bit-identical to the
pre-sanitizer tree; on, svasan only *observes* — it never mutates pool,
TLB, or table state, so clean runs produce identical outputs too.

A violation raises :class:`SanitizerError` carrying a structured
:class:`SvasanReport` (detector, page/key, shadow state, hint); construct
``SVASanitizer(raise_on_report=False)`` to collect reports instead (the
``reports`` list), e.g. to scan for multiple violations in one run.

Stats schema (``SVASanitizer.stats()``; see ARCHITECTURE.md):
pages_tracked / checks / reports.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional, Tuple

if TYPE_CHECKING:                                  # pragma: no cover
    from repro.core.sva.iommu import IOMMU
    from repro.core.sva.page_pool import PagePool

#: shadow page states
FREE, OWNED, SHARED = "FREE", "OWNED", "SHARED"


def enabled_by_env() -> bool:
    """True when ``REPRO_SVASAN`` is set to anything but ''/'0'."""
    return os.environ.get("REPRO_SVASAN", "") not in ("", "0")


def resolve(sanitize: Optional[bool]) -> bool:
    """Resolve a three-state knob: explicit True/False wins, ``None``
    defers to the ``REPRO_SVASAN`` environment variable."""
    return enabled_by_env() if sanitize is None else bool(sanitize)


@dataclass(frozen=True)
class SvasanReport:
    """One detected violation — the precise, machine-readable record the
    injected-bug tests assert on."""
    detector: str                 # double-free | translate-after-unmap | ...
    page: Optional[int]           # physical page (pool detectors)
    # (asid, logical page) for exact-entry detectors, (asid, base, n) for
    # the stale-range detector.
    key: Optional[Tuple[int, ...]]
    state: str                    # shadow state at detection time
    message: str

    def __str__(self) -> str:
        where = f"page {self.page}" if self.page is not None else \
            f"key {self.key}"
        return (f"svasan[{self.detector}] {where} "
                f"(shadow={self.state}): {self.message}")


class SanitizerError(RuntimeError):
    """Raised at the faulting operation when ``raise_on_report`` (default).
    ``.report`` carries the structured :class:`SvasanReport`."""

    def __init__(self, report: SvasanReport):
        super().__init__(str(report))
        self.report = report


class SVASanitizer:
    """The shadow-state checker. One instance may watch several pools (the
    per-slot layout has one pool per slot) and one IOMMU; attach with
    :meth:`attach_pool` / by assigning ``iommu.sanitizer``."""

    def __init__(self, raise_on_report: bool = True):
        self.raise_on_report = raise_on_report
        self.reports: List[SvasanReport] = []
        self.checks = 0
        # (pool token, page) -> shadow refcount; absent == FREE
        self._rc: Dict[Tuple[int, int], int] = {}
        self._pool_tokens: Dict[int, int] = {}     # id(pool) -> token
        self._next_token = 0

    # ------------------------------------------------------------ plumbing
    def attach_pool(self, pool: "PagePool") -> None:
        """Start shadowing ``pool`` (its pages must all be free — attach at
        construction). Also installs the pool-side hook."""
        if id(pool) not in self._pool_tokens:
            self._pool_tokens[id(pool)] = self._next_token
            self._next_token += 1
        pool.sanitizer = self

    def _token(self, pool: "PagePool") -> int:
        tok = self._pool_tokens.get(id(pool))
        if tok is None:                            # late attach: adopt state
            self.attach_pool(pool)
            tok = self._pool_tokens[id(pool)]
        return tok

    def state(self, pool: "PagePool", page: int) -> str:
        rc = self._rc.get((self._token(pool), page), 0)
        return FREE if rc == 0 else OWNED if rc == 1 else SHARED

    def _report(self, detector: str, message: str,
                page: Optional[int] = None,
                key: Optional[Tuple[int, ...]] = None,
                state: str = FREE) -> None:
        rep = SvasanReport(detector, page, key, state, message)
        self.reports.append(rep)
        if self.raise_on_report:
            raise SanitizerError(rep)

    def stats(self) -> dict:
        return dict(pages_tracked=len(self._rc), checks=self.checks,
                    reports=len(self.reports))

    # ---------------------------------------------------- PagePool hooks
    def on_alloc(self, pool: "PagePool", pages: Iterable[int]) -> None:
        tok = self._token(pool)
        for p in pages:
            self.checks += 1
            if self._rc.get((tok, p), 0):
                self._report(
                    "double-free", "allocator handed out a page that is "
                    "still live in the shadow state (free-list corruption "
                    "or a missed free)", page=p, state=self.state(pool, p))
            self._rc[(tok, p)] = 1

    def on_share(self, pool: "PagePool", pages: Iterable[int]) -> None:
        tok = self._token(pool)
        for p in pages:
            self.checks += 1
            rc = self._rc.get((tok, p), 0)
            if rc == 0:
                self._report(
                    "double-free", "share (refcount++) of a FREE page — "
                    "the mapping being shared no longer owns it",
                    page=p, state=FREE)
            self._rc[(tok, p)] = rc + 1

    def on_free(self, pool: "PagePool", pages: Iterable[int]) -> None:
        tok = self._token(pool)
        for p in pages:
            self.checks += 1
            rc = self._rc.get((tok, p), 0)
            if rc == 0:
                self._report(
                    "double-free", "free of a page whose shadow state is "
                    "already FREE", page=p, state=FREE)
                continue                           # collect mode: keep going
            if rc == 1:
                del self._rc[(tok, p)]
            else:
                self._rc[(tok, p)] = rc - 1

    # ------------------------------------------------------- IOMMU hooks
    def check_hit(self, iommu: "IOMMU", asid: int, page: int,
                  cached_phys: int) -> None:
        """Cross-check a TLB hit against the live table state (called by
        ``IOMMU.translate`` on the hit path). Unattached ASIDs translate
        identity by design — nothing to check."""
        self.checks += 1
        sp = iommu.space(asid)
        if sp is None:
            return
        key = (asid, page)
        if page not in sp.table:
            self._report(
                "translate-after-unmap", "TLB hit for a logical page the "
                "live table no longer maps — the entry outlived its "
                "unmap/invalidation (use-after-free translation)",
                key=key, state=OWNED)
        elif sp.table[page] != cached_phys:
            self._report(
                "translate-after-unmap", f"TLB hit returned physical page "
                f"{cached_phys} but the live table maps logical page "
                f"{page} -> {sp.table[page]} — a remap's invalidation was "
                "skipped (stale translation)", key=key, state=SHARED)

    def check_fill(self, iommu: "IOMMU", key: Tuple[int, int],
                   phys: int) -> None:
        """A prefetch fill is about to install (``_install_pending``). The
        mapping it was issued for must still be live."""
        self.checks += 1
        sp = iommu.space(key[0])
        if sp is not None and key[1] not in sp.table:
            self._report(
                "stale-prefetch", "prefetch fill installing a translation "
                "for a logical page that was unmapped after the fill was "
                "issued — the fill outlived its mapping", key=key,
                state=FREE)

    def check_tenant_translate(self, iommu: "IOMMU",
                               tenant: Optional[str], asid: int,
                               page: int) -> None:
        """A translation is entering the TLB under ``tenant``'s identity:
        the ASID's registered owner must be that tenant. Runs AFTER the
        IOMMU's own isolation gate and re-derives ownership from the
        registry, so a bypassed/patched gate is caught here."""
        self.checks += 1
        owner = iommu._asid_tenant.get(asid)
        if owner is not None and owner != tenant:
            self._report(
                "cross-tenant-translate",
                f"translation issued under tenant {tenant!r} for asid "
                f"{asid} owned by tenant {owner!r} — the isolation gate "
                "was bypassed (a foreign page would have been translated)",
                key=(asid, page), state=OWNED)

    def check_unmapped(self, iommu: "IOMMU", asid: int,
                       lps: Optional[Iterable[int]] = None) -> None:
        """After an unmap/detach of ``asid`` (all pages when ``lps`` is
        None): no TLB entry and no in-flight prefetch may survive for the
        dead keys."""
        self.checks += 1
        dead_ranges: List[Tuple[int, ...]] = []
        if lps is None:
            dead_pending = [k for k in iommu._pending if k[0] == asid]
            dead_tlb = [k for k in iommu.tlb.keys()
                        if k[0] == asid and len(k) == 2]
            dead_ranges = [k for k in iommu.tlb.keys()
                           if k[0] == asid and len(k) == 3]
        else:
            dead = set(lps)
            keys = {(asid, lp) for lp in dead}
            dead_pending = [k for k in iommu._pending if k in keys]
            dead_tlb = [k for k in keys if k in iommu.tlb]
            # Range entries don't key on a single logical page: a
            # (asid, base, n) entry is stale as soon as it still *covers*
            # any dead page — it would keep translating the unmapped page.
            dead_ranges = [
                k for k in iommu.tlb.keys()
                if len(k) == 3 and k[0] == asid
                and any(k[1] <= lp < k[1] + k[2] for lp in dead)]
        if dead_ranges:
            self._report(
                "stale-range", f"{len(dead_ranges)} range entrie(s) still "
                "cover unmapped logical pages — the range outlived a split "
                "or invalidation and would translate a dead mapping",
                key=dead_ranges[0], state=FREE)
        if dead_pending:
            self._report(
                "stale-prefetch", f"{len(dead_pending)} in-flight prefetch "
                "fill(s) survived the unmap of their address space — a "
                "delayed install would resurrect a dead translation",
                key=dead_pending[0], state=FREE)
        elif dead_tlb:
            self._report(
                "translate-after-unmap", f"{len(dead_tlb)} TLB entrie(s) "
                "survived their unmap — the next translate of these keys "
                "hits a dead mapping", key=dead_tlb[0], state=FREE)

    # ----------------------------------------------- PagedKVManager hooks
    def check_write(self, pool: "PagePool", page: int) -> None:
        """A decode append is about to write ``page`` (after the manager's
        CoW-before-write pass): it must be exclusively owned."""
        self.checks += 1
        st = self.state(pool, page)
        if st == SHARED:
            self._report(
                "cow-bypass-write", "decode write targets a page other "
                "mappings still reference and no copy-on-write or "
                "steal-back happened — the write would corrupt the shared "
                "prefix", page=page, state=st)
        elif st == FREE:
            self._report(
                "cow-bypass-write", "decode write targets a FREE page — "
                "the sequence lost ownership before its write landed",
                page=page, state=st)

    def snapshot_rc(self, pool: "PagePool",
                    pages: Iterable[int]) -> Dict[int, int]:
        tok = self._token(pool)
        return {p: self._rc.get((tok, p), 0) for p in set(pages)}

    def check_release(self, pool: "PagePool", seq_id: int,
                      pages: List[int], before: Dict[int, int]) -> None:
        """After ``release(seq_id)`` freed ``pages``: every page's shadow
        refcount must have dropped by exactly the sequence's reference
        count on it (pages can repeat when a partial tail page aliases)."""
        tok = self._token(pool)
        drops: Dict[int, int] = {}
        for p in pages:
            drops[p] = drops.get(p, 0) + 1
        for p, n in drops.items():
            self.checks += 1
            now = self._rc.get((tok, p), 0)
            if now != before.get(p, 0) - n:
                self._report(
                    "leak-at-release", f"release of seq {seq_id} should "
                    f"have dropped {n} reference(s) on the page but its "
                    f"shadow refcount went {before.get(p, 0)} -> {now} — "
                    "the page leaked (it can never be reallocated)",
                    page=p, state=self.state(pool, p))


__all__ = ["FREE", "OWNED", "SHARED", "SVASanitizer", "SanitizerError",
           "SvasanReport", "enabled_by_env", "resolve"]
