"""Unified IOMMU front-end — ONE translation API for the performance
simulator, the SVA mapping layer, and the serving engine.

The paper's central object is an IOMMU with an IOTLB, a multi-level
page-table walker, and LLC-aware walk costs. This module is its single
implementation; everything else is a client:

  * the simulator's :class:`~repro.core.simulator.platform.MemorySystem`
    delegates translation to ``IOMMU(walk_model=Sv39Walk(...),
    tlb=TLBConfig(4))`` — the paper's 4-entry hardware IOTLB over the
    3-level sequential Sv39 walk with Listing-1 LLC warming;
  * :class:`~repro.core.sva.mapping.SVASpace` attaches one
    :class:`IOAddressSpace` per mapping handle (PASID-style);
  * :class:`~repro.core.sva.kv_manager.PagedKVManager` attaches one
    address space per batch slot and runs the decode hot path's page
    accesses through a ``CountingWalk`` IOMMU with a large TLB — the
    delta-upload cache and the hardware IOTLB are the same class
    configured differently.

Design-space axes (Kim et al., "Address Translation Design Tradeoffs for
Heterogeneous Systems"): TLB size and replacement policy
(``TLBConfig(n_entries, policy)`` — lru | fifo | lfu | random) and walker
cost model (``WalkModel``) are independently pluggable, so the same traffic
can be priced as pure stats (``CountingWalk``) or as modeled Sv39 cycles
with/without the shared LLC (``Sv39Walk``).

No module outside this one constructs a raw
:class:`~repro.core.sva.tlb.TranslationCache`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Dict, Iterable, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.core.sva.tlb import POLICIES, TLBStats, TranslationCache


@dataclass(frozen=True)
class TLBConfig:
    """IOTLB geometry + replacement policy (the translation design space)."""
    n_entries: int = 4096
    policy: str = "lru"           # lru | fifo | lfu | random
    seed: int = 0                 # random-policy determinism (trace parity)

    def __post_init__(self):
        if self.n_entries < 1:
            raise ValueError(f"n_entries={self.n_entries} (need >= 1)")
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy={self.policy!r} (expected one of {POLICIES})")


@dataclass
class WalkStats:
    walks: int = 0                # page-table walks performed
    cycles: float = 0.0           # total modeled walk cost (model's units)

    def as_dict(self):
        return dict(walks=self.walks, cycles=round(self.cycles, 3))


@runtime_checkable
class WalkModel(Protocol):
    """Prices one page-table walk; the *value* of a translation always comes
    from the owning :class:`IOAddressSpace`'s table, never from the model."""

    name: str
    stats: WalkStats

    def walk(self, asid: int, page: int) -> float:
        """Cost of a full walk for ``page`` (physical id). Returns cycles."""
        ...

    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Host creates IO mappings right before offload (paper Listing 1);
        cost models may warm PTE state (LLC residency)."""
        ...


class CountingWalk:
    """Pure-stats walker (zero cost) — the serving engine's live-traffic
    hit/miss/walk counter."""

    name = "counting"

    def __init__(self):
        self.stats = WalkStats()

    def walk(self, asid: int, page: int) -> float:
        self.stats.walks += 1
        return 0.0

    def host_map_pass(self, pages: Iterable[int]) -> None:
        return None


class Sv39Walk:
    """The 3-level sequential-access RISC-V Sv39 walk cost model (paper
    Fig. 5), lifted out of the simulator's ``MemorySystem.ptw_cost_accel``.

    The 128 KiB shared LLC caches ONLY host + PTW traffic: the Listing-1
    ``host_map_pass`` fills PTE cache lines (8 PTEs of 8 B per 64 B line),
    so leaf PTEs are LLC-resident at offload time. ``host_interference``
    adds the Fig.-5 concurrent-traffic eviction probability on top of the
    baseline ``pte_evict_prob`` (LLC shared with OS data between map and
    use). Costs are returned in ``dram_access_cycles``'s clock domain
    scaled by ``to_accel`` (the simulator passes host->accelerator H2A).
    """

    name = "sv39"

    def __init__(self, levels: int = 3, dram_access_cycles: float = 235.0,
                 llc: bool = False, llc_hit_cycles: float = 10.0,
                 pte_evict_prob: float = 0.10, host_interference: float = 0.0,
                 to_accel: float = 1.0, seed: int = 0):
        self.levels = levels
        self.dram_access_cycles = dram_access_cycles
        self.llc = llc
        self.llc_hit_cycles = llc_hit_cycles
        self.pte_evict_prob = pte_evict_prob
        self.host_interference = host_interference
        self.to_accel = to_accel
        self.llc_resident: set = set()      # PTE line ids resident in LLC
        self._rng = np.random.default_rng(seed)
        self.stats = WalkStats()

    def host_map_pass(self, pages: Iterable[int]) -> None:
        if self.llc:
            for p in set(pages):
                self.llc_resident.add(p // 8)

    def walk(self, asid: int, page: int) -> float:
        """One full page-table walk: up to ``levels`` sequential accesses.
        Upper levels are few enough to stay cached; the leaf PTE line is
        cached iff the map pass warmed it and no eviction hit it since."""
        total_host = 0.0
        evict_p = self.pte_evict_prob + self.host_interference
        for level in range(self.levels):
            line = page // 8 if level == self.levels - 1 else -level
            cached = self.llc and (
                line in self.llc_resident or level < self.levels - 1)
            if cached and level == self.levels - 1 and \
                    self._rng.random() < evict_p:
                cached = False        # PTE line evicted between map and walk
            total_host += (self.llc_hit_cycles if cached
                           else self.dram_access_cycles)
        cost = total_host * self.to_accel
        self.stats.walks += 1
        self.stats.cycles += cost
        return cost


class IOAddressSpace:
    """A PASID-style per-process/per-request address space: a logical->
    physical page table plus the translation verbs over it. Obtained via
    :meth:`IOMMU.attach`; all TLB state lives in the owning IOMMU (shared,
    keyed ``(asid, logical_page)``)."""

    def __init__(self, iommu: "IOMMU", asid: int):
        self.iommu = iommu
        self.asid = asid
        self.table: Dict[int, int] = {}
        # True once a TLB entry exists for a page NOT in the table (identity
        # fallback / caller-supplied phys): detach must then fall back to a
        # full-ASID scan instead of the O(mapped pages) table walk.
        self._untracked_fills = False

    # ------------------------------------------------------------- mapping
    def map(self, pages: Sequence[int], start: int = 0,
            warm: bool = True) -> None:
        """Install logical pages ``[start, start+len)`` -> ``pages`` and run
        the Listing-1 host map pass (PTE writes land in the LLC). ``warm``
        additionally pre-fills the device TLB (the driver's map-then-offload
        pattern leaves translations hot)."""
        for lp, pp in enumerate(pages, start=start):
            self.table[lp] = pp
            if warm:
                # host pre-warm, NOT a device page-table walk
                self.iommu.tlb.fill((self.asid, lp), pp, walked=False)
        self.iommu.host_map_pass(pages)

    def extend(self, pages: Sequence[int]) -> None:
        """Grow the mapping (decode appends crossing a page boundary)."""
        self.map(pages, start=len(self.table))

    def remap(self, lp: int, pp: int) -> None:
        """Point one logical page at a new physical page (CoW divergence):
        the stale translation self-invalidates, the new one is warmed."""
        self.table[lp] = pp
        self.iommu.tlb.invalidate_key((self.asid, lp))
        self.iommu.tlb.fill((self.asid, lp), pp, walked=False)
        self.iommu.host_map_pass([pp])

    def unmap(self, lps: Optional[Iterable[int]] = None) -> None:
        """Tear down translations — ONLY this space's (per-key
        self-invalidation; other ASIDs stay warm). ``lps=None`` unmaps the
        whole space."""
        if lps is None:
            self.table.clear()
            self.iommu.invalidate(asid=self.asid)
            return
        for lp in lps:
            self.table.pop(lp, None)
        self.iommu.invalidate(pages=[(self.asid, lp) for lp in lps])

    # --------------------------------------------------------- translation
    def translate(self, lp: int) -> Tuple[int, float, bool]:
        """(physical page, walk cost, hit)."""
        return self.iommu.translate(self.asid, lp)

    def invalidate(self, lps: Optional[Iterable[int]] = None) -> None:
        """Drop this space's TLB entries (table survives — a re-walk will
        re-derive them)."""
        if lps is None:
            self.iommu.invalidate(asid=self.asid)
        else:
            self.iommu.invalidate(pages=[(self.asid, lp) for lp in lps])

    @property
    def n_pages(self) -> int:
        return len(self.table)


class IOMMU:
    """The translation front-end: one shared IOTLB + one walk cost model,
    many attached address spaces (ASIDs)."""

    def __init__(self, walk_model: Optional[WalkModel] = None,
                 tlb: TLBConfig = TLBConfig()):
        self.walk_model: WalkModel = walk_model or CountingWalk()
        self.tlb_config = tlb
        self.tlb = TranslationCache(tlb.n_entries, policy=tlb.policy,
                                    seed=tlb.seed)
        self.epoch = 0
        self._spaces: Dict[int, IOAddressSpace] = {}

    # ----------------------------------------------------------- lifecycle
    def attach(self, asid: int) -> IOAddressSpace:
        """Create the per-process/per-request address space for ``asid``."""
        if asid in self._spaces:
            raise ValueError(f"asid {asid} already attached")
        sp = IOAddressSpace(self, asid)
        self._spaces[asid] = sp
        return sp

    def detach(self, asid: int) -> None:
        """Destroy an address space, self-invalidating ONLY its own
        translations (a whole-TLB flush per teardown would force a full
        re-walk for every OTHER live space — the Listing-1 full flush is
        ``invalidate()``). Costs O(mapped pages), not O(TLB entries): the
        space's table already enumerates its logical pages."""
        sp = self._spaces.pop(asid, None)
        if sp is None:
            return
        if sp._untracked_fills:
            self.invalidate(asid=asid)           # full scan, rare
        else:
            self.invalidate(pages=[(asid, lp) for lp in sp.table])
        sp.table.clear()

    def space(self, asid: int) -> Optional[IOAddressSpace]:
        return self._spaces.get(asid)

    @property
    def n_spaces(self) -> int:
        return len(self._spaces)

    # --------------------------------------------------------- translation
    def translate(self, asid: int, page: int,
                  phys: Optional[int] = None) -> Tuple[int, float, bool]:
        """IOTLB lookup; walks the page table on miss.

        Returns (physical page, walk cost, hit). ``phys`` overrides the
        table-derived value (trace replay: the recorded access already knows
        its physical page); a hit whose cached value contradicts it is by
        definition stale (a remap the replay never saw invalidate) and is
        re-walked, like the hardware would after the remap's invalidation.
        Unattached ASIDs translate identity — the simulator drives raw page
        ids without building tables; for an ATTACHED space a missing table
        entry is a caller error (a walk of a hole would cache a bogus
        translation in the shared TLB) and raises.
        """
        val, hit = self.tlb.lookup((asid, page))
        if hit and phys is not None and val != phys:
            self.tlb.stats.hits -= 1             # stale: account as a miss
            self.tlb.stats.misses += 1
            self.tlb.invalidate_key((asid, page))
            hit = False
        if hit:
            return val, 0.0, True
        sp = self._spaces.get(asid)
        if phys is None:
            if sp is not None:
                if page not in sp.table:
                    raise KeyError(
                        f"asid {asid}: logical page {page} is not mapped")
                phys = sp.table[page]
            else:
                phys = page
        cost = self.walk_model.walk(asid, phys)
        self.tlb.fill((asid, page), phys)
        if sp is not None and page not in sp.table:
            sp._untracked_fills = True
        return phys, cost, False

    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Paper Listing 1: the host maps right before offload; the walk
        model may warm PTE state."""
        self.walk_model.host_map_pass(pages)

    # -------------------------------------------------------- invalidation
    def invalidate(self, asid: Optional[int] = None,
                   pages: Optional[Iterable[Tuple[int, int]]] = None) -> None:
        """Three granularities (the paper's invalidation interface):

          invalidate()                 full flush; bumps the epoch EXACTLY
                                       once (Listing-1 self-invalidation —
                                       the next table upload must be full)
          invalidate(asid=a)           drop every translation of one space
          invalidate(pages=[(a, lp)])  drop specific translations
        """
        if pages is not None:
            for key in pages:
                self.tlb.invalidate_key(key)
            return
        if asid is not None:
            for key in self.tlb.keys():
                if key[0] == asid:
                    self.tlb.invalidate_key(key)
            return
        self.tlb.invalidate()
        self.epoch += 1

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        """The unified translation stats schema every layer reports:

          tlb    hits / misses / evictions / invalidations / walks / hit_rate
          walk   model name + walks / cycles (modeled cost)
          epoch  full-flush count
          asids  live address spaces
        """
        return {"tlb": self.tlb.stats.as_dict(),
                "walk": {"model": self.walk_model.name,
                         **self.walk_model.stats.as_dict()},
                "epoch": self.epoch,
                "asids": self.n_spaces}


__all__ = ["CountingWalk", "IOAddressSpace", "IOMMU", "Sv39Walk",
           "TLBConfig", "WalkModel", "WalkStats"]
