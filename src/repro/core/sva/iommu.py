"""Unified IOMMU front-end — ONE translation API for the performance
simulator, the SVA mapping layer, and the serving engine.

The paper's central object is an IOMMU with an IOTLB, a multi-level
page-table walker, and LLC-aware walk costs. This module is its single
implementation; everything else is a client:

  * the simulator's :class:`~repro.core.simulator.platform.MemorySystem`
    delegates translation to ``IOMMU(walk_model=Sv39Walk(...),
    tlb=TLBConfig(4))`` — the paper's 4-entry hardware IOTLB over the
    3-level sequential Sv39 walk with Listing-1 LLC warming;
  * :class:`~repro.core.sva.mapping.SVASpace` attaches one
    :class:`IOAddressSpace` per mapping handle (PASID-style);
  * :class:`~repro.core.sva.kv_manager.PagedKVManager` attaches one
    address space per batch slot and runs the decode hot path's page
    accesses through a ``CountingWalk`` IOMMU with a large TLB — the
    delta-upload cache and the hardware IOTLB are the same class
    configured differently.

Design-space axes (Kim et al., "Address Translation Design Tradeoffs for
Heterogeneous Systems"): TLB size, set associativity, and replacement
policy (``TLBConfig(n_entries, policy, ways=...)`` — lru | fifo | lfu |
random, ways=0 fully associative), walker cost model (``WalkModel``), and
the walker's non-leaf PTE walk cache (``WalkCacheConfig``) are
independently pluggable, so the same traffic can be priced as pure stats
(``CountingWalk``) or as modeled Sv39 cycles with/without the shared LLC
and with/without a hardware walk cache (``Sv39Walk``).
``benchmarks/tlb_sweep.py`` sweeps these axes over recorded serving
traces.

No module outside this one constructs a raw
:class:`~repro.core.sva.tlb.TranslationCache`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Dict, Iterable, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.core.sva.tlb import POLICIES, TLBStats, TranslationCache


@dataclass(frozen=True)
class TLBConfig:
    """IOTLB geometry + replacement policy (the translation design space).

    ``ways`` is the set associativity: 0 (or ``n_entries``) is fully
    associative — one set, bit-identical to the historical behavior; any
    proper divisor of ``n_entries`` splits the cache into
    ``n_entries // ways`` sets indexed on the logical page, with per-set
    replacement state and conflict-miss accounting."""
    n_entries: int = 4096
    policy: str = "lru"           # lru | fifo | lfu | random
    seed: int = 0                 # random-policy determinism (trace parity)
    ways: int = 0                 # 0 = fully associative (== n_entries)

    def __post_init__(self):
        if self.n_entries < 1:
            raise ValueError(f"n_entries={self.n_entries} (need >= 1)")
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy={self.policy!r} (expected one of {POLICIES})")
        ways = self.ways or self.n_entries
        if ways < 1 or ways > self.n_entries or self.n_entries % ways:
            raise ValueError(
                f"ways={self.ways} must divide n_entries={self.n_entries} "
                f"(1 <= ways <= n_entries; 0 = fully associative)")

    @property
    def resolved_ways(self) -> int:
        return self.ways or self.n_entries

    @property
    def n_sets(self) -> int:
        return self.n_entries // self.resolved_ways


@dataclass(frozen=True)
class WalkCacheConfig:
    """Geometry of the walker's page-table-walk cache: a small on-IOMMU
    cache of NON-LEAF PTE lines (hardware MMU walk caches), so a hit skips
    the upper-level accesses of a walk. ``n_entries == 0`` disables it —
    the default, bit-identical to the historical 3-sequential-access
    walker."""
    n_entries: int = 0            # 0 = walk cache disabled
    ways: int = 0                 # 0 = fully associative
    policy: str = "lru"           # lru | fifo | lfu | random
    seed: int = 0

    def __post_init__(self):
        if self.n_entries < 0:
            raise ValueError(f"n_entries={self.n_entries} (need >= 0)")
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy={self.policy!r} (expected one of {POLICIES})")
        if self.n_entries:
            ways = self.ways or self.n_entries
            if ways < 1 or ways > self.n_entries or self.n_entries % ways:
                raise ValueError(
                    f"ways={self.ways} must divide n_entries="
                    f"{self.n_entries} (0 = fully associative)")


@dataclass
class WalkStats:
    walks: int = 0                # page-table walks performed
    cycles: float = 0.0           # total modeled walk cost (model's units)

    def as_dict(self):
        return dict(walks=self.walks, cycles=round(self.cycles, 3))


@runtime_checkable
class WalkModel(Protocol):
    """Prices one page-table walk; the *value* of a translation always comes
    from the owning :class:`IOAddressSpace`'s table, never from the model."""

    name: str
    stats: WalkStats

    def walk(self, asid: int, page: int,
             vpn: Optional[int] = None) -> float:
        """Cost of a full walk for ``page`` (physical id). ``vpn`` is the
        VIRTUAL (logical) page the walk resolves — walk caches tag on it,
        like hardware; defaults to ``page`` for identity-translating
        callers. Returns cycles."""
        ...

    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Host creates IO mappings right before offload (paper Listing 1);
        cost models may warm PTE state (LLC residency)."""
        ...


class CountingWalk:
    """Pure-stats walker (zero cost) — the serving engine's live-traffic
    hit/miss/walk counter."""

    name = "counting"

    def __init__(self):
        self.stats = WalkStats()

    def walk(self, asid: int, page: int,
             vpn: Optional[int] = None) -> float:
        self.stats.walks += 1
        return 0.0

    def host_map_pass(self, pages: Iterable[int]) -> None:
        return None


class Sv39Walk:
    """The 3-level sequential-access RISC-V Sv39 walk cost model (paper
    Fig. 5), lifted out of the simulator's ``MemorySystem.ptw_cost_accel``.

    The 128 KiB shared LLC caches ONLY host + PTW traffic: the Listing-1
    ``host_map_pass`` fills PTE cache lines (8 PTEs of 8 B per 64 B line),
    so leaf PTEs are LLC-resident at offload time. ``host_interference``
    adds the Fig.-5 concurrent-traffic eviction probability on top of the
    baseline ``pte_evict_prob`` (LLC shared with OS data between map and
    use). Costs are returned in ``dram_access_cycles``'s clock domain
    scaled by ``to_accel`` (the simulator passes host->accelerator H2A).
    """

    name = "sv39"

    def __init__(self, levels: int = 3, dram_access_cycles: float = 235.0,
                 llc: bool = False, llc_hit_cycles: float = 10.0,
                 pte_evict_prob: float = 0.10, host_interference: float = 0.0,
                 to_accel: float = 1.0, seed: int = 0,
                 walk_cache: Optional[WalkCacheConfig] = None):
        self.levels = levels
        self.dram_access_cycles = dram_access_cycles
        self.llc = llc
        self.llc_hit_cycles = llc_hit_cycles
        self.pte_evict_prob = pte_evict_prob
        self.host_interference = host_interference
        self.to_accel = to_accel
        self.llc_resident: set = set()      # PTE line ids resident in LLC
        self._rng = np.random.default_rng(seed)
        self.stats = WalkStats()
        # Optional hardware walk cache over NON-LEAF PTEs: a hit at depth d
        # skips the accesses of levels 0..d (they resolve from on-IOMMU
        # SRAM). Disabled (None) reproduces the plain sequential walker.
        self.walk_cache_config = walk_cache
        self.walk_cache: Optional[TranslationCache] = None
        if walk_cache is not None and walk_cache.n_entries:
            self.walk_cache = TranslationCache(
                walk_cache.n_entries, policy=walk_cache.policy,
                seed=walk_cache.seed, ways=walk_cache.ways)

    def host_map_pass(self, pages: Iterable[int]) -> None:
        if self.llc:
            for p in set(pages):
                self.llc_resident.add(p // 8)

    def _wc_key(self, asid: int, vpn: int, level: int) -> Tuple[int, ...]:
        """Walk-cache tag for the non-leaf PTE covering VIRTUAL page
        ``vpn`` at ``level``: the page table is indexed by VA, and Sv39
        resolves 9 page-number bits per level, so the level-d entry covers
        ``vpn >> 9*(levels-1-d)``."""
        return (asid, level, vpn >> (9 * (self.levels - 1 - level)))

    def walk(self, asid: int, page: int,
             vpn: Optional[int] = None) -> float:
        """One full page-table walk: up to ``levels`` sequential accesses.
        A walk-cache hit on a non-leaf PTE (tagged on ``vpn``, the virtual
        page being resolved) skips every level above it. Upper levels are
        few enough to stay LLC-cached; the leaf PTE line is LLC-cached iff
        the map pass (or a previous walk's refill) warmed it and no
        eviction hit it since — a rolled eviction drops the line, and the
        walk's DRAM refill re-installs it."""
        vpn = page if vpn is None else vpn
        total_host = 0.0
        evict_p = self.pte_evict_prob + self.host_interference
        start_level = 0
        if self.walk_cache is not None:
            # Probe deepest non-leaf entry first (hardware walk caches
            # resolve the longest cached prefix).
            for level in range(self.levels - 2, -1, -1):
                _, hit = self.walk_cache.lookup(self._wc_key(asid, vpn,
                                                             level))
                if hit:
                    start_level = level + 1
                    break
        for level in range(start_level, self.levels):
            leaf = level == self.levels - 1
            line = page // 8 if leaf else -level
            cached = self.llc and (not leaf or line in self.llc_resident)
            if cached and leaf and self._rng.random() < evict_p:
                # PTE line evicted between map and walk: it leaves the LLC
                # (the refill below re-warms it after the walk completes)
                self.llc_resident.discard(line)
                cached = False
            total_host += (self.llc_hit_cycles if cached
                           else self.dram_access_cycles)
            if not leaf and self.walk_cache is not None:
                # the walker read this non-leaf PTE: install it (not a
                # device walk of its own — never counts in wc walk stats)
                self.walk_cache.fill(self._wc_key(asid, vpn, level), 1,
                                     walked=False)
        if self.llc:
            # The walk's leaf access leaves the PTE line LLC-resident: a
            # hit keeps it, a miss's DRAM refill installs it.
            self.llc_resident.add(page // 8)
        cost = total_host * self.to_accel
        self.stats.walks += 1
        self.stats.cycles += cost
        return cost


class IOAddressSpace:
    """A PASID-style per-process/per-request address space: a logical->
    physical page table plus the translation verbs over it. Obtained via
    :meth:`IOMMU.attach`; all TLB state lives in the owning IOMMU (shared,
    keyed ``(asid, logical_page)``)."""

    def __init__(self, iommu: "IOMMU", asid: int):
        self.iommu = iommu
        self.asid = asid
        self.table: Dict[int, int] = {}
        # True once a TLB entry exists for a page NOT in the table (identity
        # fallback / caller-supplied phys): detach must then fall back to a
        # full-ASID scan instead of the O(mapped pages) table walk.
        self._untracked_fills = False

    # ------------------------------------------------------------- mapping
    def map(self, pages: Sequence[int], start: int = 0,
            warm: bool = True) -> None:
        """Install logical pages ``[start, start+len)`` -> ``pages`` and run
        the Listing-1 host map pass (PTE writes land in the LLC). ``warm``
        additionally pre-fills the device TLB (the driver's map-then-offload
        pattern leaves translations hot)."""
        for lp, pp in enumerate(pages, start=start):
            self.table[lp] = pp
            if warm:
                # host pre-warm, NOT a device page-table walk
                self.iommu.tlb.fill((self.asid, lp), pp, walked=False)
        self.iommu.host_map_pass(pages)

    def extend(self, pages: Sequence[int]) -> None:
        """Grow the mapping (decode appends crossing a page boundary).
        Appends past the HIGHEST live logical page — ``len(self.table)``
        would collide with live pages after a partial ``unmap()`` (holes
        shrink the table but not the address range) and silently remap
        them."""
        start = max(self.table) + 1 if self.table else 0
        self.map(pages, start=start)

    def remap(self, lp: int, pp: int) -> None:
        """Point one logical page at a new physical page (CoW divergence):
        the stale translation self-invalidates, the new one is warmed."""
        self.table[lp] = pp
        self.iommu.tlb.invalidate_key((self.asid, lp))
        self.iommu.tlb.fill((self.asid, lp), pp, walked=False)
        self.iommu.host_map_pass([pp])

    def unmap(self, lps: Optional[Iterable[int]] = None) -> None:
        """Tear down translations — ONLY this space's (per-key
        self-invalidation; other ASIDs stay warm). ``lps=None`` unmaps the
        whole space."""
        if lps is None:
            self.table.clear()
            self.iommu.invalidate(asid=self.asid)
            return
        for lp in lps:
            self.table.pop(lp, None)
        self.iommu.invalidate(pages=[(self.asid, lp) for lp in lps])

    # --------------------------------------------------------- translation
    def translate(self, lp: int) -> Tuple[int, float, bool]:
        """(physical page, walk cost, hit)."""
        return self.iommu.translate(self.asid, lp)

    def invalidate(self, lps: Optional[Iterable[int]] = None) -> None:
        """Drop this space's TLB entries (table survives — a re-walk will
        re-derive them)."""
        if lps is None:
            self.iommu.invalidate(asid=self.asid)
        else:
            self.iommu.invalidate(pages=[(self.asid, lp) for lp in lps])

    @property
    def n_pages(self) -> int:
        return len(self.table)


class IOMMU:
    """The translation front-end: one shared IOTLB + one walk cost model,
    many attached address spaces (ASIDs)."""

    def __init__(self, walk_model: Optional[WalkModel] = None,
                 tlb: TLBConfig = TLBConfig()):
        self.walk_model: WalkModel = walk_model or CountingWalk()
        self.tlb_config = tlb
        self.tlb = TranslationCache(tlb.n_entries, policy=tlb.policy,
                                    seed=tlb.seed, ways=tlb.ways)
        self.epoch = 0
        self._spaces: Dict[int, IOAddressSpace] = {}

    # ----------------------------------------------------------- lifecycle
    def attach(self, asid: int) -> IOAddressSpace:
        """Create the per-process/per-request address space for ``asid``."""
        if asid in self._spaces:
            raise ValueError(f"asid {asid} already attached")
        sp = IOAddressSpace(self, asid)
        self._spaces[asid] = sp
        return sp

    def detach(self, asid: int) -> None:
        """Destroy an address space, self-invalidating ONLY its own
        translations (a whole-TLB flush per teardown would force a full
        re-walk for every OTHER live space — the Listing-1 full flush is
        ``invalidate()``). Costs O(mapped pages), not O(TLB entries): the
        space's table already enumerates its logical pages."""
        sp = self._spaces.pop(asid, None)
        if sp is None:
            return
        if sp._untracked_fills:
            self.invalidate(asid=asid)           # full scan, rare
        else:
            self.invalidate(pages=[(asid, lp) for lp in sp.table])
        sp.table.clear()

    def space(self, asid: int) -> Optional[IOAddressSpace]:
        return self._spaces.get(asid)

    @property
    def n_spaces(self) -> int:
        return len(self._spaces)

    # --------------------------------------------------------- translation
    def translate(self, asid: int, page: int,
                  phys: Optional[int] = None) -> Tuple[int, float, bool]:
        """IOTLB lookup; walks the page table on miss.

        Returns (physical page, walk cost, hit). ``phys`` overrides the
        table-derived value (trace replay: the recorded access already knows
        its physical page); a hit whose cached value contradicts it is by
        definition stale (a remap the replay never saw invalidate) and is
        re-walked, like the hardware would after the remap's invalidation.
        Unattached ASIDs translate identity — the simulator drives raw page
        ids without building tables; for an ATTACHED space a missing table
        entry is a caller error (a walk of a hole would cache a bogus
        translation in the shared TLB) and raises.
        """
        val, hit = self.tlb.lookup((asid, page))
        if hit and phys is not None and val != phys:
            self.tlb.stats.hits -= 1             # stale: account as a miss
            self.tlb.stats.misses += 1
            self.tlb.invalidate_key((asid, page))
            hit = False
        if hit:
            return val, 0.0, True
        sp = self._spaces.get(asid)
        if phys is None:
            if sp is not None:
                if page not in sp.table:
                    raise KeyError(
                        f"asid {asid}: logical page {page} is not mapped")
                phys = sp.table[page]
            else:
                phys = page
        cost = self.walk_model.walk(asid, phys, vpn=page)
        self.tlb.fill((asid, page), phys)
        if sp is not None and page not in sp.table:
            sp._untracked_fills = True
        return phys, cost, False

    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Paper Listing 1: the host maps right before offload; the walk
        model may warm PTE state."""
        self.walk_model.host_map_pass(pages)

    # -------------------------------------------------------- invalidation
    def invalidate(self, asid: Optional[int] = None,
                   pages: Optional[Iterable[Tuple[int, int]]] = None) -> None:
        """Three granularities (the paper's invalidation interface):

          invalidate()                 full flush; bumps the epoch EXACTLY
                                       once (Listing-1 self-invalidation —
                                       the next table upload must be full)
          invalidate(asid=a)           drop every translation of one space
          invalidate(pages=[(a, lp)])  drop specific translations
        """
        if pages is not None:
            for key in pages:
                self.tlb.invalidate_key(key)
            return
        if asid is not None:
            for key in self.tlb.keys():
                if key[0] == asid:
                    self.tlb.invalidate_key(key)
            return
        self.tlb.invalidate()
        self.epoch += 1

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        """The unified translation stats schema every layer reports:

          tlb    hits / misses / evictions / invalidations / walks /
                 conflict_misses / hit_rate
          walk   model name + walks / cycles (modeled cost); walkers with a
                 walk cache add a ``walk_cache:`` block (hits / misses /
                 geometry)
          epoch  full-flush count
          asids  live address spaces
        """
        walk = {"model": self.walk_model.name,
                **self.walk_model.stats.as_dict()}
        wc = getattr(self.walk_model, "walk_cache", None)
        if wc is not None:
            wcs = wc.stats
            walk["walk_cache"] = dict(
                hits=wcs.hits, misses=wcs.misses, evictions=wcs.evictions,
                n_entries=wc.n_entries, ways=wc.ways)
        return {"tlb": self.tlb.stats.as_dict(),
                "walk": walk,
                "epoch": self.epoch,
                "asids": self.n_spaces}


__all__ = ["CountingWalk", "IOAddressSpace", "IOMMU", "Sv39Walk",
           "TLBConfig", "WalkCacheConfig", "WalkModel", "WalkStats"]
