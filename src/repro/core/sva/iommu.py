"""Unified IOMMU front-end — ONE translation API for the performance
simulator, the SVA mapping layer, and the serving engine.

The paper's central object is an IOMMU with an IOTLB, a multi-level
page-table walker, and LLC-aware walk costs. This module is its single
implementation; everything else is a client:

  * the simulator's :class:`~repro.core.simulator.platform.MemorySystem`
    delegates translation to ``IOMMU(walk_model=Sv39Walk(...),
    tlb=TLBConfig(4))`` — the paper's 4-entry hardware IOTLB over the
    3-level sequential Sv39 walk with Listing-1 LLC warming;
  * :class:`~repro.core.sva.mapping.SVASpace` attaches one
    :class:`IOAddressSpace` per mapping handle (PASID-style);
  * :class:`~repro.core.sva.kv_manager.PagedKVManager` attaches one
    address space per batch slot and runs the decode hot path's page
    accesses through a ``CountingWalk`` IOMMU with a large TLB — the
    delta-upload cache and the hardware IOTLB are the same class
    configured differently.

Design-space axes (Kim et al., "Address Translation Design Tradeoffs for
Heterogeneous Systems"): TLB size, set associativity, and replacement
policy (``TLBConfig(n_entries, policy, ways=...)`` — lru | fifo | lfu |
random | gdsfs, ways=0 fully associative), walker cost model
(``WalkModel``), and the walker's non-leaf PTE walk cache
(``WalkCacheConfig``) are independently pluggable, so the same traffic can
be priced as pure stats (``CountingWalk``) or as modeled Sv39 cycles
with/without the shared LLC and with/without a hardware walk cache
(``Sv39Walk``). ``benchmarks/tlb_sweep.py`` sweeps these axes over
recorded serving traces.

Range-coalesced IOTLB entries (``TLBConfig(ranges=N)``, SPARTA-style,
PAPERS.md): when the page table shows a physically contiguous run around
a translation, ONE entry ``(asid, base_lpn, n_pages) -> base_ppn`` covers
up to N pages — installed opportunistically at map-time pre-warm and on
demand-miss fills, weighted ``span=n_pages`` under gdsfs, set-indexed on
``base_lpn``. Invalidation is range-granular: a partial unmap or CoW
remap SPLITS a covering range into its surviving segments (a range entry
never outlives a split; the svasan stale-range detector checks exactly
this). Resident ranges are kept disjoint, so a lookup has at most one
covering entry. ``ranges=0`` (default) is bit-identical to the per-page
front-end; coalescing changes translation accounting only, never data
movement. Counters land in the ``range:`` stats block.

Adaptive front-end (this is where the design space stops being static):

  * ``PrefetchConfig(policy="none|next_page|stream", degree, distance)``
    arms an IOTLB prefetcher modeled after Kurth et al.'s MMU-aware DMA
    engine: demand traffic predicts upcoming logical pages and issues
    walk-model fills for them off the demand path. Prefetched fills
    *complete* at the next demand translate — a demand that arrives while
    its prefetch is still in flight is a *late* prefetch and pays the full
    walk cost (conservative: no partial-latency credit). A prefetch NEVER
    fabricates a translation: for an attached address space only pages
    present in its table are prefetched (holes are skipped cleanly);
    unattached ASIDs prefetch identity, exactly like demand translation.
    Counters (``prefetch_issued/useful/late``) live in ``TLBStats``.
  * ``AutoTuneConfig(interval_steps, candidates)`` + :class:`TLBAutoTuner`
    retune the TLB geometry online: every ``interval_steps`` decode steps
    the tuner reads the live hit-rate/conflict-miss window, explores each
    candidate geometry for one window, then exploits the best (re-exploring
    when the exploit hit rate sags). A switch is a real hardware resize:
    :meth:`IOMMU.reconfigure_tlb` flushes every translation and bumps the
    epoch (the next serving table upload must be full); cumulative stats
    carry across so the ``tlb:`` schema stays monotonic.

Multi-tenant domains (the MMU-partitioning / execution-domain axis —
"Address Translation Design Tradeoffs for Heterogeneous Systems" +
bus-firewall execution domains): a :class:`TenantDomain` groups ASIDs
under one named tenant. Ownership is established at :meth:`IOMMU.attach`
(``attach(asid, tenant=...)``) and enforced on EVERY translation — a
translate on behalf of one tenant for an ASID another tenant owns raises
a structured :class:`IsolationError` before any TLB state is touched, so
range entries and prefetch fills can never leak across the boundary
(they are keyed by ASID, and the ASID's owner is checked first).
``TLBConfig(partitions={tenant: ways})`` additionally way-partitions the
IOTLB so one tenant's thrash cannot evict another's entries; per-tenant
``tlb:`` stats (including a tenant-local ``conflict_misses``) land in the
``tenant:`` stats block, which — like ``range:`` — only appears once a
tenant is registered.

Stats schema (``IOMMU.stats()``; see ARCHITECTURE.md): ``tlb:``
(``TLBStats.as_dict``), ``walk:`` (model name, walks, cycles, plus
``walk_cache:`` and ``prefetch:`` blocks when configured), ``epoch``,
``asids``.

No module outside this one constructs a raw
:class:`~repro.core.sva.tlb.TranslationCache`.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Dict, Iterable, List, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import numpy as np

from repro.core.sva.tlb import POLICIES, TLBStats, TranslationCache

if TYPE_CHECKING:                                  # pragma: no cover
    from repro.core.sva.sanitizer import SVASanitizer


@dataclass(frozen=True)
class TLBConfig:
    """IOTLB geometry + replacement policy (the translation design space).

    ``ways`` is the set associativity: 0 (or ``n_entries``) is fully
    associative — one set, bit-identical to the historical behavior; any
    proper divisor of ``n_entries`` splits the cache into
    ``n_entries // ways`` sets indexed on the logical page, with per-set
    replacement state and conflict-miss accounting.

    ``partitions`` way-partitions the cache between tenants: a mapping
    (or tuple of pairs — normalized, so configs stay hashable for the
    auto-tuner's equality checks) ``tenant -> private ways per set``.
    Leftover ways form the shared pool for un-partitioned traffic; the
    empty default is bit-identical to the unpartitioned cache."""
    n_entries: int = 4096
    policy: str = "lru"           # lru | fifo | lfu | random
    seed: int = 0                 # random-policy determinism (trace parity)
    ways: int = 0                 # 0 = fully associative (== n_entries)
    ranges: int = 0               # max pages one range entry may coalesce
                                  # (0 = per-page entries only; >= 2 arms
                                  # SPARTA-style range coalescing)
    partitions: Tuple[Tuple[str, int], ...] = ()  # tenant -> ways per set

    def __post_init__(self):
        if self.n_entries < 1:
            raise ValueError(f"n_entries={self.n_entries} (need >= 1)")
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy={self.policy!r} (expected one of {POLICIES})")
        ways = self.ways or self.n_entries
        if ways < 1 or ways > self.n_entries or self.n_entries % ways:
            raise ValueError(
                f"ways={self.ways} must divide n_entries={self.n_entries} "
                f"(1 <= ways <= n_entries; 0 = fully associative)")
        if self.ranges < 0 or self.ranges == 1:
            raise ValueError(
                f"ranges={self.ranges} (0 = off, else the max coalesced "
                "run length, >= 2)")
        if isinstance(self.partitions, dict):
            object.__setattr__(self, "partitions",
                               tuple(sorted(self.partitions.items())))
        else:
            object.__setattr__(self, "partitions",
                               tuple(tuple(p) for p in self.partitions))
        names = [t for t, _ in self.partitions]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate tenant in partitions={names}")
        for t, w in self.partitions:
            if not isinstance(t, str) or not t:
                raise ValueError(f"partition tenant {t!r} must be a "
                                 "non-empty string")
            if w < 1:
                raise ValueError(f"partition {t!r}: ways={w} (need >= 1)")
        reserved = sum(w for _, w in self.partitions)
        if reserved > ways:
            raise ValueError(
                f"partitions reserve {reserved} ways but the TLB has "
                f"{ways} per set")

    @property
    def resolved_ways(self) -> int:
        return self.ways or self.n_entries

    @property
    def n_sets(self) -> int:
        return self.n_entries // self.resolved_ways

    @property
    def partition_dict(self) -> Dict[str, int]:
        return dict(self.partitions)


@dataclass(frozen=True)
class WalkCacheConfig:
    """Geometry of the walker's page-table-walk cache: a small on-IOMMU
    cache of NON-LEAF PTE lines (hardware MMU walk caches), so a hit skips
    the upper-level accesses of a walk. ``n_entries == 0`` disables it —
    the default, bit-identical to the historical 3-sequential-access
    walker."""
    n_entries: int = 0            # 0 = walk cache disabled
    ways: int = 0                 # 0 = fully associative
    policy: str = "lru"           # lru | fifo | lfu | random
    seed: int = 0

    def __post_init__(self):
        if self.n_entries < 0:
            raise ValueError(f"n_entries={self.n_entries} (need >= 0)")
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy={self.policy!r} (expected one of {POLICIES})")
        if self.n_entries:
            ways = self.ways or self.n_entries
            if ways < 1 or ways > self.n_entries or self.n_entries % ways:
                raise ValueError(
                    f"ways={self.ways} must divide n_entries="
                    f"{self.n_entries} (0 = fully associative)")


PREFETCH_POLICIES = ("none", "next_page", "stream")

#: accesses in a row with stride +1 before the stream prefetcher engages
STREAM_THRESHOLD = 2


@dataclass(frozen=True)
class PrefetchConfig:
    """IOTLB prefetcher knobs (Kurth et al., MMU-aware DMA prefetching).

    ``none``       disabled — bit-identical to the pre-prefetch front-end.
    ``next_page``  on a demand MISS at logical page p, issue fills for
                   ``p+1 .. p+degree`` (the classic next-line prefetch).
    ``stream``     per-ASID +1-stride detector: once ``STREAM_THRESHOLD``
                   sequential accesses are seen, keep a run-ahead window of
                   ``distance`` pages beyond the demand page, issuing at
                   most ``degree`` fills per access (hits trigger too, so
                   the prefetcher runs ahead of a streaming DMA instead of
                   reacting to its misses).

    ``degree`` bounds fills per trigger; ``distance`` how far past the
    demand page the stream window reaches (only ``stream`` uses it)."""
    policy: str = "none"
    degree: int = 2
    distance: int = 4

    def __post_init__(self):
        if self.policy not in PREFETCH_POLICIES:
            raise ValueError(f"policy={self.policy!r} "
                             f"(expected one of {PREFETCH_POLICIES})")
        if self.degree < 1:
            raise ValueError(f"degree={self.degree} (need >= 1)")
        if self.distance < 1:
            raise ValueError(f"distance={self.distance} (need >= 1)")

    @property
    def enabled(self) -> bool:
        return self.policy != "none"


@dataclass(frozen=True)
class AutoTuneConfig:
    """Online TLB-geometry auto-tuner knobs.

    Every ``interval_steps`` observed decode steps the tuner closes a
    measurement window over the live TLB stats (hit-rate delta, conflict
    misses). It explores each candidate geometry for one window, then
    settles on the best (highest window hit rate; ties prefer fewer
    conflict misses, then fewer entries, then earlier candidates) and
    re-explores when the exploit
    window's hit rate drops more than ``retune_margin`` below the best
    explored value. Windows with fewer than ``min_accesses`` demand
    accesses are ignored (idle engine)."""
    interval_steps: int = 32
    candidates: Tuple[TLBConfig, ...] = ()
    min_accesses: int = 1
    retune_margin: float = 0.05

    def __post_init__(self):
        if self.interval_steps < 1:
            raise ValueError(
                f"interval_steps={self.interval_steps} (need >= 1)")
        if not self.candidates:
            raise ValueError("candidates must name at least one TLBConfig")
        if self.min_accesses < 1:
            raise ValueError(f"min_accesses={self.min_accesses} (need >= 1)")
        if not 0.0 <= self.retune_margin <= 1.0:
            raise ValueError(
                f"retune_margin={self.retune_margin} (need 0..1)")


def default_autotune_candidates(base: TLBConfig) -> Tuple[TLBConfig, ...]:
    """A small entries ladder around ``base`` (same ways/policy): the
    default candidate set when a deployment turns auto-tuning on without
    naming geometries."""
    entries = sorted({max(4, base.n_entries // 16),
                      max(4, base.n_entries // 4), base.n_entries})
    out = []
    for e in entries:
        ways = base.ways if base.ways and e % base.ways == 0 else 0
        out.append(TLBConfig(e, base.policy, seed=base.seed, ways=ways,
                             ranges=base.ranges))
    return tuple(out)


@dataclass
class WalkStats:
    walks: int = 0                # page-table walks performed
    cycles: float = 0.0           # total modeled walk cost (model's units)

    def as_dict(self):
        return dict(walks=self.walks, cycles=round(self.cycles, 3))


@runtime_checkable
class WalkModel(Protocol):
    """Prices one page-table walk; the *value* of a translation always comes
    from the owning :class:`IOAddressSpace`'s table, never from the model."""

    name: str
    stats: WalkStats

    def walk(self, asid: int, page: int,
             vpn: Optional[int] = None) -> float:
        """Cost of a full walk for ``page`` (physical id). ``vpn`` is the
        VIRTUAL (logical) page the walk resolves — walk caches tag on it,
        like hardware; defaults to ``page`` for identity-translating
        callers. Returns cycles."""
        ...

    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Host creates IO mappings right before offload (paper Listing 1);
        cost models may warm PTE state (LLC residency)."""
        ...


class CountingWalk:
    """Pure-stats walker (zero cost) — the serving engine's live-traffic
    hit/miss/walk counter."""

    name = "counting"

    def __init__(self):
        self.stats = WalkStats()

    def walk(self, asid: int, page: int,
             vpn: Optional[int] = None) -> float:
        self.stats.walks += 1
        return 0.0

    def host_map_pass(self, pages: Iterable[int]) -> None:
        return None


class Sv39Walk:
    """The 3-level sequential-access RISC-V Sv39 walk cost model (paper
    Fig. 5), lifted out of the simulator's ``MemorySystem.ptw_cost_accel``.

    The 128 KiB shared LLC caches ONLY host + PTW traffic: the Listing-1
    ``host_map_pass`` fills PTE cache lines (8 PTEs of 8 B per 64 B line),
    so leaf PTEs are LLC-resident at offload time. ``host_interference``
    adds the Fig.-5 concurrent-traffic eviction probability on top of the
    baseline ``pte_evict_prob`` (LLC shared with OS data between map and
    use). Costs are returned in ``dram_access_cycles``'s clock domain
    scaled by ``to_accel`` (the simulator passes host->accelerator H2A).
    """

    name = "sv39"

    def __init__(self, levels: int = 3, dram_access_cycles: float = 235.0,
                 llc: bool = False, llc_hit_cycles: float = 10.0,
                 pte_evict_prob: float = 0.10, host_interference: float = 0.0,
                 to_accel: float = 1.0, seed: int = 0,
                 walk_cache: Optional[WalkCacheConfig] = None):
        self.levels = levels
        self.dram_access_cycles = dram_access_cycles
        self.llc = llc
        self.llc_hit_cycles = llc_hit_cycles
        self.pte_evict_prob = pte_evict_prob
        self.host_interference = host_interference
        self.to_accel = to_accel
        self.llc_resident: set = set()      # PTE line ids resident in LLC
        self._rng = np.random.default_rng(seed)
        self.stats = WalkStats()
        # Optional hardware walk cache over NON-LEAF PTEs: a hit at depth d
        # skips the accesses of levels 0..d (they resolve from on-IOMMU
        # SRAM). Disabled (None) reproduces the plain sequential walker.
        self.walk_cache_config = walk_cache
        self.walk_cache: Optional[TranslationCache] = None
        if walk_cache is not None and walk_cache.n_entries:
            self.walk_cache = TranslationCache(
                walk_cache.n_entries, policy=walk_cache.policy,
                seed=walk_cache.seed, ways=walk_cache.ways)

    def host_map_pass(self, pages: Iterable[int]) -> None:
        if self.llc:
            for p in set(pages):
                self.llc_resident.add(p // 8)

    def _wc_key(self, asid: int, vpn: int, level: int) -> Tuple[int, ...]:
        """Walk-cache tag for the non-leaf PTE covering VIRTUAL page
        ``vpn`` at ``level``: the page table is indexed by VA, and Sv39
        resolves 9 page-number bits per level, so the level-d entry covers
        ``vpn >> 9*(levels-1-d)``."""
        return (asid, level, vpn >> (9 * (self.levels - 1 - level)))

    def walk(self, asid: int, page: int,
             vpn: Optional[int] = None,
             wc_sink: Optional[list] = None) -> float:
        """One full page-table walk: up to ``levels`` sequential accesses.
        A walk-cache hit on a non-leaf PTE (tagged on ``vpn``, the virtual
        page being resolved) skips every level above it. Upper levels are
        few enough to stay LLC-cached; the leaf PTE line is LLC-cached iff
        the map pass (or a previous walk's refill) warmed it and no
        eviction hit it since — a rolled eviction drops the line, and the
        walk's DRAM refill re-installs it.

        ``wc_sink`` (prefetch walks) defers walk-cache installs: the
        non-leaf keys this walk read are appended to the sink instead of
        filled, so the caller can install them when the in-flight walk
        actually completes (``IOMMU._install_pending``). The cache is still
        PROBED — an in-flight prefetch rides the same hardware walker."""
        vpn = page if vpn is None else vpn
        total_host = 0.0
        evict_p = self.pte_evict_prob + self.host_interference
        start_level = 0
        if self.walk_cache is not None:
            # Probe deepest non-leaf entry first (hardware walk caches
            # resolve the longest cached prefix).
            for level in range(self.levels - 2, -1, -1):
                _, hit = self.walk_cache.lookup(self._wc_key(asid, vpn,
                                                             level))
                if hit:
                    start_level = level + 1
                    break
        for level in range(start_level, self.levels):
            leaf = level == self.levels - 1
            line = page // 8 if leaf else -level
            cached = self.llc and (not leaf or line in self.llc_resident)
            if cached and leaf and self._rng.random() < evict_p:
                # PTE line evicted between map and walk: it leaves the LLC
                # (the refill below re-warms it after the walk completes)
                self.llc_resident.discard(line)
                cached = False
            total_host += (self.llc_hit_cycles if cached
                           else self.dram_access_cycles)
            if not leaf and self.walk_cache is not None:
                # the walker read this non-leaf PTE: install it (not a
                # device walk of its own — never counts in wc walk stats)
                key = self._wc_key(asid, vpn, level)
                if wc_sink is None:
                    self.walk_cache.fill(key, 1, walked=False)
                else:
                    wc_sink.append(key)
        if self.llc:
            # The walk's leaf access leaves the PTE line LLC-resident: a
            # hit keeps it, a miss's DRAM refill installs it.
            self.llc_resident.add(page // 8)
        cost = total_host * self.to_accel
        self.stats.walks += 1
        self.stats.cycles += cost
        return cost

    def prefetch_walk(self, asid: int, page: int,
                      vpn: Optional[int] = None) -> Tuple[float, tuple]:
        """Walk on behalf of a PREFETCH: identical probing and cost to a
        demand walk, but the non-leaf PTE lines it read are RETURNED
        instead of installed — the walk is in flight until the prefetch
        completes, so the IOMMU installs the lines (and counts them as
        ``walk_cache_prefills``) at completion time. Returns
        ``(cost, non_leaf_keys)``."""
        lines: list = []
        cost = self.walk(asid, page, vpn=vpn, wc_sink=lines)
        return cost, tuple(lines)


class IOAddressSpace:
    """A PASID-style per-process/per-request address space: a logical->
    physical page table plus the translation verbs over it. Obtained via
    :meth:`IOMMU.attach`; all TLB state lives in the owning IOMMU (shared,
    keyed ``(asid, logical_page)``)."""

    def __init__(self, iommu: "IOMMU", asid: int):
        self.iommu = iommu
        self.asid = asid
        self.table: Dict[int, int] = {}
        # True once a TLB entry exists for a page NOT in the table (identity
        # fallback / caller-supplied phys): detach must then fall back to a
        # full-ASID scan instead of the O(mapped pages) table walk.
        self._untracked_fills = False

    # ------------------------------------------------------------- mapping
    def map(self, pages: Sequence[int], start: int = 0,
            warm: bool = True) -> None:
        """Install logical pages ``[start, start+len)`` -> ``pages`` and run
        the Listing-1 host map pass (PTE writes land in the LLC). ``warm``
        additionally pre-fills the device TLB (the driver's map-then-offload
        pattern leaves translations hot) — with range coalescing armed,
        physically contiguous chunks warm as single range entries."""
        for lp, pp in enumerate(pages, start=start):
            self.table[lp] = pp
        if warm:
            if self.iommu.range_max:
                self.iommu._warm_fill_runs(self.asid, start, pages)
            else:
                for lp, pp in enumerate(pages, start=start):
                    # host pre-warm, NOT a device page-table walk
                    self.iommu.tlb.fill((self.asid, lp), pp, walked=False)
        self.iommu.host_map_pass(pages)

    def extend(self, pages: Sequence[int]) -> None:
        """Grow the mapping (decode appends crossing a page boundary).
        Appends past the HIGHEST live logical page — ``len(self.table)``
        would collide with live pages after a partial ``unmap()`` (holes
        shrink the table but not the address range) and silently remap
        them."""
        start = max(self.table) + 1 if self.table else 0
        self.map(pages, start=start)

    def remap(self, lp: int, pp: int) -> None:
        """Point one logical page at a new physical page (CoW divergence):
        the stale translation self-invalidates, the new one is warmed.
        Routed through the IOMMU's page invalidation so an IN-FLIGHT
        prefetch of the old translation dies too — otherwise its delayed
        install would overwrite the fresh post-CoW fill with the stale
        physical page."""
        self.table[lp] = pp
        self.iommu.invalidate(pages=[(self.asid, lp)])
        self.iommu.tlb.fill((self.asid, lp), pp, walked=False)
        self.iommu.host_map_pass([pp])

    def unmap(self, lps: Optional[Iterable[int]] = None) -> None:
        """Tear down translations — ONLY this space's (per-key
        self-invalidation; other ASIDs stay warm). ``lps=None`` unmaps the
        whole space."""
        if lps is None:
            self.table.clear()
            self.iommu.invalidate(asid=self.asid)
            if self.iommu.sanitizer is not None:
                self.iommu.sanitizer.check_unmapped(self.iommu, self.asid)
            return
        lps = list(lps)               # iterated twice — accept generators
        for lp in lps:
            self.table.pop(lp, None)
        self.iommu.invalidate(pages=[(self.asid, lp) for lp in lps])
        if self.iommu.sanitizer is not None:
            self.iommu.sanitizer.check_unmapped(self.iommu, self.asid, lps)

    # --------------------------------------------------------- translation
    def translate(self, lp: int) -> Tuple[int, float, bool]:
        """(physical page, walk cost, hit)."""
        return self.iommu.translate(self.asid, lp)

    def invalidate(self, lps: Optional[Iterable[int]] = None) -> None:
        """Drop this space's TLB entries (table survives — a re-walk will
        re-derive them)."""
        if lps is None:
            self.iommu.invalidate(asid=self.asid)
        else:
            self.iommu.invalidate(pages=[(self.asid, lp) for lp in lps])

    @property
    def n_pages(self) -> int:
        return len(self.table)


class IsolationError(PermissionError):
    """A tenant tried to translate through an ASID another tenant owns —
    the hard multi-tenant boundary. Structured like
    :class:`~repro.core.sva.sanitizer.SvasanReport`: the fields are what
    the isolation tests assert on."""

    def __init__(self, tenant: Optional[str], owner: str, asid: int,
                 page: Optional[int] = None):
        self.tenant = tenant      # who asked (None = untenanted caller)
        self.owner = owner        # who owns the ASID
        self.asid = asid
        self.page = page          # logical page, when a translate faulted
        where = f" page {page}" if page is not None else ""
        super().__init__(
            f"tenant {tenant!r} denied: asid {asid}{where} is owned by "
            f"tenant {owner!r}")


class TenantDomain:
    """One tenant's view of the IOMMU: the set of ASIDs it owns and the
    translation verbs scoped to them (the execution-domain / bus-firewall
    analogue). Obtained via :meth:`IOMMU.register_tenant`; every translate
    issued through a domain carries the tenant identity, and the IOMMU
    refuses (structured :class:`IsolationError`) before touching any TLB
    state when the ASID belongs to someone else."""

    def __init__(self, iommu: "IOMMU", name: str):
        self.iommu = iommu
        self.name = name
        self.asids: set = set()
        self.denials = 0          # isolation faults charged to this tenant

    def attach(self, asid: int) -> IOAddressSpace:
        """Attach a fresh address space owned by this tenant."""
        return self.iommu.attach(asid, tenant=self.name)

    def adopt(self, asid: int) -> None:
        """Take ownership of an ASID without (re)attaching a space — trace
        replay assigns recorded slots to tenants this way."""
        owner = self.iommu._asid_tenant.get(asid)
        if owner is not None and owner != self.name:
            self.denials += 1
            raise IsolationError(self.name, owner, asid)
        self.iommu._asid_tenant[asid] = self.name
        self.asids.add(asid)

    def translate(self, asid: int, page: int,
                  phys: Optional[int] = None) -> Tuple[int, float, bool]:
        """Translate on behalf of this tenant (isolation-checked)."""
        return self.iommu.translate(asid, page, phys, tenant=self.name)

    def stats(self) -> dict:
        return dict(asids=len(self.asids), denials=self.denials)


class IOMMU:
    """The translation front-end: one shared IOTLB + one walk cost model,
    many attached address spaces (ASIDs), and an optional IOTLB prefetcher
    (``PrefetchConfig`` — see the module docstring for the timing model)."""

    def __init__(self, walk_model: Optional[WalkModel] = None,
                 tlb: TLBConfig = TLBConfig(),
                 prefetch: PrefetchConfig = PrefetchConfig()):
        self.walk_model: WalkModel = walk_model or CountingWalk()
        self.tlb_config = tlb
        # Tenant registry: name -> TenantDomain, asid -> owning tenant.
        # Empty (the default) keeps every path bit-identical to the
        # untenanted front-end — translate()'s check is one truthiness
        # test, and the cache gets no tenant resolver.
        self._tenants: Dict[str, TenantDomain] = {}
        self._asid_tenant: Dict[int, str] = {}
        self.tlb = self._build_cache(tlb)
        self.prefetch_config = prefetch
        # Range-coalescing counters (the ``range:`` stats block; only
        # reported when ``tlb.ranges`` arms coalescing).
        self.range_fills = 0          # range entries installed
        self.range_hits = 0           # demand hits served by a range entry
        self.coalesced_pages = 0      # pages covered by installed ranges
        self.range_splits = 0         # ranges split by partial invalidation
        # Prefetcher state: fills issued but not yet completed (they install
        # at the START of the next demand translate — arriving demand for a
        # pending key is a LATE prefetch), installed-but-never-demanded keys
        # (for useful-once accounting), and the per-ASID stream detector
        # [last_lp, run_length, next_unprefetched_lp].
        self._pending: "OrderedDict" = OrderedDict()
        self._prefetched: set = set()
        self._streams: Dict[int, List[int]] = {}
        # Non-leaf PTE lines installed into the walk model's walk cache by
        # COMPLETING prefetches (a useful prefetch warms the walk cache for
        # the neighbourhood, not just its own leaf translation).
        self.walk_cache_prefills = 0
        self.epoch = 0
        self._spaces: Dict[int, IOAddressSpace] = {}
        # svasan shadow-state hook (core/sva/sanitizer.py); None keeps
        # translate()/unmap paths bit-identical to the unsanitized stack.
        self.sanitizer: Optional["SVASanitizer"] = None

    # ------------------------------------------------------------- tenants
    def _build_cache(self, tlb: TLBConfig) -> TranslationCache:
        """The ONE TranslationCache constructor for the IOTLB: geometry
        from ``tlb``, tenant resolver wired iff tenancy is in play."""
        parts = tlb.partition_dict
        tenant_of = self._tenant_of_key if (parts or self._tenants) else None
        return TranslationCache(tlb.n_entries, policy=tlb.policy,
                                seed=tlb.seed, ways=tlb.ways,
                                range_aware=bool(tlb.ranges),
                                partitions=parts or None,
                                tenant_of=tenant_of)

    def _tenant_of_key(self, key) -> Optional[str]:
        """Tenant owning a TLB key — both exact ``(asid, lp)`` and range
        ``(asid, base, n)`` keys carry the ASID first."""
        if isinstance(key, tuple) and key:
            return self._asid_tenant.get(key[0])
        return None

    def register_tenant(self, name: str) -> TenantDomain:
        """Create (or return) the named tenant domain. The first
        registration arms per-tenant TLB accounting."""
        if not name:
            raise ValueError("tenant name must be non-empty")
        dom = self._tenants.get(name)
        if dom is None:
            dom = self._tenants[name] = TenantDomain(self, name)
            if self.tlb._tenant_of is None:
                self.tlb._tenant_of = self._tenant_of_key
        return dom

    def tenant_of(self, asid: int) -> Optional[str]:
        """The tenant owning ``asid`` (None = unowned)."""
        return self._asid_tenant.get(asid)

    def _check_tenant(self, tenant: Optional[str], asid: int,
                      page: Optional[int] = None) -> None:
        """The isolation gate: an owned ASID may only be used by its
        owner. Untenanted callers (tenant=None) are refused too — once an
        ASID belongs to a domain, anonymous access is a leak."""
        owner = self._asid_tenant.get(asid)
        if owner is not None and owner != tenant:
            dom = self._tenants.get(tenant) if tenant else None
            if dom is not None:
                dom.denials += 1
            raise IsolationError(tenant, owner, asid, page)

    # ----------------------------------------------------------- lifecycle
    def attach(self, asid: int,
               tenant: Optional[str] = None) -> IOAddressSpace:
        """Create the per-process/per-request address space for ``asid``.
        ``tenant`` assigns ownership to a registered domain (the slot's
        translations are then isolation-checked against it)."""
        if asid in self._spaces:
            raise ValueError(f"asid {asid} already attached")
        if tenant is not None and tenant not in self._tenants:
            raise ValueError(f"tenant {tenant!r} is not registered")
        if self._asid_tenant:
            # re-attaching an ASID a live tenant still owns needs the
            # owner's identity (or a prior detach dropped it)
            self._check_tenant(tenant, asid)
        sp = IOAddressSpace(self, asid)
        self._spaces[asid] = sp
        if tenant is not None:
            self._asid_tenant[asid] = tenant
            self._tenants[tenant].asids.add(asid)
        return sp

    def detach(self, asid: int) -> None:
        """Destroy an address space, self-invalidating ONLY its own
        translations (a whole-TLB flush per teardown would force a full
        re-walk for every OTHER live space — the Listing-1 full flush is
        ``invalidate()``). Costs O(mapped pages), not O(TLB entries): the
        space's table already enumerates its logical pages."""
        sp = self._spaces.pop(asid, None)
        if sp is None:
            return
        if sp._untracked_fills:
            self.invalidate(asid=asid)           # full scan, rare
        else:
            self.invalidate(pages=[(asid, lp) for lp in sp.table])
            # predictor state and any in-flight prefetch die with the space
            for key in [k for k in self._pending if k[0] == asid]:
                del self._pending[key]
            self._streams.pop(asid, None)
        if self.sanitizer is not None:
            # nothing of the dead space may survive detach: no TLB entry,
            # no in-flight prefetch fill
            self.sanitizer.check_unmapped(self, asid)
        owner = self._asid_tenant.pop(asid, None)
        if owner is not None:
            dom = self._tenants.get(owner)
            if dom is not None:
                dom.asids.discard(asid)
        sp.table.clear()

    def space(self, asid: int) -> Optional[IOAddressSpace]:
        return self._spaces.get(asid)

    @property
    def n_spaces(self) -> int:
        return len(self._spaces)

    @property
    def range_max(self) -> int:
        """Max pages one range entry may cover (0 = coalescing off)."""
        return self.tlb_config.ranges

    # ------------------------------------------------------- range entries
    def _warm_fill_runs(self, asid: int, start: int,
                        pages: Sequence[int],
                        singles: bool = True) -> None:
        """Map-time pre-warm with coalescing: physically contiguous chunks
        of the mapped pages (capped at ``range_max``) warm as one range
        entry each; singletons warm per-page (``singles=False`` skips them
        — the trace replay uses this so its per-page baseline, which never
        warms, stays an apples-to-apples comparison). Falls back to
        per-page fills for a chunk that would overlap a resident range
        (ranges stay disjoint — the invariant every lookup leans on)."""
        i, n = 0, len(pages)
        while i < n:
            j = i + 1
            while (j < n and pages[j] == pages[j - 1] + 1
                   and j - i < self.range_max):
                j += 1
            lp, run = start + i, j - i
            if run >= 2 and not self.tlb.ranges_overlapping(
                    asid, lp, lp + run - 1):
                for k in range(lp, lp + run):    # drop subsumed exact keys
                    if (asid, k) in self.tlb:
                        self.tlb.invalidate_key((asid, k))
                self.tlb.fill((asid, lp, run), pages[i], walked=False,
                              span=float(run))
                self.range_fills += 1
                self.coalesced_pages += run
            elif singles:
                for k in range(run):
                    self.tlb.fill((asid, lp + k), pages[i + k], walked=False)
            i = j

    def _try_coalesce(self, sp: IOAddressSpace, asid: int, page: int,
                      phys: int, cost: float) -> bool:
        """Opportunistic range fill on a demand miss: when the space's table
        shows a physically contiguous run around ``page``, install ONE range
        entry covering it (capped at ``range_max``, anchored at the demand
        page — extend down, then up). Resident exact keys inside the run are
        subsumed; resident ranges fully inside it are replaced; any partial
        overlap bails to a per-page fill (ranges stay disjoint). Returns
        True when a range entry was installed."""
        table = sp.table
        max_run = self.range_max
        lo, hi = page, page
        while (page - lo) + 1 < max_run and table.get(lo - 1) == \
                phys - (page - lo) - 1:
            lo -= 1
        while (hi - lo) + 1 < max_run and table.get(hi + 1) == \
                phys + (hi - page) + 1:
            hi += 1
        n = hi - lo + 1
        if n < 2:
            return False
        base_ppn = phys - (page - lo)
        for b, bn in self.tlb.ranges_overlapping(asid, lo, hi):
            if b < lo or b + bn - 1 > hi:
                return False                     # partial overlap: bail
        for b, bn in self.tlb.ranges_overlapping(asid, lo, hi):
            self.tlb.invalidate_key((asid, b, bn))
        for lp in range(lo, hi + 1):
            k = (asid, lp)
            if k in self.tlb:
                self.tlb.invalidate_key(k)
                self._prefetched.discard(k)
        self.tlb.fill((asid, lo, n), base_ppn, cost=cost, span=float(n))
        self.range_fills += 1
        self.coalesced_pages += n
        return True

    def _split_ranges_for(self,
                          keys: List[Tuple[int, int]]) -> None:
        """Range-granular invalidation: a range entry covering any of the
        dead ``(asid, lp)`` keys is removed and its SURVIVING maximal
        segments re-installed (length 1 -> exact key, length >= 2 -> a
        narrower range). A range entry never outlives a split — the
        correctness surface CoW remaps and partial unmaps ride on."""
        dead: Dict[int, set] = {}
        for asid, lp in keys:
            dead.setdefault(asid, set()).add(lp)
        for asid, lps in dead.items():
            lo, hi = min(lps), max(lps)
            for base, n in self.tlb.ranges_overlapping(asid, lo, hi):
                covered = set(range(base, base + n))
                if not (covered & lps):
                    continue
                base_ppn = self.tlb.peek((asid, base, n))
                self.tlb.invalidate_key((asid, base, n))
                survivors = sorted(covered - lps)
                if survivors:
                    self.range_splits += 1
                seg_lo = None
                prev = None
                for lp in survivors + [None]:    # sentinel flushes last seg
                    if seg_lo is not None and (lp is None or lp != prev + 1):
                        seg_n = prev - seg_lo + 1
                        seg_pp = base_ppn + (seg_lo - base)
                        if seg_n == 1:
                            self.tlb.fill((asid, seg_lo), seg_pp,
                                          walked=False)
                        else:
                            self.tlb.fill((asid, seg_lo, seg_n), seg_pp,
                                          walked=False, span=float(seg_n))
                        seg_lo = None
                    if lp is not None and seg_lo is None:
                        seg_lo = lp
                    prev = lp

    # --------------------------------------------------------- translation
    def translate(self, asid: int, page: int,
                  phys: Optional[int] = None,
                  tenant: Optional[str] = None) -> Tuple[int, float, bool]:
        """IOTLB lookup; walks the page table on miss.

        ``tenant`` is the identity the translation is issued under
        (:meth:`TenantDomain.translate` supplies it): when any tenant owns
        ASIDs, a translate for an ASID the caller does not own raises
        :class:`IsolationError` BEFORE any TLB state is read or filled —
        range entries and prefetch fills are keyed by ASID, so nothing can
        leak across the boundary. With no tenants registered the check is
        a single truthiness test (bit-identical fast path).

        Returns (physical page, walk cost, hit). ``phys`` overrides the
        table-derived value (trace replay: the recorded access already knows
        its physical page); a hit whose cached value contradicts it is by
        definition stale (a remap the replay never saw invalidate) and is
        re-walked, like the hardware would after the remap's invalidation.
        Unattached ASIDs translate identity — the simulator drives raw page
        ids without building tables; for an ATTACHED space a missing table
        entry is a caller error (a walk of a hole would cache a bogus
        translation in the shared TLB) and raises.

        With prefetching on, a demand hit can carry a nonzero cost: a LATE
        prefetch (the fill was issued by the immediately preceding demand
        access and its walk is still in flight) charges the full stored
        walk cost — conservative, no partial-latency credit — while a
        timely prefetched hit costs 0 like any other hit.
        """
        if self._asid_tenant:
            self._check_tenant(tenant, asid, page)
            if self.sanitizer is not None:
                # independent shadow check: catches a monkeypatched /
                # buggy _check_tenant red-handed (cross-tenant-translate)
                self.sanitizer.check_tenant_translate(self, tenant, asid,
                                                      page)
        pf = self.prefetch_config.enabled
        ranges = self.range_max
        key = (asid, page)
        late_cost = 0.0
        if pf and self._pending:
            late_cost = self._install_pending(key)
        rng = None
        if ranges and key not in self.tlb:
            # No exact entry — a resident range may still cover the page.
            # ONE counting lookup either way (range key on coverage, exact
            # key otherwise so the miss lands in the right set).
            rng = self.tlb.range_covering(asid, page)
        if rng is not None:
            base, n = rng
            base_ppn, hit = self.tlb.lookup((asid, base, n))
            val = base_ppn + (page - base) if hit else None
            if hit:
                self.range_hits += 1
        else:
            val, hit = self.tlb.lookup(key)
        if hit and phys is not None and val != phys:
            self.tlb.stats.hits -= 1             # stale: account as a miss
            self.tlb.stats.misses += 1
            if rng is not None:
                # stale range hit: the covering range must not survive the
                # page it mis-translates — split it, like hardware after
                # the remap's range-granular invalidation
                self.range_hits -= 1
                self._split_ranges_for([key])
            else:
                self.tlb.invalidate_key(key)
            self._prefetched.discard(key)
            hit = False
            late_cost = 0.0
        if hit:
            if self.sanitizer is not None and phys is None:
                # hit-path cross-check against the live table (translate-
                # after-unmap / missed-remap-invalidation detector). Replay
                # callers pass ``phys`` ground truth and re-walk stale hits
                # above — their tables are deliberately looser.
                self.sanitizer.check_hit(self, asid, page, val)
            if key in self._prefetched:
                self._prefetched.discard(key)
                self.tlb.stats.prefetch_useful += 1
                if late_cost:
                    self.tlb.stats.prefetch_late += 1
            else:
                late_cost = 0.0                  # entry predates the flush
            if pf:
                self._note_access(asid, page, miss=False)
            return val, late_cost, True
        sp = self._spaces.get(asid)
        if phys is None:
            if sp is not None:
                if page not in sp.table:
                    raise KeyError(
                        f"asid {asid}: logical page {page} is not mapped")
                phys = sp.table[page]
            else:
                phys = page
        cost = self.walk_model.walk(asid, phys, vpn=page)
        # coalesce only when the live table agrees with the filled value
        # (replay ground truth can disagree after an unseen remap)
        coalesced = (bool(ranges) and sp is not None
                     and sp.table.get(page) == phys
                     and self._try_coalesce(sp, asid, page, phys, cost))
        if not coalesced:
            self.tlb.fill(key, phys, cost=cost)
        self._prefetched.discard(key)   # prefetched once, evicted before use
        if sp is not None and page not in sp.table:
            sp._untracked_fills = True
        if pf:
            self._note_access(asid, page, miss=True)
        return phys, cost, False

    # ---------------------------------------------------------- prefetcher
    def _install_pending(self, demand_key: Tuple[int, int]) -> float:
        """Complete every in-flight prefetch (they finish at the start of
        the next demand translate). Returns the stored walk cost when the
        demanded key itself was still in flight (a LATE prefetch — the
        demand exposes that walk's latency), else 0."""
        late = 0.0
        wc = getattr(self.walk_model, "walk_cache", None)
        for key, (pp, cost, lines) in self._pending.items():
            if key == demand_key:
                late = cost
            if self.sanitizer is not None:
                self.sanitizer.check_fill(self, key, pp)
            self.tlb.fill(key, pp, walked=False, cost=cost)
            self._prefetched.add(key)
            if lines and wc is not None:
                # the prefetch walk's non-leaf reads land now that the walk
                # has completed (deferred from Sv39Walk.prefetch_walk)
                for line in lines:
                    wc.fill(line, 1, walked=False)
                    self.walk_cache_prefills += 1
        self._pending.clear()
        if len(self._prefetched) > 4 * self.tlb.n_entries:
            # evicted-before-use keys accumulate; prune lazily
            self._prefetched = {k for k in self._prefetched if k in self.tlb}
        return late

    def _note_access(self, asid: int, page: int, miss: bool) -> None:
        """Feed the prefetch predictor one demand access and issue fills."""
        cfg = self.prefetch_config
        if cfg.policy == "next_page":
            if miss:
                self._issue(asid, range(page + 1, page + 1 + cfg.degree))
            return
        st = self._streams.get(asid)               # stream
        if st is None or page != st[0] + 1:
            self._streams[asid] = [page, 1, page + 1]
            return
        st[0] = page
        st[1] += 1
        if st[1] < STREAM_THRESHOLD:
            return
        start = max(st[2], page + 1)
        end = min(page + cfg.distance, start + cfg.degree - 1)
        if start <= end:
            self._issue(asid, range(start, end + 1))
            st[2] = end + 1

    def _issue(self, asid: int, pages: Iterable[int]) -> None:
        """Issue walk-model fills for predicted logical pages. NEVER
        fabricates a translation: attached spaces only prefetch pages
        present in their table (holes are skipped cleanly); unattached
        ASIDs prefetch identity, matching their demand behavior."""
        sp = self._spaces.get(asid)
        for lp in pages:
            if lp < 0:
                continue
            key = (asid, lp)
            if key in self.tlb or key in self._pending:
                continue
            if self.range_max and \
                    self.tlb.range_covering(asid, lp) is not None:
                continue                 # a range entry already covers it
            if sp is not None:
                pp = sp.table.get(lp)
                if pp is None:
                    continue                     # unmapped: skip, don't walk
            else:
                pp = lp
            # Walk models that distinguish in-flight prefetch walks (the
            # Sv39 walker defers its walk-cache installs) expose
            # prefetch_walk; others price it like any demand walk.
            pw = getattr(self.walk_model, "prefetch_walk", None)
            if pw is not None:
                cost, lines = pw(asid, pp, vpn=lp)
            else:
                cost = self.walk_model.walk(asid, pp, vpn=lp)
                lines = ()
            self._pending[key] = (pp, cost, lines)
            self.tlb.stats.prefetch_issued += 1

    def host_map_pass(self, pages: Iterable[int]) -> None:
        """Paper Listing 1: the host maps right before offload; the walk
        model may warm PTE state."""
        self.walk_model.host_map_pass(pages)

    # -------------------------------------------------------- invalidation
    def invalidate(self, asid: Optional[int] = None,
                   pages: Optional[Iterable[Tuple[int, int]]] = None) -> None:
        """Three granularities (the paper's invalidation interface):

          invalidate()                 full flush; bumps the epoch EXACTLY
                                       once (Listing-1 self-invalidation —
                                       the next table upload must be full)
          invalidate(asid=a)           drop every translation of one space
          invalidate(pages=[(a, lp)])  drop specific translations
        """
        if pages is not None:
            keys = list(pages)
            for key in keys:
                self.tlb.invalidate_key(key)
                self._pending.pop(key, None)
                self._prefetched.discard(key)
            if self.range_max:
                # range-granular: a range covering a dead page splits into
                # its surviving segments (never outlives the invalidation)
                self._split_ranges_for(keys)
            return
        if asid is not None:
            for key in self.tlb.keys():
                if key[0] == asid:
                    self.tlb.invalidate_key(key)
            for key in [k for k in self._pending if k[0] == asid]:
                del self._pending[key]
            self._prefetched = {k for k in self._prefetched
                                if k[0] != asid}
            self._streams.pop(asid, None)
            return
        self.tlb.invalidate()
        self._pending.clear()
        self._prefetched.clear()
        self._streams.clear()
        self.epoch += 1

    def reconfigure_tlb(self, tlb: TLBConfig) -> None:
        """Online geometry switch (the auto-tuner's resize): swap in a
        fresh TranslationCache with the new geometry. A resize is a real
        hardware flush — every translation dies, in-flight prefetches are
        dropped, and the epoch bumps exactly once (the next serving table
        upload must be full). Cumulative stats carry over so the ``tlb:``
        schema stays monotonic across switches; the flush is counted as an
        invalidation like any other full flush."""
        if tlb == self.tlb_config:
            return
        stats = self.tlb.stats
        tenant_stats = self.tlb.tenant_stats
        self.tlb_config = tlb
        self.tlb = self._build_cache(tlb)
        self.tlb.stats = stats
        self.tlb.tenant_stats = tenant_stats
        self.tlb.stats.invalidations += 1
        self._pending.clear()
        self._prefetched.clear()
        self._streams.clear()
        self.epoch += 1

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        """The unified translation stats schema every layer reports:

          tlb    hits / misses / evictions / invalidations / walks /
                 conflict_misses / hit_rate
          walk   model name + walks / cycles (modeled cost); walkers with a
                 walk cache add a ``walk_cache:`` block (hits / misses /
                 geometry)
          epoch  full-flush count
          asids  live address spaces
        """
        walk = {"model": self.walk_model.name,
                **self.walk_model.stats.as_dict()}
        wc = getattr(self.walk_model, "walk_cache", None)
        if wc is not None:
            wcs = wc.stats
            walk["walk_cache"] = dict(
                hits=wcs.hits, misses=wcs.misses, evictions=wcs.evictions,
                n_entries=wc.n_entries, ways=wc.ways)
        if self.prefetch_config.enabled:
            ts = self.tlb.stats
            walk["prefetch"] = dict(
                policy=self.prefetch_config.policy,
                degree=self.prefetch_config.degree,
                distance=self.prefetch_config.distance,
                issued=ts.prefetch_issued, useful=ts.prefetch_useful,
                late=ts.prefetch_late,
                walk_cache_prefills=self.walk_cache_prefills)
        out = {"tlb": self.tlb.stats.as_dict(),
               "walk": walk,
               "epoch": self.epoch,
               "asids": self.n_spaces}
        if self.range_max:
            out["range"] = dict(
                max_run=self.range_max, n_ranges=self.tlb.n_ranges,
                fills=self.range_fills, hits=self.range_hits,
                coalesced_pages=self.coalesced_pages,
                splits=self.range_splits)
        if self._tenants:
            parts = self.tlb_config.partition_dict
            tenant = {}
            for name, dom in sorted(self._tenants.items()):
                block = dom.stats()
                block["ways"] = parts.get(name, 0)
                ts = self.tlb.tenant_stats.get(name)
                if ts is not None:
                    block["tlb"] = ts.as_dict()
                tenant[name] = block
            out["tenant"] = tenant
        return out


class TLBAutoTuner:
    """Online geometry auto-tuner over an :class:`IOMMU`'s TLB.

    Drive it with :meth:`observe_step` once per decode step (the
    ``PagedKVManager`` does this from ``translate_step``; trace replay does
    it per ``step`` event). Deterministic: the same access stream through
    the same config reproduces the same switch sequence.

    Phases: ``explore`` measures each candidate geometry for one window
    (the current geometry is measured first when it is a candidate),
    ``exploit`` stays on the best explored geometry and re-enters explore
    when its live hit rate drops ``retune_margin`` below the best explored
    value (workload shift). Every switch goes through
    :meth:`IOMMU.reconfigure_tlb` — flush + epoch bump."""

    def __init__(self, iommu: IOMMU, config: AutoTuneConfig):
        self.iommu = iommu
        self.config = config
        self.candidates: Tuple[TLBConfig, ...] = config.candidates
        # Measure the installed geometry first when it's a candidate (no
        # gratuitous flush at engine start).
        try:
            self._idx = self.candidates.index(iommu.tlb_config)
        except ValueError:
            self._idx = 0
            iommu.reconfigure_tlb(self.candidates[0])
        self._explored: Dict[int, float] = {}
        self._phase = "explore"
        self._steps = 0
        self._warmup = True        # discard the first window after a switch
        self.windows = 0
        self.switches = 0
        self.best_idx: Optional[int] = None
        self._snap = self._snapshot()

    def _snapshot(self) -> Tuple[int, int, int]:
        s = self.iommu.tlb.stats
        return s.hits, s.misses, s.conflict_misses

    def _window_stats(self) -> Tuple[float, int, int]:
        """(hit rate, conflict misses, demand accesses) over the window
        since the last snapshot — the live signal the tuner watches."""
        h0, m0, c0 = self._snap
        s = self.iommu.tlb.stats
        dh, dm = s.hits - h0, s.misses - m0
        total = dh + dm
        return ((dh / total if total else 0.0),
                s.conflict_misses - c0, total)

    def _switch_to(self, idx: int) -> None:
        if self.candidates[idx] != self.iommu.tlb_config:
            self.iommu.reconfigure_tlb(self.candidates[idx])
            self.switches += 1
            self._warmup = True     # post-flush window is cold: don't score
        self._idx = idx

    def observe_step(self) -> None:
        """Count one decode step; close a measurement window every
        ``interval_steps`` and explore/exploit accordingly."""
        self._steps += 1
        if self._steps % self.config.interval_steps:
            return
        rate, conflicts, accesses = self._window_stats()
        self._snap = self._snapshot()
        if accesses < self.config.min_accesses:
            return                              # idle window: no signal
        if self._warmup:
            # The window right after a geometry switch (or engine start)
            # measures compulsory refills, not the geometry — skip it so a
            # candidate is never condemned for the flush it began with.
            self._warmup = False
            return
        self.windows += 1
        if self._phase == "explore":
            self._explored[self._idx] = (rate, conflicts)
            nxt = next((i for i in range(len(self.candidates))
                        if i not in self._explored), None)
            if nxt is not None:
                self._switch_to(nxt)
                return
            # every candidate measured: exploit the best window hit rate;
            # ties break on fewer conflict misses (a set-constrained
            # geometry losing to associativity at equal rate), then fewer
            # entries, then candidate order
            self.best_idx = min(
                self._explored,
                key=lambda i: (-self._explored[i][0], self._explored[i][1],
                               self.candidates[i].n_entries, i))
            self._phase = "exploit"
            self._switch_to(self.best_idx)
            return
        best_rate = self._explored.get(self.best_idx, (0.0, 0))[0]
        if rate < best_rate - self.config.retune_margin:
            # workload shifted under us: measurements are stale, re-explore
            # (starting from the currently installed geometry — no flush)
            self._explored = {}
            self._phase = "explore"

    @property
    def converged(self) -> bool:
        return self._phase == "exploit"

    def stats(self) -> dict:
        """The ``autotune:`` stats block (see ARCHITECTURE.md)."""
        cur = self.iommu.tlb_config
        return dict(
            phase=self._phase, windows=self.windows, switches=self.switches,
            interval_steps=self.config.interval_steps,
            n_candidates=len(self.candidates),
            current=dict(n_entries=cur.n_entries, ways=cur.resolved_ways,
                         policy=cur.policy),
            explored={self._label(self.candidates[i]):
                      dict(hit_rate=round(r, 4), conflict_misses=c)
                      for i, (r, c) in sorted(self._explored.items())})

    @staticmethod
    def _label(c: TLBConfig) -> str:
        w = "full" if c.resolved_ways == c.n_entries else str(c.ways)
        return f"e{c.n_entries}.w{w}.{c.policy}"


__all__ = ["AutoTuneConfig", "CountingWalk", "IOAddressSpace", "IOMMU",
           "IsolationError", "PrefetchConfig", "Sv39Walk", "TLBAutoTuner",
           "TLBConfig", "TenantDomain", "WalkCacheConfig", "WalkModel",
           "WalkStats", "default_autotune_candidates"]
