"""Translation cache — the IOTLB analogue, with epoch self-invalidation.

Two users:
  * the performance simulator models the paper's 4-entry hardware IOTLB and
    counts PTW walks (3 sequential accesses on miss, RISC-V Sv39);
  * the serving engine uses a larger cache to decide which block-table rows
    actually changed since the last device upload (delta uploads) and when a
    full re-upload is required (epoch invalidation — paper Listing 1:
    flush + remap before offload).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Hashable, Optional, Tuple


@dataclass
class TLBStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    walks: int = 0           # page-table walks performed (one per miss)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, invalidations=self.invalidations,
                    walks=self.walks, hit_rate=round(self.hit_rate, 4))


class TranslationCache:
    """LRU (key -> value) cache with epoch invalidation."""

    def __init__(self, n_entries: int):
        assert n_entries >= 1
        self.n_entries = n_entries
        self._map: OrderedDict = OrderedDict()
        self.epoch = 0
        self.stats = TLBStats()

    def lookup(self, key: Hashable) -> Tuple[Optional[int], bool]:
        """Returns (value, hit)."""
        if key in self._map:
            self._map.move_to_end(key)
            self.stats.hits += 1
            return self._map[key], True
        self.stats.misses += 1
        return None, False

    def fill(self, key: Hashable, value) -> None:
        """Insert after a walk (miss path)."""
        self.stats.walks += 1
        if key in self._map:
            self._map.move_to_end(key)
            self._map[key] = value
            return
        if len(self._map) >= self.n_entries:
            self._map.popitem(last=False)
            self.stats.evictions += 1
        self._map[key] = value

    def translate(self, key: Hashable, walk_fn) -> Tuple[int, bool]:
        """lookup + walk-and-fill on miss. Returns (value, hit)."""
        val, hit = self.lookup(key)
        if hit:
            return val, True
        val = walk_fn(key)
        self.fill(key, val)
        return val, False

    def invalidate(self) -> None:
        """Epoch invalidation: drop everything (paper's self-invalidation)."""
        self._map.clear()
        self.epoch += 1
        self.stats.invalidations += 1

    def invalidate_key(self, key: Hashable) -> None:
        self._map.pop(key, None)

    def __len__(self) -> int:
        return len(self._map)
