"""Translation cache — the IOTLB analogue, with pluggable replacement.

This class is a *component* of the unified IOMMU front-end
(:mod:`repro.core.sva.iommu`): the paper's 4-entry hardware IOTLB and the
serving engine's large delta-upload cache are the same class configured
differently (``TLBConfig(n_entries, policy)``).  No module outside
``iommu.py`` constructs it directly — attach an address space to an
:class:`~repro.core.sva.iommu.IOMMU` instead.

Replacement policies (the Kim-et-al. translation design space):

  lru     hit refreshes recency; evict the least recently used entry
  fifo    insertion order only; hits never reorder
  lfu     evict the least frequently used entry (ties: oldest insertion)
  random  evict a uniformly random entry (seeded — traces stay reproducible)
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Hashable, Iterable, Optional, Tuple

import numpy as np

POLICIES = ("lru", "fifo", "lfu", "random")


@dataclass
class TLBStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    walks: int = 0           # page-table walks performed (one per genuine miss)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, invalidations=self.invalidations,
                    walks=self.walks, hit_rate=round(self.hit_rate, 4))


class TranslationCache:
    """(key -> value) cache with epoch invalidation and pluggable policy."""

    def __init__(self, n_entries: int, policy: str = "lru", seed: int = 0):
        assert n_entries >= 1
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} (expected one of {POLICIES})")
        self.n_entries = n_entries
        self.policy = policy
        self._map: OrderedDict = OrderedDict()
        self._freq: dict = {}
        self._rng = np.random.default_rng(seed)
        self.stats = TLBStats()

    def lookup(self, key: Hashable) -> Tuple[Optional[int], bool]:
        """Returns (value, hit)."""
        if key in self._map:
            if self.policy == "lru":
                self._map.move_to_end(key)
            elif self.policy == "lfu":
                self._freq[key] += 1
            self.stats.hits += 1
            return self._map[key], True
        self.stats.misses += 1
        return None, False

    def _evict_one(self) -> None:
        if self.policy in ("lru", "fifo"):
            victim = next(iter(self._map))
        elif self.policy == "lfu":
            # min frequency; ties broken by insertion order (OrderedDict scan)
            victim = min(self._map, key=lambda k: self._freq[k])
        else:                                     # random (seeded)
            keys = list(self._map)
            victim = keys[int(self._rng.integers(len(keys)))]
        del self._map[victim]
        self._freq.pop(victim, None)
        self.stats.evictions += 1

    def fill(self, key: Hashable, value, walked: bool = True) -> None:
        """Insert a translation. A walk is counted ONLY for a genuine
        walk-and-fill (``walked=True`` AND the key not already resident):
        refreshing a live entry (e.g. re-warming on ``extend``) or a host
        pre-warm at map time (``walked=False`` — the driver wrote the PTE,
        no device walk happened) must not inflate Fig.5-style walk
        counts."""
        if key in self._map:
            if self.policy == "lru":
                self._map.move_to_end(key)
            self._map[key] = value
            return
        if walked:
            self.stats.walks += 1
        if len(self._map) >= self.n_entries:
            self._evict_one()
        self._map[key] = value
        self._freq[key] = 1

    def invalidate(self) -> None:
        """Full invalidation: drop everything (paper's self-invalidation).
        The epoch counter lives on the owning IOMMU — the single owner of
        full-flush state."""
        self._map.clear()
        self._freq.clear()
        self.stats.invalidations += 1

    def invalidate_key(self, key: Hashable) -> None:
        self._map.pop(key, None)
        self._freq.pop(key, None)

    def keys(self) -> Iterable[Hashable]:
        return list(self._map.keys())

    def __contains__(self, key: Hashable) -> bool:
        return key in self._map

    def __len__(self) -> int:
        return len(self._map)
