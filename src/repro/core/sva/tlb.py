"""Translation cache — the IOTLB analogue, with pluggable replacement and
hardware geometry.

This class is a *component* of the unified IOMMU front-end
(:mod:`repro.core.sva.iommu`): the paper's 4-entry hardware IOTLB and the
serving engine's large delta-upload cache are the same class configured
differently (``TLBConfig(n_entries, policy, ways=...)``).  No module outside
``iommu.py`` constructs it directly — attach an address space to an
:class:`~repro.core.sva.iommu.IOMMU` instead.

Replacement policies (the Kim-et-al. translation design space):

  lru     hit refreshes recency; evict the least recently used entry
  fifo    insertion order only; hits never reorder
  lfu     evict the least frequently used entry (ties: oldest insertion)
  random  evict a uniformly random entry (seeded — traces stay reproducible)

Associativity (the second Kim-et-al. axis): ``ways`` splits the cache into
``n_entries // ways`` sets indexed by the logical page (the last integer
component of a tuple key); replacement state is kept per set. ``ways == 0``
or ``ways == n_entries`` is fully associative — one set, bit-identical to
the historical behavior. A lookup miss whose target set is full while the
cache as a whole still has free entries is counted as a *conflict miss*
(a fully-associative cache of the same capacity could have absorbed it);
with one set that situation cannot arise, so ``conflict_misses`` is always
0 for fully-associative configs.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Hashable, Iterable, List, Optional, Tuple

import numpy as np

POLICIES = ("lru", "fifo", "lfu", "random")


@dataclass
class TLBStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    walks: int = 0           # page-table walks performed (one per genuine miss)
    conflict_misses: int = 0  # misses a same-size fully-assoc cache had room for

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, invalidations=self.invalidations,
                    walks=self.walks, conflict_misses=self.conflict_misses,
                    hit_rate=round(self.hit_rate, 4))


class TranslationCache:
    """(key -> value) set-associative cache with epoch invalidation and
    pluggable policy. One set (``ways in (0, n_entries)``) is fully
    associative."""

    def __init__(self, n_entries: int, policy: str = "lru", seed: int = 0,
                 ways: int = 0):
        assert n_entries >= 1
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} (expected one of {POLICIES})")
        ways = ways or n_entries
        if ways < 1 or ways > n_entries or n_entries % ways:
            raise ValueError(
                f"ways={ways} must divide n_entries={n_entries} "
                f"(1 <= ways <= n_entries)")
        self.n_entries = n_entries
        self.ways = ways
        self.n_sets = n_entries // ways
        self.policy = policy
        self._sets: List[OrderedDict] = [OrderedDict()
                                         for _ in range(self.n_sets)]
        self._set0 = self._sets[0]      # fully-assoc fast path (hot loop)
        self._freq: dict = {}
        self._n = 0                               # total resident entries
        self._rng = np.random.default_rng(seed)   # shared across sets
        self.stats = TLBStats()

    # ------------------------------------------------------------- indexing
    def _set_index(self, key: Hashable) -> int:
        """Set selection on the logical page: the last integer component of
        a tuple key (the IOMMU keys ``(asid, logical_page)``), a bare int
        key, or ``hash(key)`` for anything else."""
        if self.n_sets == 1:
            return 0
        page = key
        if isinstance(page, tuple) and page:
            page = page[-1]
        if not isinstance(page, (int, np.integer)):
            page = hash(page)
        return int(page) % self.n_sets

    def lookup(self, key: Hashable) -> Tuple[Optional[int], bool]:
        """Returns (value, hit)."""
        s = self._set0 if self.n_sets == 1 \
            else self._sets[self._set_index(key)]
        if key in s:
            if self.policy == "lru":
                s.move_to_end(key)
            elif self.policy == "lfu":
                self._freq[key] += 1
            self.stats.hits += 1
            return s[key], True
        self.stats.misses += 1
        if len(s) >= self.ways and self._n < self.n_entries:
            self.stats.conflict_misses += 1
        return None, False

    def _evict_one(self, set_index: int) -> None:
        s = self._sets[set_index]
        if self.policy in ("lru", "fifo"):
            victim = next(iter(s))
        elif self.policy == "lfu":
            # min frequency; ties broken by insertion order (OrderedDict scan)
            victim = min(s, key=lambda k: self._freq[k])
        else:                                     # random (seeded)
            keys = list(s)
            victim = keys[int(self._rng.integers(len(keys)))]
        del s[victim]
        self._freq.pop(victim, None)
        self._n -= 1
        self.stats.evictions += 1

    def fill(self, key: Hashable, value, walked: bool = True) -> None:
        """Insert a translation. A walk is counted ONLY for a genuine
        walk-and-fill (``walked=True`` AND the key not already resident):
        refreshing a live entry (e.g. re-warming on ``extend``) or a host
        pre-warm at map time (``walked=False`` — the driver wrote the PTE,
        no device walk happened) must not inflate Fig.5-style walk
        counts. A refresh still counts as a *use* (it re-ups recency under
        ``lru`` and frequency under ``lfu`` — a page kept hot by map/extend
        re-warms must not look cold to the replacement policy)."""
        si = 0 if self.n_sets == 1 else self._set_index(key)
        s = self._sets[si]
        if key in s:
            if self.policy == "lru":
                s.move_to_end(key)
            elif self.policy == "lfu":
                self._freq[key] += 1
            s[key] = value
            return
        if walked:
            self.stats.walks += 1
        if len(s) >= self.ways:
            self._evict_one(si)
        s[key] = value
        self._freq[key] = 1
        self._n += 1

    def invalidate(self) -> None:
        """Full invalidation: drop everything (paper's self-invalidation).
        The epoch counter lives on the owning IOMMU — the single owner of
        full-flush state."""
        for s in self._sets:
            s.clear()
        self._freq.clear()
        self._n = 0
        self.stats.invalidations += 1

    def invalidate_key(self, key: Hashable) -> None:
        s = self._sets[self._set_index(key)]
        if s.pop(key, None) is not None:
            self._n -= 1
        self._freq.pop(key, None)

    def keys(self) -> Iterable[Hashable]:
        out: List[Hashable] = []
        for s in self._sets:
            out.extend(s.keys())
        return out

    def __contains__(self, key: Hashable) -> bool:
        s = self._set0 if self.n_sets == 1 \
            else self._sets[self._set_index(key)]
        return key in s

    def __len__(self) -> int:
        return self._n
