"""Translation cache — the IOTLB analogue, with pluggable replacement and
hardware geometry.

This class is a *component* of the unified IOMMU front-end
(:mod:`repro.core.sva.iommu`): the paper's 4-entry hardware IOTLB and the
serving engine's large delta-upload cache are the same class configured
differently (``TLBConfig(n_entries, policy, ways=...)``).  No module outside
``iommu.py`` constructs it directly — attach an address space to an
:class:`~repro.core.sva.iommu.IOMMU` instead.

Replacement policies (the Kim-et-al. translation design space):

  lru     hit refreshes recency; evict the least recently used entry
  fifo    insertion order only; hits never reorder
  lfu     evict the least frequently used entry (ties: oldest insertion)
  random  evict a uniformly random entry (seeded — traces stay reproducible)
  gdsfs   Greedy-Dual-Size-Frequency: every entry carries a priority
          ``clock + frequency * cost / span`` (``cost`` = the walk cost paid
          to fill it, ``span`` = how much the entry covers — 1 for a single
          page translation); evict the minimum priority and age the set's
          clock up to it. Size-aware: at equal frequency, an entry that was
          expensive to walk (LLC-cold, no walk-cache hit) outlives a cheap
          one, and a wide entry outlives a narrow one per byte of reach.
          Deterministic (no RNG), so traces stay reproducible.

Stats schema (``TLBStats.as_dict()``, the ``tlb:`` section every layer
reports — see ARCHITECTURE.md): hits / misses / evictions / invalidations /
walks / conflict_misses / prefetch_issued / prefetch_useful /
prefetch_late / hit_rate. The prefetch counters are driven by the owning
:class:`~repro.core.sva.iommu.IOMMU`'s prefetcher (always present, 0 when
prefetching is off).

Associativity (the second Kim-et-al. axis): ``ways`` splits the cache into
``n_entries // ways`` sets indexed by the logical page (the last integer
component of a tuple key); replacement state is kept per set. ``ways == 0``
or ``ways == n_entries`` is fully associative — one set, bit-identical to
the historical behavior. A lookup miss whose target set is full while the
cache as a whole still has free entries is counted as a *conflict miss*
(a fully-associative cache of the same capacity could have absorbed it);
with one set that situation cannot arise, so ``conflict_misses`` is always
0 for fully-associative configs.

Range entries (``range_aware=True``, SPARTA-style coalescing): a 3-tuple
key ``(asid, base_lpn, n_pages)`` is a *range entry* whose value is the
base physical page — one entry translates ``n_pages`` contiguous logical
pages to ``n_pages`` contiguous physical pages (``ppn = value + (lp -
base_lpn)``). Range keys set-index on ``base_lpn`` (NOT the last tuple
component — under an Sv39 walk cache 3-tuples are ``(asid, level,
top-bits)`` keys, which is why range decoding is an explicit constructor
opt-in rather than inferred from arity), weigh ``span=n_pages`` under
gdsfs, and are tracked in a per-ASID side index so ``range_covering``
resolves a logical page without scanning sets. The owning IOMMU is the
only producer of range keys (coalescing on fill, splitting on partial
invalidation — see iommu.py).

Way partitioning (``partitions={tenant: ways}``, the MMU-partitioning
axis of multi-tenant serving): each named tenant is granted a private
way budget *within every set*; the remaining ways form a shared pool for
un-partitioned traffic. A new fill whose tenant's partition is full
evicts only among that tenant's own entries, so one tenant's thrash can
never evict another tenant's (or the shared pool's) working set; a fill
into the shared pool reclaims shared entries first and never steals a
protected way. ``tenant_of`` (installed by the owning IOMMU) maps a key
to its tenant; per-tenant :class:`TLBStats` accumulate alongside the
global counters — a partitioned tenant's ``conflict_misses`` counts
misses its own partition was too small for while the cache as a whole
still had room. With no partitions configured every code path reduces to
the historical behavior bit-for-bit.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Hashable, Iterable, List, Optional, Tuple

import numpy as np

POLICIES = ("lru", "fifo", "lfu", "random", "gdsfs")


@dataclass
class TLBStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    walks: int = 0           # page-table walks performed (one per genuine miss)
    conflict_misses: int = 0  # misses a same-size fully-assoc cache had room for
    prefetch_issued: int = 0  # prefetch fills issued (walks done off the demand path)
    prefetch_useful: int = 0  # prefetched entries that saw a demand hit
    prefetch_late: int = 0    # useful, but demanded while the walk was in flight

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, invalidations=self.invalidations,
                    walks=self.walks, conflict_misses=self.conflict_misses,
                    prefetch_issued=self.prefetch_issued,
                    prefetch_useful=self.prefetch_useful,
                    prefetch_late=self.prefetch_late,
                    hit_rate=round(self.hit_rate, 4))


class TranslationCache:
    """(key -> value) set-associative cache with epoch invalidation and
    pluggable policy. One set (``ways in (0, n_entries)``) is fully
    associative."""

    def __init__(self, n_entries: int, policy: str = "lru", seed: int = 0,
                 ways: int = 0, range_aware: bool = False,
                 partitions: Optional[Dict[str, int]] = None,
                 tenant_of=None):
        assert n_entries >= 1
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} (expected one of {POLICIES})")
        ways = ways or n_entries
        if ways < 1 or ways > n_entries or n_entries % ways:
            raise ValueError(
                f"ways={ways} must divide n_entries={n_entries} "
                f"(1 <= ways <= n_entries)")
        self.n_entries = n_entries
        self.ways = ways
        self.n_sets = n_entries // ways
        self.policy = policy
        self.range_aware = range_aware
        # Way partitioning: tenant -> private ways per set; leftover ways
        # are the shared pool. tenant_of (key -> tenant | None) is
        # installed by the owning IOMMU — None means untenanted traffic.
        self._partitions: Dict[str, int] = dict(partitions) if partitions \
            else {}
        self._tenant_of = tenant_of
        if self._partitions:
            bad = {t: w for t, w in self._partitions.items() if w < 1}
            if bad:
                raise ValueError(f"partition ways must be >= 1 (got {bad})")
            reserved = sum(self._partitions.values())
            if reserved > self.ways:
                raise ValueError(
                    f"partitions reserve {reserved} ways but the cache has "
                    f"{self.ways} per set")
            self._shared_ways = self.ways - reserved
        else:
            self._shared_ways = self.ways
        #: per-tenant counters (lazily created on first tenant-owned access)
        self.tenant_stats: Dict[str, TLBStats] = {}
        # per-ASID side index of resident range entries: asid -> {base: n}.
        # Disjoint by construction (the IOMMU never fills overlapping
        # ranges), so range_covering has at most one answer.
        self._range_index: Dict[int, Dict[int, int]] = {}
        self._sets: List[OrderedDict] = [OrderedDict()
                                         for _ in range(self.n_sets)]
        self._set0 = self._sets[0]      # fully-assoc fast path (hot loop)
        self._freq: dict = {}
        # gdsfs bookkeeping: per-key [cost, span, priority] (frequency lives
        # in _freq) and a per-set aging clock (GDSF's L, raised to each
        # evicted priority so long-resident entries cannot starve new ones).
        self._meta: dict = {}
        self._clock: List[float] = [0.0] * self.n_sets
        self._n = 0                               # total resident entries
        self._rng = np.random.default_rng(seed)   # shared across sets
        self.stats = TLBStats()

    # ------------------------------------------------------------- indexing
    def _is_range_key(self, key: Hashable) -> bool:
        return self.range_aware and isinstance(key, tuple) and len(key) == 3

    def _set_index(self, key: Hashable) -> int:
        """Set selection on the logical page: the last integer component of
        a tuple key (the IOMMU keys ``(asid, logical_page)``), a bare int
        key, or ``hash(key)`` for anything else. Range keys
        ``(asid, base_lpn, n_pages)`` index on ``base_lpn``."""
        if self.n_sets == 1:
            return 0
        page = key
        if self._is_range_key(page):
            page = page[1]
        elif isinstance(page, tuple) and page:
            page = page[-1]
        if not isinstance(page, (int, np.integer)):
            page = hash(page)
        return int(page) % self.n_sets

    # -------------------------------------------------------- partitioning
    def _tstats(self, key: Hashable) -> Optional[TLBStats]:
        """The per-tenant stats block for ``key``'s owner (None when no
        tenant resolver is installed or the key is untenanted)."""
        if self._tenant_of is None:
            return None
        tenant = self._tenant_of(key)
        if tenant is None:
            return None
        ts = self.tenant_stats.get(tenant)
        if ts is None:
            ts = self.tenant_stats[tenant] = TLBStats()
        return ts

    def _group_of(self, key: Hashable) -> Optional[str]:
        """The replacement group ``key`` competes in: its tenant when that
        tenant holds a partition, else None (the shared pool)."""
        if not self._partitions or self._tenant_of is None:
            return None
        t = self._tenant_of(key)
        return t if t in self._partitions else None

    def _group_members(self, s: OrderedDict,
                       group: Optional[str]) -> List[Hashable]:
        return [k for k in s if self._group_of(k) == group]

    def partition_occupancy(self) -> Dict[Optional[str], List[int]]:
        """Resident entries per set, per partition group (None = shared
        pool). Diagnostics/tests: a partitioned tenant's count never
        exceeds its way budget in any set."""
        out: Dict[Optional[str], List[int]] = {
            t: [0] * self.n_sets for t in self._partitions}
        out[None] = [0] * self.n_sets
        for si, s in enumerate(self._sets):
            for k in s:
                out[self._group_of(k)][si] += 1
        return out

    def lookup(self, key: Hashable) -> Tuple[Optional[int], bool]:
        """Returns (value, hit)."""
        s = self._set0 if self.n_sets == 1 \
            else self._sets[self._set_index(key)]
        ts = None if self._tenant_of is None else self._tstats(key)
        if key in s:
            if self.policy == "lru":
                s.move_to_end(key)
            elif self.policy == "lfu":
                self._freq[key] += 1
            elif self.policy == "gdsfs":
                self._bump_gdsfs(key)
            self.stats.hits += 1
            if ts is not None:
                ts.hits += 1
            return s[key], True
        self.stats.misses += 1
        if len(s) >= self.ways and self._n < self.n_entries:
            self.stats.conflict_misses += 1
        if ts is not None:
            ts.misses += 1
            if self._partitions and self._n < self.n_entries:
                # The tenant-local analogue: the miss happened while the
                # tenant's own way budget in this set was exhausted.
                group = self._group_of(key)
                budget = self._partitions.get(group, self._shared_ways) \
                    if group is not None else self._shared_ways
                if len(self._group_members(s, group)) >= budget > 0:
                    ts.conflict_misses += 1
        return None, False

    def _bump_gdsfs(self, key: Hashable, cost: Optional[float] = None,
                    span: Optional[float] = None) -> None:
        """A use under gdsfs: frequency++ and re-price the priority at the
        current set clock (optionally refreshing cost/span)."""
        self._freq[key] += 1
        m = self._meta[key]
        if cost is not None and cost > 0:
            m[0] = cost
        if span is not None and span > 0:
            m[1] = span
        si = 0 if self.n_sets == 1 else self._set_index(key)
        m[2] = self._clock[si] + self._freq[key] * m[0] / m[1]

    def _evict_one(self, set_index: int,
                   among: Optional[set] = None) -> None:
        """Evict one entry from ``set_index`` by policy. ``among`` (way
        partitioning) restricts the candidate pool to those keys — the
        policy then picks its victim among them in the same order it would
        have considered them unrestricted. ``among=None`` is the
        historical full-set eviction, bit-for-bit."""
        s = self._sets[set_index]
        if self.policy in ("lru", "fifo"):
            victim = next(iter(s)) if among is None \
                else next(k for k in s if k in among)
        elif self.policy == "lfu":
            # min frequency; ties broken by insertion order (OrderedDict scan)
            pool = s if among is None else [k for k in s if k in among]
            victim = min(pool, key=lambda k: self._freq[k])
        elif self.policy == "gdsfs":
            # min priority; ties broken by insertion order. Aging: the set
            # clock rises to the evicted priority (GDSF's L), so a stale
            # high-cost entry eventually loses to fresh traffic.
            pool = s if among is None else [k for k in s if k in among]
            victim = min(pool, key=lambda k: self._meta[k][2])
            self._clock[set_index] = self._meta[victim][2]
        else:                                     # random (seeded)
            keys = list(s) if among is None else [k for k in s if k in among]
            victim = keys[int(self._rng.integers(len(keys)))]
        if self._tenant_of is not None:
            vt = self._tenant_of(victim)
            if vt is not None:
                vs = self.tenant_stats.get(vt)
                if vs is None:
                    vs = self.tenant_stats[vt] = TLBStats()
                vs.evictions += 1
        del s[victim]
        self._freq.pop(victim, None)
        self._meta.pop(victim, None)
        if self._is_range_key(victim):
            self._drop_range(victim)
        self._n -= 1
        self.stats.evictions += 1

    def _drop_range(self, key: Tuple[int, int, int]) -> None:
        asid_ranges = self._range_index.get(key[0])
        if asid_ranges is not None:
            asid_ranges.pop(key[1], None)
            if not asid_ranges:
                del self._range_index[key[0]]

    def fill(self, key: Hashable, value, walked: bool = True,
             cost: Optional[float] = None, span: float = 1.0) -> None:
        """Insert a translation. A walk is counted ONLY for a genuine
        walk-and-fill (``walked=True`` AND the key not already resident):
        refreshing a live entry (e.g. re-warming on ``extend``) or a host
        pre-warm at map time (``walked=False`` — the driver wrote the PTE,
        no device walk happened) must not inflate Fig.5-style walk
        counts. A refresh still counts as a *use* (it re-ups recency under
        ``lru``, frequency under ``lfu``, and priority under ``gdsfs`` — a
        page kept hot by map/extend re-warms must not look cold to the
        replacement policy).

        ``cost``/``span`` feed the gdsfs score (frequency × cost ÷ span):
        ``cost`` is the walk cost paid to produce this translation (None or
        0 prices as 1 — e.g. CountingWalk fills, where gdsfs degrades to a
        frequency policy), ``span`` what the entry covers. Ignored by every
        other policy."""
        si = 0 if self.n_sets == 1 else self._set_index(key)
        s = self._sets[si]
        if key in s:
            if self.policy == "lru":
                s.move_to_end(key)
            elif self.policy == "lfu":
                self._freq[key] += 1
            elif self.policy == "gdsfs":
                self._bump_gdsfs(key, cost, span)
            s[key] = value
            return
        if walked:
            self.stats.walks += 1
            ts = None if self._tenant_of is None else self._tstats(key)
            if ts is not None:
                ts.walks += 1
        if self._partitions:
            group = self._group_of(key)
            budget = self._partitions[group] if group is not None \
                else self._shared_ways
            members = self._group_members(s, group)
            if budget > 0 and len(members) >= budget:
                # the group's own budget is full: thrash stays inside it
                self._evict_one(si, among=set(members))
            elif len(s) >= self.ways:
                # set full while this group is under budget (shared pool
                # squeezed to zero, or partitions reconfigured): reclaim
                # shared entries first so protected ways stay protected.
                shared = self._group_members(s, None)
                self._evict_one(si, among=set(shared) if shared else None)
        elif len(s) >= self.ways:
            self._evict_one(si)
        s[key] = value
        self._freq[key] = 1
        if self.policy == "gdsfs":
            c = cost if cost is not None and cost > 0 else 1.0
            sp = span if span > 0 else 1.0
            self._meta[key] = [c, sp, self._clock[si] + c / sp]
        if self._is_range_key(key):
            self._range_index.setdefault(key[0], {})[key[1]] = key[2]
        self._n += 1

    def invalidate(self) -> None:
        """Full invalidation: drop everything (paper's self-invalidation).
        The epoch counter lives on the owning IOMMU — the single owner of
        full-flush state."""
        for s in self._sets:
            s.clear()
        self._freq.clear()
        self._meta.clear()
        self._clock = [0.0] * self.n_sets
        self._range_index.clear()
        self._n = 0
        self.stats.invalidations += 1

    def invalidate_key(self, key: Hashable) -> None:
        s = self._sets[self._set_index(key)]
        if s.pop(key, None) is not None:
            self._n -= 1
            if self._is_range_key(key):
                self._drop_range(key)
        self._freq.pop(key, None)
        self._meta.pop(key, None)

    # ---------------------------------------------------------- range entries
    def range_covering(self, asid: int,
                       lp: int) -> Optional[Tuple[int, int]]:
        """The resident range entry covering ``(asid, lp)`` as
        ``(base_lpn, n_pages)``, or None. Resident ranges are disjoint, so
        the lowest covering base (deterministic) is the only one."""
        asid_ranges = self._range_index.get(asid)
        if not asid_ranges:
            return None
        best: Optional[Tuple[int, int]] = None
        for base, n in asid_ranges.items():
            if base <= lp < base + n and (best is None or base < best[0]):
                best = (base, n)
        return best

    def ranges_overlapping(self, asid: int, lo: int,
                           hi: int) -> List[Tuple[int, int]]:
        """Resident range entries of ``asid`` intersecting ``[lo, hi]``
        (inclusive), ascending by base."""
        asid_ranges = self._range_index.get(asid)
        if not asid_ranges:
            return []
        return sorted((base, n) for base, n in asid_ranges.items()
                      if base <= hi and base + n - 1 >= lo)

    def peek(self, key: Hashable):
        """Value for ``key`` with NO stats and NO replacement-state bump —
        the IOMMU's split path reads a range's base this way."""
        s = self._set0 if self.n_sets == 1 \
            else self._sets[self._set_index(key)]
        return s.get(key)

    @property
    def n_ranges(self) -> int:
        return sum(len(r) for r in self._range_index.values())

    def keys(self) -> Iterable[Hashable]:
        out: List[Hashable] = []
        for s in self._sets:
            out.extend(s.keys())
        return out

    def __contains__(self, key: Hashable) -> bool:
        s = self._set0 if self.n_sets == 1 \
            else self._sets[self._set_index(key)]
        return key in s

    def __len__(self) -> int:
        return self._n
