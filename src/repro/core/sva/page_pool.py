"""Physical page pool — the host side of the shared-virtual-address layer.

Mirrors the paper's reserved-DRAM-vs-mapped-pages split: in ``zero_copy``
mode sequences get *mapped* pages (an IOVA range backed by whatever physical
pages are free); in ``copy`` mode admission additionally models the staging
copy into a physically-contiguous region (the paper's baseline).

One instance now typically backs the GLOBAL pool shared by every serving
slot (see core/sva/kv_manager.py), so utilization/high-water stats here are
the fleet-level memory signal, not a per-slot one.

Pure host-side bookkeeping (numpy/ints); the device arrays live in the
compiled step's paged pools. Reference counting enables prefix sharing
(multiple sequences mapping the same physical page, RadixAttention-style).

Free-list policy (deterministic, documented — the contiguity substrate):
the free list is kept **sorted by physical page number** at all times.
``alloc`` hands out the lowest-numbered free pages; ``free`` re-inserts
in address order (``bisect.insort``), so a freed run re-forms in place
and an alloc/free/alloc round-trip preserves run availability. The
historical LIFO recycle order maximized fragmentation for run allocation;
``tests/test_range_tlb.py`` pins the round-trip property. ``alloc_run``
adds first-fit physically-contiguous allocation on top, the producer side
of the IOMMU's range-coalesced IOTLB entries (see iommu.py).

Stats schema (``PoolStats.as_dict()``; surfaced as the ``pool_*`` gauges
of ``PagedKVManager.stats()`` — see ARCHITECTURE.md): allocs / frees /
shares (refcount++ events) / high_water (peak pages in use) /
failed_allocs (OutOfPages raises) / cow_copies (writes that had to
duplicate a shared page) / run_allocs (alloc_run requests satisfied
contiguously) / run_fallbacks (alloc_run requests that fell back to
discontiguous pages).
"""
from __future__ import annotations

from bisect import insort
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

if TYPE_CHECKING:                                  # pragma: no cover
    from repro.core.sva.sanitizer import SVASanitizer


class OutOfPages(RuntimeError):
    pass


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    shares: int = 0
    high_water: int = 0
    failed_allocs: int = 0
    cow_copies: int = 0           # writes that had to duplicate a shared page
    run_allocs: int = 0           # alloc_run satisfied with a contiguous run
    run_fallbacks: int = 0        # alloc_run fell back to discontiguous pages

    def as_dict(self):
        return dict(allocs=self.allocs, frees=self.frees, shares=self.shares,
                    high_water=self.high_water,
                    failed_allocs=self.failed_allocs,
                    cow_copies=self.cow_copies,
                    run_allocs=self.run_allocs,
                    run_fallbacks=self.run_fallbacks)


class PagePool:
    """Fixed-size pool of physical pages with refcounts and an
    address-ordered free list (lowest page first; see module docstring)."""

    def __init__(self, n_pages: int, page_size: int,
                 sanitizer: Optional["SVASanitizer"] = None):
        self.n_pages = n_pages
        self.page_size = page_size
        # Sorted ascending at all times: alloc takes from the front,
        # free re-inserts in address order, so freed runs re-form.
        self._free: List[int] = list(range(n_pages))
        self._ref = np.zeros(n_pages, dtype=np.int32)
        self.stats = PoolStats()
        # svasan shadow-state hook (core/sva/sanitizer.py). None (default)
        # keeps every hot path one attribute test away from the historical
        # behavior; attach via SVASanitizer.attach_pool().
        self.sanitizer: Optional["SVASanitizer"] = None
        if sanitizer is not None:
            sanitizer.attach_pool(self)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - self.n_free

    def alloc(self, n: int) -> List[int]:
        """Allocate the ``n`` lowest-numbered free pages (ascending)."""
        if n > len(self._free):
            self.stats.failed_allocs += 1
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        pages = self._free[:n]
        del self._free[:n]
        return self._claim(pages)

    def alloc_run(self, n: int) -> List[int]:
        """Allocate ``n`` pages, physically contiguous if any free run of
        length >= n exists (first-fit over the sorted free list); otherwise
        fall back to the lowest-numbered discontiguous pages. Never fails
        when ``alloc(n)`` would succeed — contiguity is a hint, capacity is
        the contract."""
        if n > len(self._free):
            self.stats.failed_allocs += 1
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        if n <= 1:
            self.stats.run_allocs += 1
            pages = self._free[:n]
            del self._free[:n]
            return self._claim(pages)
        free = self._free
        run_start = 0                     # index into free where the run began
        for i in range(1, len(free)):
            if free[i] != free[i - 1] + 1:
                run_start = i
            if i - run_start + 1 == n:    # first fit
                lo = run_start
                pages = free[lo:lo + n]
                del free[lo:lo + n]
                self.stats.run_allocs += 1
                return self._claim(pages)
        self.stats.run_fallbacks += 1
        pages = free[:n]
        del free[:n]
        return self._claim(pages)

    def _claim(self, pages: List[int]) -> List[int]:
        if self.sanitizer is not None:
            self.sanitizer.on_alloc(self, pages)
        for p in pages:
            assert self._ref[p] == 0
            self._ref[p] = 1
        self.stats.allocs += len(pages)
        self.stats.high_water = max(self.stats.high_water, self.n_used)
        return pages

    def share(self, pages: List[int]) -> None:
        """Refcount++ (prefix sharing: a second sequence maps the same pages)."""
        if self.sanitizer is not None:
            self.sanitizer.on_share(self, pages)
        for p in pages:
            assert self._ref[p] > 0, f"share of unmapped page {p}"
            self._ref[p] += 1
        self.stats.shares += len(pages)

    def free(self, pages: List[int]) -> None:
        # sanitizer first: a double-free raises a precise SanitizerError
        # before the bare assert below would trip
        if self.sanitizer is not None:
            self.sanitizer.on_free(self, pages)
        for p in pages:
            assert self._ref[p] > 0, f"double free of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                # order-preserving free: re-insert in address order so a
                # freed run re-forms in place (see module docstring)
                insort(self._free, p)
        self.stats.frees += len(pages)

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def total_refs(self) -> int:
        """Total live mappings (sum of refcounts). This is the gauge
        tenant page quotas meter against: a prefix page shared by two
        sequences counts twice, exactly like ``len(SeqState.pages)`` does
        in ``PagedKVManager.tenant_pages_used`` — so the sum of every
        tenant's mapped pages plus the prefix cache's own holds must
        reconcile with this number (pinned by tests/test_multitenant.py)."""
        return int(self._ref.sum())

    def is_shared(self, page: int) -> bool:
        """True when more than one mapping references ``page`` — a write
        through any single mapping must copy-on-write first."""
        return int(self._ref[page]) > 1

    @property
    def utilization(self) -> float:
        """Fraction of pages currently mapped (global-pool pressure gauge)."""
        return self.n_used / self.n_pages if self.n_pages else 0.0

    def free_runs(self) -> List[Tuple[int, int]]:
        """Maximal contiguous free runs as ``(start_page, length)`` pairs,
        ascending — the fragmentation picture ``alloc_run`` allocates from."""
        runs: List[Tuple[int, int]] = []
        for p in self._free:
            if runs and p == runs[-1][0] + runs[-1][1]:
                runs[-1] = (runs[-1][0], runs[-1][1] + 1)
            else:
                runs.append((p, 1))
        return runs

    def check_invariants(self) -> None:
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free list has duplicates"
        assert self._free == sorted(self._free), "free list out of order"
        for p in range(self.n_pages):
            if p in free_set:
                assert self._ref[p] == 0, f"free page {p} has refs"
            else:
                assert self._ref[p] > 0, f"used page {p} has no refs"
