"""Physical page pool — the host side of the shared-virtual-address layer.

Mirrors the paper's reserved-DRAM-vs-mapped-pages split: in ``zero_copy``
mode sequences get *mapped* pages (an IOVA range backed by whatever physical
pages are free); in ``copy`` mode admission additionally models the staging
copy into a physically-contiguous region (the paper's baseline).

One instance now typically backs the GLOBAL pool shared by every serving
slot (see core/sva/kv_manager.py), so utilization/high-water stats here are
the fleet-level memory signal, not a per-slot one.

Pure host-side bookkeeping (numpy/ints); the device arrays live in the
compiled step's paged pools. Reference counting enables prefix sharing
(multiple sequences mapping the same physical page, RadixAttention-style).

Stats schema (``PoolStats.as_dict()``; surfaced as the ``pool_*`` gauges
of ``PagedKVManager.stats()`` — see ARCHITECTURE.md): allocs / frees /
shares (refcount++ events) / high_water (peak pages in use) /
failed_allocs (OutOfPages raises) / cow_copies (writes that had to
duplicate a shared page).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional

import numpy as np

if TYPE_CHECKING:                                  # pragma: no cover
    from repro.core.sva.sanitizer import SVASanitizer


class OutOfPages(RuntimeError):
    pass


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    shares: int = 0
    high_water: int = 0
    failed_allocs: int = 0
    cow_copies: int = 0           # writes that had to duplicate a shared page

    def as_dict(self):
        return dict(allocs=self.allocs, frees=self.frees, shares=self.shares,
                    high_water=self.high_water,
                    failed_allocs=self.failed_allocs,
                    cow_copies=self.cow_copies)


class PagePool:
    """Fixed-size pool of physical pages with refcounts and a LIFO free list."""

    def __init__(self, n_pages: int, page_size: int,
                 sanitizer: Optional["SVASanitizer"] = None):
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._ref = np.zeros(n_pages, dtype=np.int32)
        self.stats = PoolStats()
        # svasan shadow-state hook (core/sva/sanitizer.py). None (default)
        # keeps every hot path one attribute test away from the historical
        # behavior; attach via SVASanitizer.attach_pool().
        self.sanitizer: Optional["SVASanitizer"] = None
        if sanitizer is not None:
            sanitizer.attach_pool(self)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - self.n_free

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            self.stats.failed_allocs += 1
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        if self.sanitizer is not None:
            self.sanitizer.on_alloc(self, pages)
        for p in pages:
            assert self._ref[p] == 0
            self._ref[p] = 1
        self.stats.allocs += n
        self.stats.high_water = max(self.stats.high_water, self.n_used)
        return pages

    def share(self, pages: List[int]) -> None:
        """Refcount++ (prefix sharing: a second sequence maps the same pages)."""
        if self.sanitizer is not None:
            self.sanitizer.on_share(self, pages)
        for p in pages:
            assert self._ref[p] > 0, f"share of unmapped page {p}"
            self._ref[p] += 1
        self.stats.shares += len(pages)

    def free(self, pages: List[int]) -> None:
        # sanitizer first: a double-free raises a precise SanitizerError
        # before the bare assert below would trip
        if self.sanitizer is not None:
            self.sanitizer.on_free(self, pages)
        for p in pages:
            assert self._ref[p] > 0, f"double free of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
        self.stats.frees += len(pages)

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def is_shared(self, page: int) -> bool:
        """True when more than one mapping references ``page`` — a write
        through any single mapping must copy-on-write first."""
        return int(self._ref[page]) > 1

    @property
    def utilization(self) -> float:
        """Fraction of pages currently mapped (global-pool pressure gauge)."""
        return self.n_used / self.n_pages if self.n_pages else 0.0

    def check_invariants(self) -> None:
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free list has duplicates"
        for p in range(self.n_pages):
            if p in free_set:
                assert self._ref[p] == 0, f"free page {p} has refs"
            else:
                assert self._ref[p] > 0, f"used page {p} has no refs"
