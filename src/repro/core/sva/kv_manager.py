"""Paged KV-cache manager: binds the SVA layer to the compiled model's
per-slot cache view.

The compiled decode step sees, per batch slot, a page pool row of
``max_pages`` pages and an int32 block table (see models/attention.PagedKV).
This manager owns the *global* allocation state: which physical page of a
slot's row backs which logical page of the sequence, prefix sharing,
eviction, and the delta-upload bookkeeping through the translation cache.

Zero-copy vs copy admission (paper Fig. 2, at serving granularity):
  zero_copy — admission writes table rows only; KV data is produced in
              place by prefill.
  copy      — admission is modeled as a physical re-copy of the prompt's KV
              into slot-contiguous pages (tracked in stats.bytes_copied and
              charged on-device by the benchmark harness).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.sva.mapping import SVASpace
from repro.core.sva.page_pool import OutOfPages, PagePool
from repro.core.sva.tlb import TranslationCache


@dataclass
class SeqState:
    seq_id: int
    slot: int
    length: int                   # tokens in cache
    pages: List[int]              # physical pages (slot-row indices)
    max_tokens: int
    tokens: List[int] = field(default_factory=list)   # generated so far
    done: bool = False


class PagedKVManager:
    """Per-slot page allocation + block tables for a fixed-B decode step."""

    def __init__(self, n_slots: int, max_pages_per_slot: int, page_size: int,
                 kv_bytes_per_token: int = 0, offload_mode: str = "zero_copy"):
        assert offload_mode in ("zero_copy", "copy")
        self.n_slots = n_slots
        self.max_pages = max_pages_per_slot
        self.page_size = page_size
        self.kv_bytes_per_token = kv_bytes_per_token
        self.offload_mode = offload_mode
        # One pool per slot (the compiled step's pool rows are per-slot);
        # a single SVASpace tracks stats across all of them.
        self.pools = [PagePool(max_pages_per_slot, page_size)
                      for _ in range(n_slots)]
        self.space = SVASpace(PagePool(1, page_size))   # stats aggregator
        self.tlb = TranslationCache(n_entries=4096)
        self.free_slots = list(range(n_slots - 1, -1, -1))
        self.seqs: Dict[int, SeqState] = {}
        self.tables = np.zeros((n_slots, max_pages_per_slot), np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self.dirty_rows = set(range(n_slots))

    # ------------------------------------------------------------ admission
    def admit(self, seq_id: int, prompt_len: int, max_tokens: int
              ) -> Optional[SeqState]:
        """Allocate a slot + pages for a prompt; None if no slot free."""
        if not self.free_slots:
            return None
        need = -(-(prompt_len + max_tokens) // self.page_size)
        need = min(need, self.max_pages)
        slot = self.free_slots[-1]
        pool = self.pools[slot]
        try:
            pages = pool.alloc(need)
        except OutOfPages:
            return None
        self.free_slots.pop()
        st = SeqState(seq_id, slot, prompt_len, pages, max_tokens)
        self.seqs[seq_id] = st
        # Row is kept a PERMUTATION of [0, max_pages): allocated pages first,
        # remaining physical pages as filler — prefill's scatter inverts it.
        used = set(pages)
        filler = [p for p in range(self.max_pages) if p not in used]
        row = np.asarray(pages + filler, np.int32)
        self.tables[slot] = row
        self.lengths[slot] = prompt_len
        self.dirty_rows.add(slot)
        self.space.stats.map_calls += 1
        self.space.stats.table_entries_written += len(pages)
        self.space.stats.bytes_mapped += prompt_len * self.kv_bytes_per_token
        if self.offload_mode == "copy":
            self.space.stats.bytes_copied += prompt_len * self.kv_bytes_per_token
        for lp, pp in enumerate(pages):
            self.tlb.fill((slot, lp), pp)
        return st

    def append_token(self, seq_id: int, token: int) -> None:
        st = self.seqs[seq_id]
        st.tokens.append(token)
        st.length += 1
        self.lengths[st.slot] = st.length
        needed = -(-st.length // self.page_size)
        if needed > len(st.pages) and len(st.pages) < self.max_pages:
            new = self.pools[st.slot].alloc(1)
            lp = len(st.pages)
            st.pages.extend(new)
            # swap to keep the row a permutation
            row = self.tables[st.slot]
            j = int(np.where(row == new[0])[0][0])
            row[lp], row[j] = row[j], row[lp]
            self.dirty_rows.add(st.slot)
            self.space.stats.table_entries_written += 1
            self.tlb.fill((st.slot, lp), new[0])
        if len(st.tokens) >= st.max_tokens:
            st.done = True

    def release(self, seq_id: int) -> None:
        st = self.seqs.pop(seq_id)
        self.pools[st.slot].free(st.pages)
        self.free_slots.append(st.slot)
        self.lengths[st.slot] = 0
        self.space.stats.unmap_calls += 1
        # self-invalidation (paper Listing 1): translations for this slot die
        for lp in range(len(st.pages)):
            self.tlb.invalidate_key((st.slot, lp))
        self.dirty_rows.add(st.slot)

    # ------------------------------------------------------------ device view
    def delta_rows(self) -> List[int]:
        """Slot rows whose tables changed since last upload (delta upload —
        the serving-level analogue of a warm IOTLB)."""
        rows = sorted(self.dirty_rows)
        self.dirty_rows.clear()
        return rows

    def device_tables(self) -> np.ndarray:
        return self.tables.copy()

    def device_lengths(self) -> np.ndarray:
        return self.lengths.copy()

    def active_seqs(self) -> List[SeqState]:
        return [s for s in self.seqs.values() if not s.done]

    def stats(self) -> dict:
        return {"sva": self.space.stats.as_dict(),
                "tlb": self.tlb.stats.as_dict(),
                "pool_used": sum(p.n_used for p in self.pools),
                "pool_free": sum(p.n_free for p in self.pools)}
