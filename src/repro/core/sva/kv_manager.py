"""Paged KV-cache manager: binds the SVA layer to the compiled model's
cache view.

Two layouts:

  global   (zero-copy serving) ONE PagePool shared by every slot. The
           compiled step sees a single physical page pool per KV layer
           (``n_slots * max_pages`` pages) and per-slot int32 block tables
           indexing into it. Unallocated table entries hold the NULL page id
           (== total page count): device writes through them are dropped and
           gathers read as zero. Admission writes table rows only — KV data
           is produced in place by the batched prefill scatter.

  per_slot (copy baseline) one PagePool per slot; each table row is a
           permutation of [0, max_pages) over that slot's private pool. This
           is the layout the staging-copy admission path (the paper's
           baseline) uses.

Copy-on-write prefix sharing (global layout): a :class:`PrefixIndex` keyed
by token-content hash chains over FULL pages lets ``admit`` map a prompt's
already-resident prefix pages via refcount++ instead of fresh allocation —
the paper's map-don't-copy result applied across *requests* (multiple agents
translating to the same physical pages, RadixAttention-style). The index
also caches one partially-filled tail page per prompt, so an identical
prompt maps end-to-end with zero fresh prefill. Shared pages are immutable:
``append_token`` detects a write landing in a page whose refcount > 1 and
either *steals* it back from the index (sole other owner) or performs a CoW
duplication — a fresh page plus a queued device-side page copy (drained by
the engine via ``drain_cow_copies`` before the next decode step). ``release``
only drops the sequence's own references, so prefix pages survive completion
as a warm prefix cache; the index LRU-evicts leaf entries when the pool runs
dry.

Translation goes through the unified :class:`~repro.core.sva.iommu.IOMMU`
front-end: one PASID-style address space per batch slot, a large
``CountingWalk`` TLB (the delta-upload cache), and ``translate_step()``
running every decode step's page gathers through it — the live-traffic
counterpart of the simulator's 4-entry hardware IOTLB (same class,
different ``TLBConfig``). Delta-upload bookkeeping: rows whose tables
changed since the last device upload accumulate in ``dirty_rows`` and are
drained with ``delta_rows()`` — the serving-level analogue of a warm IOTLB.
``invalidate_epoch()`` models the paper's Listing-1 flush: every
translation dies (the IOMMU epoch bumps exactly once) and the next upload
must be a full-table upload.

Adaptive front-end hooks (both default-off):

  * ``tlb_prefetch=PrefetchConfig(...)`` arms the IOMMU's IOTLB prefetcher
    on the decode gather stream (Kurth-et-al. MMU-aware DMA prefetch);
  * ``autotune=AutoTuneConfig(...)`` attaches a :class:`TLBAutoTuner` that
    ``translate_step`` advances once per decode step — the serving TLB
    geometry then follows the live hit-rate/conflict-miss signal instead
    of a static per-deployment pick from ``benchmarks/tlb_sweep.py``
    (a switch = flush + epoch bump, so the engine's next table upload is
    full).

Disaggregated serving (``migrate``): a finished prefill's pages hand off
between two ASIDs over the SAME pool — modeled remote DMA in which the
source ASID translates every page through a transfer IOMMU (per-page
PTW/IOTLB cost under the fabric's walk model) before the pages either
re-attach zero-copy (``share``: refcount hand-off + table move, the SVA
payoff) or are duplicated device-side (``copy``: the staged baseline).
Accounting accumulates in :class:`TransferStats` (the ``transfer:`` stats
block).

Stats schema (``stats()``; see ARCHITECTURE.md): ``sva:`` host-side mode
counters (disjoint zero-copy vs staging), ``tlb:`` the IOMMU's TLBStats
dict, ``iommu:`` {walk, epoch, asids, tlb_entries, tlb_ways, tlb_policy,
autotune: when tuning}, ``pool_*`` page-pool gauges, ``prefix:`` the
PrefixIndex block (hits/misses/pages_shared/tokens_saved/evictions/
steals/cached_pages/policy/max_pages) when sharing is on, ``tenant:``
per-tenant quota/occupancy/TLB blocks when tenants are configured,
``transfer:`` the TransferStats block once a migration has run.

Multi-tenant serving (``tenants={name: {quota_pages,
quota_prefix_pages, tlb_ways}}``): each tenant gets a
:class:`~repro.core.sva.iommu.TenantDomain` (admission attaches every
slot under its owner, so the decode gather stream is isolation-checked
each step), a page quota admission defers on and the scheduler preempts
over, a prefix-cache scope of its own (identical cross-tenant prompts
NEVER share pages — the index keys are tenant-scoped at the root), an
optional private prefix-page cap, and optionally private IOTLB ways
(``TLBConfig.partitions``). No tenants configured = bit-identical to the
single-tenant manager.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sva.iommu import (IOMMU, AutoTuneConfig, CountingWalk,
                                  PrefetchConfig, TLBAutoTuner, TLBConfig)
from repro.core.sva.mapping import SVAStats
from repro.core.sva.page_pool import OutOfPages, PagePool
from repro.core.sva.sanitizer import SVASanitizer
from repro.core.sva.sanitizer import resolve as _resolve_sanitize


class CapacityError(ValueError):
    """Request can NEVER be admitted (prompt+max_tokens exceeds slot
    capacity) — distinct from a transient OutOfPages/no-slot condition."""


@dataclass
class SeqState:
    seq_id: int
    slot: int
    length: int                   # tokens in cache
    pages: List[int]              # physical page ids
    max_tokens: int
    tokens: List[int] = field(default_factory=list)   # generated so far
    done: bool = False
    shared_pages: int = 0         # leading pages mapped from the prefix index
    prefill_start: int = 0        # first prompt position that needs compute
    tenant: Optional[str] = None  # owning tenant domain (None = untenanted)


class _PrefixNode:
    """One FULL page of prompt tokens in the content-addressed radix chain.

    Children are keyed by the NEXT page's token tuple; ``partials`` caches
    partially-filled tail pages (content tuple -> [page, lru, uses]). Every
    node and every partial entry owns exactly one pool reference on its
    page."""

    __slots__ = ("page", "parent", "key", "children", "partials",
                 "last_used", "uses", "tenant")

    def __init__(self, page: Optional[int], parent: Optional["_PrefixNode"],
                 key: Optional[Tuple[int, ...]],
                 tenant: Optional[str] = None):
        self.page = page
        self.parent = parent
        self.key = key
        self.children: Dict[Tuple[int, ...], _PrefixNode] = {}
        self.partials: Dict[Tuple[int, ...], List] = {}  # content -> [page, lru, uses]
        self.last_used = 0
        self.uses = 0
        self.tenant = tenant      # owning tenant (root-level scope tag)


@dataclass
class PrefixStats:
    hits: int = 0                 # admissions that mapped >= 1 shared page
    misses: int = 0
    pages_shared: int = 0         # share events at admission
    tokens_saved: int = 0         # prompt tokens whose prefill was skipped
    evictions: int = 0            # LRU entries dropped under page pressure
    steals: int = 0               # index entries reclaimed by their writer

    def as_dict(self):
        return dict(hits=self.hits, misses=self.misses,
                    pages_shared=self.pages_shared,
                    tokens_saved=self.tokens_saved,
                    evictions=self.evictions, steals=self.steals)


PREFIX_POLICIES = ("lru", "lfu", "gdsfs")


class PrefixIndex:
    """Longest-shared-prefix lookup over admitted prompts, token-hash per
    full page (plus one cached partial tail page per prompt).

    Eviction under page pressure is policy-pluggable — ``lru`` recency,
    ``lfu`` frequency (keeps a popular system prompt resident even when a
    burst of one-off prompts churns the pool), or ``gdsfs`` size-aware
    frequency: score = uses × covered-tokens ÷ page-span (the TLB's
    GDSFS score with the prefill compute saved per hit as the cost term),
    so at equal frequency a partial tail page covering 3 tokens is shed
    before a full page covering ``page_size`` — both hold one page, but
    the full page saves more recompute per hit. ``max_pages`` caps the
    warm cache's footprint: after every admission the index sheds entries
    it solely owns until it fits (live sequences' pages never count
    against eviction — freeing them returns nothing)."""

    def __init__(self, page_size: int, policy: str = "lru",
                 max_pages: int = 0):
        if policy not in PREFIX_POLICIES:
            raise ValueError(
                f"policy={policy!r} (expected one of {PREFIX_POLICIES})")
        self.page_size = page_size
        self.policy = policy
        self.max_pages = max_pages          # 0 = uncapped
        self.root = _PrefixNode(None, None, None)
        self._clock = 0
        self._partial_by_page: Dict[int, Tuple[_PrefixNode, Tuple[int, ...]]] = {}
        self._node_by_page: Dict[int, _PrefixNode] = {}
        self.stats = PrefixStats()

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @property
    def n_cached_pages(self) -> int:
        return len(self._node_by_page) + len(self._partial_by_page)

    # ------------------------------------------------------------- lookup
    @staticmethod
    def _scoped(tenant: Optional[str],
                key: Tuple[int, ...]) -> Tuple:
        """Root-level key scoping: a tenant's chains hang off root children
        keyed ``(tenant, tok...)`` — deeper levels are reachable only
        through them, so one scope tag isolates the whole subtree.
        ``tenant=None`` keys are byte-identical to the untenanted index
        (adversarial cross-tenant prefix collisions CANNOT share pages)."""
        return key if tenant is None else (tenant,) + key

    def match(self, tokens: Sequence[int],
              tenant: Optional[str] = None) -> Tuple[List[int], int]:
        """Longest shared prefix of ``tokens`` already resident in the pool
        (within ``tenant``'s scope — cached KV never crosses the tenant
        boundary even for identical token content).

        Returns (pages, matched_tokens): full pages matched by content chain,
        plus the cached partial tail page iff it covers the ENTIRE remaining
        prompt (so prefill never has to write into the middle of a shared
        page — writes into shared pages only ever come from decode appends,
        which CoW)."""
        p = self.page_size
        now = self._tick()
        node = self.root
        pages: List[int] = []
        i = 0
        while i + p <= len(tokens):
            key = tuple(tokens[i:i + p])
            if node is self.root:
                key = self._scoped(tenant, key)
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = now
            child.uses += 1
            pages.append(child.page)
            node = child
            i += p
        rem = tuple(tokens[i:])
        matched = i
        pkey = self._scoped(tenant, rem) if node is self.root else rem
        if rem and pkey in node.partials:
            entry = node.partials[pkey]
            entry[1] = now
            entry[2] += 1
            pages.append(entry[0])
            matched += len(rem)
        return pages, matched

    # ----------------------------------------------------------- register
    def register(self, tokens: Sequence[int], pages: Sequence[int],
                 pool: PagePool, tenant: Optional[str] = None) -> None:
        """Insert a newly admitted prompt's pages under ``tenant``'s scope.
        Each NEW entry takes one pool reference (the warm-cache ownership
        that outlives the sequence); already-present entries are left
        untouched (the admitted sequence mapped those very pages via
        ``match``)."""
        p = self.page_size
        now = self._tick()
        node = self.root
        i = 0
        li = 0
        while i + p <= len(tokens):
            key = tuple(tokens[i:i + p])
            if node is self.root:
                key = self._scoped(tenant, key)
            child = node.children.get(key)
            if child is None:
                child = _PrefixNode(pages[li], node, key, tenant=tenant)
                child.uses = 1            # the registering admission
                node.children[key] = child
                self._node_by_page[pages[li]] = child
                pool.share([pages[li]])
            child.last_used = now
            node = child
            i += p
            li += 1
        rem = tuple(tokens[i:])
        pkey = self._scoped(tenant, rem) if node is self.root else rem
        if rem and pkey not in node.partials and li < len(pages):
            node.partials[pkey] = [pages[li], now, 1]
            self._partial_by_page[pages[li]] = (node, pkey)
            pool.share([pages[li]])

    # ----------------------------------------------------------- eviction
    def _score(self, uses: int, recency: int, covered: int):
        """Eviction key (min is evicted): recency under ``lru``,
        (frequency, recency) under ``lfu``, (frequency × covered-tokens ÷
        page-span, recency) under ``gdsfs`` — the size-aware score."""
        if self.policy == "lru":
            return recency
        if self.policy == "lfu":
            return (uses, recency)
        return (uses * covered / self.page_size, recency)     # gdsfs

    @staticmethod
    def _content_len(content: Tuple) -> int:
        """Token count a partial's content key covers (a root-level scoped
        key carries the tenant tag first — not a token)."""
        return len(content) - (1 if content
                               and isinstance(content[0], str) else 0)

    def _tenant_of_entry(self, kind: str, node: "_PrefixNode",
                         key: Tuple) -> Optional[str]:
        """Owning tenant of an evictable entry: the node's root-level scope
        tag, or — for a partial hanging directly off the root — the scope
        prefix of its content key."""
        if kind == "node" or node is not self.root:
            return node.tenant
        return key[0] if key and isinstance(key[0], str) else None

    def _candidates(self):
        """(score, kind, node, key) for every evictable entry — partial
        pages, and leaf full-page nodes (no children, no partials); parents
        become evictable bottom-up once their subtree is gone."""
        out = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            for content, (page, lru, uses) in n.partials.items():
                out.append((self._score(uses, lru,
                                        self._content_len(content)),
                            "partial", n, content))
            if n is not self.root and not n.children and not n.partials:
                out.append((self._score(n.uses, n.last_used, self.page_size),
                            "node", n, n.key))
        return out

    def cached_pages_by_tenant(self) -> Dict[Optional[str], int]:
        """Warm-cache footprint per tenant scope (None = untenanted) — the
        gauge per-tenant prefix quotas are enforced against."""
        out: Dict[Optional[str], int] = {}
        for node in self._node_by_page.values():
            out[node.tenant] = out.get(node.tenant, 0) + 1
        for node, content in self._partial_by_page.values():
            t = self._tenant_of_entry("partial", node, content)
            out[t] = out.get(t, 0) + 1
        return out

    def evict_one(self, pool: PagePool,
                  tenant: object = False) -> bool:
        """Drop the policy-selected evictable entry whose page the index is
        the SOLE owner of (refcount 1 — freeing it actually returns a
        page). Entries still referenced by live sequences are kept: evicting
        them frees nothing and only destroys future sharing value.
        ``tenant`` (pass a name or None) restricts eviction to one tenant
        scope — per-tenant prefix quotas shed only their owner's entries;
        the ``False`` default considers every scope. Returns False when no
        eviction can free a page."""
        page_of = lambda c: c[2].partials[c[3]][0] if c[1] == "partial" \
            else c[2].page
        cands = [c for c in self._candidates() if pool.refcount(page_of(c)) == 1]
        if tenant is not False:
            cands = [c for c in cands
                     if self._tenant_of_entry(c[1], c[2], c[3]) == tenant]
        if not cands:
            return False
        _, kind, node, key = min(cands, key=lambda c: c[0])
        if kind == "partial":
            page = node.partials.pop(key)[0]
            self._partial_by_page.pop(page, None)
        else:
            page = node.page
            node.parent.children.pop(key, None)
            self._node_by_page.pop(page, None)
        pool.free([page])
        self.stats.evictions += 1
        return True

    def enforce_cap(self, pool: PagePool) -> None:
        """Shed sole-owned entries until the warm cache fits ``max_pages``
        (no-op when uncapped or when every over-cap entry is still pinned by
        a live sequence)."""
        if not self.max_pages:
            return
        while self.n_cached_pages > self.max_pages:
            if not self.evict_one(pool):
                break

    def enforce_tenant_cap(self, pool: PagePool, tenant: Optional[str],
                           cap: int) -> None:
        """Per-tenant prefix quota: shed ``tenant``'s sole-owned entries
        until its scope fits ``cap`` cached pages (0 = uncapped)."""
        if not cap:
            return
        while self.cached_pages_by_tenant().get(tenant, 0) > cap:
            if not self.evict_one(pool, tenant=tenant):
                break

    def evictable_pages(self, pool: PagePool) -> int:
        """Pages the index could EVENTUALLY return to the pool: cached pages
        whose only reference is the index's own (refcount 1). A live
        sequence pinning a chain page pins every ancestor too (it maps the
        whole chain), so a refcount-1 page's entire subtree is refcount-1
        and bottom-up ``evict_one`` calls can free all of them — this is
        the prefix cache's contribution to the scheduler's page headroom."""
        return (sum(1 for pg in self._node_by_page
                    if pool.refcount(pg) == 1)
                + sum(1 for pg in self._partial_by_page
                      if pool.refcount(pg) == 1))

    def try_release_for_write(self, page: int, pool: PagePool) -> bool:
        """A sequence is about to write into ``page`` and found refcount > 1.
        If the ONLY other owner is this index (refcount == 2) and the entry
        is a leaf, reclaim it — drop the cache entry instead of copying.
        Returns True when the caller may now write in place."""
        if pool.refcount(page) != 2:
            return False
        if page in self._partial_by_page:
            node, content = self._partial_by_page.pop(page)
            node.partials.pop(content, None)
        elif page in self._node_by_page:
            node = self._node_by_page[page]
            if node.children or node.partials:
                return False          # descendants still depend on the chain
            del self._node_by_page[page]
            node.parent.children.pop(node.key, None)
        else:
            return False
        pool.free([page])
        self.stats.steals += 1
        return True


class PrefixCapTuner:
    """Online controller for the prefix-cache page cap (``PrefixIndex
    .max_pages``), replacing a static ``prefix_cache_pages`` pick with a
    live-pressure policy. Every ``interval`` observed steps it closes a
    window and compares the window's eviction count (cache churn under
    pool pressure) against its hit count (sharing value):

      shrink  free pages < 25% of the pool AND evictions outpaced hits —
              the warm cache is squatting on pages the allocator keeps
              clawing back one eviction at a time; halve the cap (floor
              ``min_pages``) and enforce it immediately, so admission and
              decode growth see the headroom as ordinary free pages.
      grow    free pages > 50% AND hits kept up with evictions — sharing
              is earning its footprint and the pool has slack; double the
              cap (ceiling: the pool size).

    Between those bands the cap holds (hysteresis — the two thresholds
    keep a borderline pool from oscillating every window)."""

    def __init__(self, index: PrefixIndex, pool: PagePool,
                 interval: int, min_pages: int = 4):
        if interval < 1:
            raise ValueError(f"interval={interval} (need >= 1)")
        self.index = index
        self.pool = pool
        self.interval = interval
        self.min_pages = min_pages
        self._steps = 0
        self._last_ev = index.stats.evictions
        self._last_hits = index.stats.hits
        self.windows = 0
        self.shrinks = 0
        self.grows = 0

    def observe_step(self) -> None:
        self._steps += 1
        if self._steps < self.interval:
            return
        self._steps = 0
        self.windows += 1
        d_ev = self.index.stats.evictions - self._last_ev
        d_hit = self.index.stats.hits - self._last_hits
        self._last_ev = self.index.stats.evictions
        self._last_hits = self.index.stats.hits
        free_frac = self.pool.n_free / max(self.pool.n_pages, 1)
        cached = self.index.n_cached_pages
        # 0 == uncapped: the effective cap is whatever is cached right now.
        cap = self.index.max_pages or max(cached, self.min_pages)
        if free_frac < 0.25 and d_ev > d_hit:
            new = max(self.min_pages, min(cap, max(cached, 1)) // 2)
            if not self.index.max_pages or new < self.index.max_pages:
                self.index.max_pages = new
                self.index.enforce_cap(self.pool)
                self.shrinks += 1
        elif free_frac > 0.5 and d_hit >= d_ev:
            new = min(self.pool.n_pages, cap * 2)
            if self.index.max_pages and new > self.index.max_pages:
                self.index.max_pages = new
                self.grows += 1

    def stats(self) -> dict:
        return {"windows": self.windows, "shrinks": self.shrinks,
                "grows": self.grows}


@dataclass
class TransferStats:
    """Accounting for prefill->decode KV migrations (modeled remote DMA).

    ``payload_bytes`` is what actually moves over the fabric: copy-mode
    duplicates every page's KV, share-mode moves only the translated table
    entries (``table_bytes``) — the SVA payoff measured by
    ``benchmarks/disagg_serving.py``. The tlb/prefetch counters are deltas
    of the transfer IOMMU's TLBStats across each migration's translation
    loop, so the ``transfer:`` block isolates hand-off translation cost
    from the serving hot path's."""
    transfers: int = 0            # completed migrations
    pages_copied: int = 0         # copy-mode: fresh decode-side pages
    pages_shared: int = 0         # share-mode: zero-copy re-attachments
    payload_bytes: int = 0        # KV bytes moved (copy mode only)
    table_bytes: int = 0          # translated table entries handed off
    ptw_cycles: float = 0.0       # walk cost of the per-page translations
    tlb_hits: int = 0
    tlb_misses: int = 0
    prefetch_issued: int = 0
    prefetch_useful: int = 0
    prefetch_late: int = 0

    def as_dict(self):
        return dict(transfers=self.transfers,
                    pages_copied=self.pages_copied,
                    pages_shared=self.pages_shared,
                    payload_bytes=self.payload_bytes,
                    table_bytes=self.table_bytes,
                    ptw_cycles=round(self.ptw_cycles, 3),
                    tlb_hits=self.tlb_hits,
                    tlb_misses=self.tlb_misses,
                    prefetch_issued=self.prefetch_issued,
                    prefetch_useful=self.prefetch_useful,
                    prefetch_late=self.prefetch_late)


class PagedKVManager:
    """Page allocation + block tables for a fixed-B decode step."""

    def __init__(self, n_slots: int, max_pages_per_slot: int, page_size: int,
                 kv_bytes_per_token: int = 0, offload_mode: str = "zero_copy",
                 layout: Optional[str] = None, prefix_sharing: bool = True,
                 prefix_policy: str = "lru", prefix_cap_pages: int = 0,
                 tlb_entries: int = 4096, tlb_policy: str = "lru",
                 tlb_ways: int = 0, tlb_ranges: int = 0,
                 tlb_prefetch: Optional[PrefetchConfig] = None,
                 autotune: Optional[AutoTuneConfig] = None,
                 prefix_autotune: int = 0,
                 pool_pages: Optional[int] = None,
                 sanitize: Optional[bool] = None,
                 tenants: Optional[Dict[str, dict]] = None):
        assert offload_mode in ("zero_copy", "copy")
        if layout is None:
            layout = "global" if offload_mode == "zero_copy" else "per_slot"
        assert layout in ("global", "per_slot")
        # Multi-tenant domains: name -> {quota_pages, quota_prefix_pages,
        # tlb_ways} (every knob optional, 0 = unlimited/shared). Quotas
        # need the one shared pool; nonzero tlb_ways way-partition the
        # serving IOTLB per tenant.
        self.tenant_specs: Dict[str, dict] = \
            {str(t): dict(spec or {}) for t, spec in tenants.items()} \
            if tenants else {}
        tlb_partitions: Dict[str, int] = {}
        if self.tenant_specs:
            if layout != "global":
                raise ValueError("tenants require the global layout "
                                 "(quotas meter the one shared pool)")
            allowed = {"quota_pages", "quota_prefix_pages", "tlb_ways"}
            for t, spec in self.tenant_specs.items():
                unknown = set(spec) - allowed
                if unknown:
                    raise ValueError(
                        f"tenant {t!r}: unknown keys {sorted(unknown)} "
                        f"(expected {sorted(allowed)})")
                for k, v in spec.items():
                    if not isinstance(v, int) or v < 0:
                        raise ValueError(
                            f"tenant {t!r}: {k}={v!r} (need an int >= 0)")
                if spec.get("tlb_ways"):
                    tlb_partitions[t] = spec["tlb_ways"]
            if tlb_partitions and autotune is not None:
                raise ValueError(
                    "TLB way partitions and the geometry auto-tuner are "
                    "mutually exclusive (a retune would drop the "
                    "partitions)")
            if tlb_partitions and not tlb_ways:
                raise ValueError(
                    "per-tenant tlb_ways need a set-associative TLB "
                    "(set tlb_ways on the manager)")
        self.n_slots = n_slots
        self.max_pages = max_pages_per_slot
        self.page_size = page_size
        self.kv_bytes_per_token = kv_bytes_per_token
        self.offload_mode = offload_mode
        self.layout = layout
        self.total_pages = n_slots * max_pages_per_slot
        self.null_page = self.total_pages            # device drop/zero sentinel
        # ``pool_pages`` constrains the PHYSICAL pool below the worst case
        # (n_slots full slots) — the oversubscription regime continuous
        # batching is built for: lazy admissions pack more live sequences
        # than full reservations would, and the scheduler preempts when
        # growth outruns the pool. Device arrays keep worst-case sizing
        # (the null page id is unchanged); the allocator just never hands
        # out pages >= pool_pages.
        if pool_pages is None:
            pool_pages = self.total_pages
        if layout == "global":
            if not max_pages_per_slot <= pool_pages <= self.total_pages:
                raise ValueError(
                    f"pool_pages={pool_pages} (need max_pages_per_slot="
                    f"{max_pages_per_slot} <= pool_pages <= "
                    f"{self.total_pages})")
        elif pool_pages != self.total_pages:
            raise ValueError("pool_pages requires the global layout")
        self.pool_pages = pool_pages
        if layout == "global":
            self.pool = PagePool(pool_pages, page_size)
            self.pools = None
            self.tables = np.full((n_slots, max_pages_per_slot),
                                  self.null_page, np.int32)
        else:
            # One pool per slot (the compiled step's pool rows are per-slot).
            self.pools = [PagePool(max_pages_per_slot, page_size)
                          for _ in range(n_slots)]
            self.pool = None
            self.tables = np.zeros((n_slots, max_pages_per_slot), np.int32)
        # Prefix sharing needs one physical page space addressable from every
        # slot's table row — only the global layout has that.
        self.prefix = (PrefixIndex(page_size, policy=prefix_policy,
                                   max_pages=prefix_cap_pages)
                       if layout == "global" and prefix_sharing else None)
        self.pending_cow: List[Tuple[int, int]] = []   # (src, dst) page copies
        self.sva_stats = SVAStats()      # host-side mode counters
        # Unified translation front-end: one ASID per batch slot, a large
        # delta-upload cache over a pure-stats walker — the same IOMMU class
        # the simulator configures as a 4-entry hardware IOTLB + Sv39 walk.
        self.iommu = IOMMU(walk_model=CountingWalk(),
                           tlb=TLBConfig(tlb_entries, tlb_policy,
                                         ways=tlb_ways, ranges=tlb_ranges,
                                         partitions=tlb_partitions),
                           prefetch=tlb_prefetch or PrefetchConfig())
        # One TenantDomain per configured tenant: admission attaches each
        # slot under its owner, so every translate is isolation-checked.
        self.tenant_domains = {t: self.iommu.register_tenant(t)
                               for t in sorted(self.tenant_specs)}
        # Online geometry auto-tuner (default off): translate_step advances
        # it one window per decode step; a geometry switch is a flush +
        # epoch bump, which the engine observes as a full table upload.
        self.autotuner = (TLBAutoTuner(self.iommu, autotune)
                          if autotune is not None else None)
        # Prefix-cache cap autotuner (default off): the engine advances it
        # once per decode step via ``observe_step``; it shrinks/grows
        # ``PrefixIndex.max_pages`` from live pool pressure.
        if prefix_autotune < 0:
            raise ValueError(
                f"prefix_autotune={prefix_autotune} (need >= 0; 0 = off)")
        self.prefix_tuner = (PrefixCapTuner(self.prefix, self.pool,
                                            prefix_autotune)
                             if prefix_autotune and self.prefix is not None
                             else None)
        # svasan (core/sva/sanitizer.py): opt-in shadow-state checking over
        # the pool(s) + the IOMMU. ``sanitize=None`` defers to REPRO_SVASAN.
        self.sanitizer = (SVASanitizer() if _resolve_sanitize(sanitize)
                          else None)
        if self.sanitizer is not None:
            for p in ([self.pool] if self.pool is not None else self.pools):
                self.sanitizer.attach_pool(p)
            self.iommu.sanitizer = self.sanitizer
        self.free_slots = list(range(n_slots - 1, -1, -1))
        self.seqs: Dict[int, SeqState] = {}
        self.lengths = np.zeros((n_slots,), np.int32)
        self.dirty_rows = set(range(n_slots))
        self.preemptions = 0
        self.resumes = 0
        self.transfer_stats = TransferStats()

    @property
    def tlb(self):
        """The IOMMU's shared translation cache (stats / test hook)."""
        return self.iommu.tlb

    @property
    def epoch(self) -> int:
        """Full-flush count — owned by the IOMMU (paper Listing 1)."""
        return self.iommu.epoch

    # ------------------------------------------------------------- tenants
    @property
    def has_tenants(self) -> bool:
        return bool(self.tenant_specs)

    def _check_tenant_name(self, tenant: Optional[str]) -> None:
        if tenant is not None and tenant not in self.tenant_specs:
            raise ValueError(f"tenant {tenant!r} is not configured "
                             f"(known: {sorted(self.tenant_specs)})")

    def tenant_pages_used(self, tenant: Optional[str]) -> int:
        """Pool pages currently mapped by the tenant's live sequences
        (shared prefix pages count once per sequence holding them — the
        quota meters mappings, like the pool refcounts do)."""
        return sum(len(st.pages) for st in self.seqs.values()
                   if st.tenant == tenant)

    def tenant_quota(self, tenant: Optional[str]) -> int:
        """The tenant's page quota (0 = unlimited)."""
        if tenant is None or tenant not in self.tenant_specs:
            return 0
        return self.tenant_specs[tenant].get("quota_pages", 0)

    def tenant_headroom(self, tenant: Optional[str]) -> int:
        """Pages the tenant may still map under its quota
        (``pool_pages`` stands in for 'unlimited')."""
        quota = self.tenant_quota(tenant)
        if not quota:
            return self.pool_pages
        return max(0, quota - self.tenant_pages_used(tenant))

    def tenants_over_quota(self) -> List[str]:
        """Tenants whose live mappings exceed their page quota right now
        (decode growth runs ahead of admission-time checks) — the
        scheduler's quota-pressure preemption signal."""
        return [t for t in sorted(self.tenant_specs)
                if self.tenant_quota(t)
                and self.tenant_pages_used(t) > self.tenant_quota(t)]

    def _enforce_tenant_prefix_caps(self) -> None:
        if self.prefix is None:
            return
        for t, spec in self.tenant_specs.items():
            cap = spec.get("quota_prefix_pages", 0)
            if cap:
                self.prefix.enforce_tenant_cap(self.pool, t, cap)

    # ------------------------------------------------------------ admission
    def ensure_fits(self, prompt_len: int, max_tokens: int,
                    tenant: Optional[str] = None) -> int:
        """Single source of truth for the slot-capacity check (used by both
        ``admit`` and the engine's ``submit``). Returns the page count
        needed; raises :class:`CapacityError` when the request can never
        fit — silently truncating the reservation would later wrap page
        indices and corrupt other sequences' KV. With ``tenant`` the check
        extends to the tenant's page quota: a request needing more pages
        than the quota allows can never run, even with the tenant idle."""
        need = -(-(prompt_len + max_tokens) // self.page_size)
        if need > self.max_pages:
            raise CapacityError(
                f"prompt_len={prompt_len} + max_tokens={max_tokens} needs "
                f"{need} pages but a slot holds {self.max_pages} "
                f"({self.max_pages * self.page_size} tokens)")
        if self.layout == "global" and need > self.pool_pages:
            raise CapacityError(
                f"prompt_len={prompt_len} + max_tokens={max_tokens} needs "
                f"{need} pages but the physical pool holds "
                f"{self.pool_pages}")
        quota = self.tenant_quota(tenant)
        if quota and need > quota:
            raise CapacityError(
                f"prompt_len={prompt_len} + max_tokens={max_tokens} needs "
                f"{need} pages but tenant {tenant!r}'s quota is {quota}")
        return need

    def _alloc_evicting(self, n: int, run: bool = False) -> List[int]:
        """Global-pool alloc that evicts warm prefix-cache entries (per the
        index's lru/lfu policy) under ``OutOfPages`` pressure before giving
        up. ``run=True`` (admission's contiguity hint) asks for a
        physically contiguous run first — the substrate range-coalesced
        IOTLB entries form over — falling back to discontiguous pages
        when fragmentation leaves no run."""
        while True:
            try:
                return self.pool.alloc_run(n) if run else self.pool.alloc(n)
            except OutOfPages:
                if self.prefix is None or not self.prefix.evict_one(self.pool):
                    raise

    def admit(self, seq_id: int, prompt_len: int, max_tokens: int,
              tokens: Optional[Sequence[int]] = None,
              lazy: bool = False,
              tenant: Optional[str] = None) -> Optional[SeqState]:
        """Allocate a slot + pages for a prompt.

        ``tokens`` (the actual prompt ids) enables prefix sharing: full
        pages whose content is already resident are mapped via refcount++
        instead of fresh allocation, and ``SeqState.prefill_start`` tells
        the engine how many leading tokens need NO prefill compute (their KV
        is already in the shared pages). At least the last prompt token is
        always left to compute so admission can produce first-token logits;
        its KV write is dropped by the engine when it lands in a shared page
        (the page already holds exactly that KV).

        ``lazy`` (continuous batching) reserves only the PROMPT's pages —
        decode growth allocates page-by-page in ``append_token``, and the
        scheduler preempts under pool pressure instead of admission
        pre-paying ``max_tokens`` worth of pages. Lazy admission also skips
        ``PrefixIndex.register``: under chunked prefill the prompt's KV
        materializes over several steps, and registering uncomputed pages
        would let another admission share garbage. The engine registers
        progressively via :meth:`register_progress` as chunks complete.

        ``tenant`` admits under a configured tenant domain: the slot's ASID
        is owned by (and isolation-checked against) that tenant, prefix
        matching is scoped to the tenant's own cached KV, and the tenant's
        page quota gates the admission (over quota -> None, wait).

        Returns None when no slot/pages are free right now (continuous
        batching waits); raises :class:`CapacityError` for requests that can
        never fit (see ``ensure_fits``).
        """
        self._check_tenant_name(tenant)
        need = self.ensure_fits(prompt_len, max_tokens, tenant=tenant)
        if lazy:
            if self.layout != "global":
                raise ValueError("lazy admission requires the global layout")
            need = max(-(-prompt_len // self.page_size), 1)
        if not self.free_slots:
            return None
        quota = self.tenant_quota(tenant)
        if quota and self.tenant_pages_used(tenant) + need > quota:
            return None                      # over quota: wait (transient)
        slot = self.free_slots[-1]
        shared: List[int] = []
        prefill_start = 0
        sharing = (self.prefix is not None and tokens is not None
                   and prompt_len > 0)
        if sharing:
            tokens = list(tokens)[:prompt_len]
            shared, matched = self.prefix.match(tokens, tenant=tenant)
            # Always recompute >= 1 token for logits; when the whole prompt
            # is resident the recomputed token's page is shared and the
            # engine drops its (identical) KV write.
            prefill_start = min(matched, prompt_len - 1)
            if shared:
                self.pool.share(shared)     # hold before eviction can run
        if self.layout == "global":
            try:
                # contiguity hint: a sequence's fresh pages try to land as
                # one physical run so they warm/coalesce into a range entry
                fresh = self._alloc_evicting(need - len(shared), run=True)
            except OutOfPages:
                if shared:
                    self.pool.free(shared)
                return None
        else:
            try:
                fresh = self.pools[slot].alloc(need)
            except OutOfPages:
                return None
        pages = shared + fresh
        self.free_slots.pop()
        st = SeqState(seq_id, slot, prompt_len, pages, max_tokens,
                      shared_pages=len(shared), prefill_start=prefill_start,
                      tenant=tenant)
        self.seqs[seq_id] = st
        if sharing:
            if not lazy:
                self.prefix.register(tokens, pages, self.pool,
                                     tenant=tenant)
            if shared:
                self.prefix.stats.hits += 1
                self.prefix.stats.pages_shared += len(shared)
                self.prefix.stats.tokens_saved += prefill_start
            else:
                self.prefix.stats.misses += 1
            self.prefix.enforce_cap(self.pool)
            self._enforce_tenant_prefix_caps()
        if self.layout == "global":
            row = np.full((self.max_pages,), self.null_page, np.int32)
            row[:need] = pages
        else:
            # Row is kept a PERMUTATION of [0, max_pages): allocated pages
            # first, remaining physical pages as filler — the per-slot
            # prefill scatter inverts it.
            used = set(pages)
            filler = [p for p in range(self.max_pages) if p not in used]
            row = np.asarray(pages + filler, np.int32)
        self.tables[slot] = row
        self.lengths[slot] = prompt_len
        self.dirty_rows.add(slot)
        if self.offload_mode == "copy":
            # Staging baseline: dedicated counters (never map_* — see
            # core/sva/mapping.py stage()).
            self.sva_stats.stage_calls += 1
            self.sva_stats.bytes_copied += \
                prompt_len * self.kv_bytes_per_token
        else:
            # Shared pages still cost a table-entry write (the mapping) —
            # what sharing saves is the allocation and the prefill compute.
            self.sva_stats.map_calls += 1
            self.sva_stats.table_entries_written += len(pages)
            self.sva_stats.bytes_mapped += \
                prompt_len * self.kv_bytes_per_token
        # PASID-style per-request address space: ASID == batch slot. map()
        # installs the logical->physical table and warms the shared TLB.
        # Tenant ownership is established here — every later translate of
        # this slot is isolation-checked against it.
        self.iommu.attach(slot, tenant=tenant).map(pages)
        return st

    def append_token(self, seq_id: int, token: int) -> None:
        st = self.seqs[seq_id]
        st.tokens.append(token)
        st.length += 1
        self.lengths[st.slot] = st.length
        needed = -(-st.length // self.page_size)
        if needed > len(st.pages):
            # Admission reserves prompt+max_tokens upfront, so this only
            # fires for callers that under-reserved; grow or fail loudly.
            if len(st.pages) >= self.max_pages:
                raise CapacityError(
                    f"seq {seq_id} grew past its slot capacity "
                    f"({self.max_pages} pages)")
            if self.layout == "global":
                new = self._alloc_evicting(1)
            else:
                new = self.pools[st.slot].alloc(1)
            lp = len(st.pages)
            st.pages.extend(new)
            if self.layout == "global":
                self.tables[st.slot, lp] = new[0]
            else:
                # swap to keep the row a permutation
                row = self.tables[st.slot]
                j = int(np.where(row == new[0])[0][0])
                row[lp], row[j] = row[j], row[lp]
            self.dirty_rows.add(st.slot)
            self.sva_stats.table_entries_written += 1
            self.iommu.space(st.slot).map(new, start=lp)
        if len(st.tokens) >= st.max_tokens:
            st.done = True
        if self.layout == "global" and not st.done:
            # A completing sequence's final token is never written to the
            # device cache (the engine releases it before the next decode
            # step), so duplicating/stealing its target page would only
            # waste a copy or destroy a still-useful cache entry.
            self._cow_before_write(st)
            if self.sanitizer is not None:
                # post-CoW: the page about to be written must be ours alone
                self.sanitizer.check_write(
                    self.pool, st.pages[(st.length - 1) // self.page_size])

    def _cow_before_write(self, st: SeqState) -> None:
        """The token just appended will be WRITTEN (by the next decode step)
        at position ``st.length - 1``. If that write lands in a page another
        mapping still references, duplicate first — or steal the page back
        from the prefix index when the index is its only other owner."""
        li = (st.length - 1) // self.page_size
        pg = st.pages[li]
        if not self.pool.is_shared(pg):
            return
        if self.prefix is not None and \
                self.prefix.try_release_for_write(pg, self.pool):
            return                           # reclaimed: write in place
        dst = self._alloc_evicting(1)[0]
        self.pending_cow.append((pg, dst))   # device copies src -> dst
        st.pages[li] = dst
        self.tables[st.slot, li] = dst
        self.pool.free([pg])                 # drop OUR ref; sharers keep it
        self.pool.stats.cow_copies += 1
        self.dirty_rows.add(st.slot)
        self.sva_stats.table_entries_written += 1
        self.iommu.space(st.slot).remap(li, dst)

    def drain_cow_copies(self) -> List[Tuple[int, int]]:
        """(src, dst) physical page copies the device must perform before
        the next decode step reads/writes the duplicated pages."""
        out = self.pending_cow
        self.pending_cow = []
        return out

    def release(self, seq_id: int) -> None:
        """Drop the sequence's OWN page references. Pages also registered in
        the prefix index keep the index's reference and live on as the warm
        prefix cache (evicted LRU under page pressure)."""
        st = self.seqs.pop(seq_id)
        free_pool = (self.pool if self.layout == "global"
                     else self.pools[st.slot])
        snap = (self.sanitizer.snapshot_rc(free_pool, st.pages)
                if self.sanitizer is not None else None)
        free_pool.free(st.pages)
        self.free_slots.append(st.slot)
        self.lengths[st.slot] = 0
        if self.layout == "global":
            self.tables[st.slot] = self.null_page
        self.sva_stats.unmap_calls += 1
        # self-invalidation: ONLY this slot's translations die (the Listing-1
        # full flush is invalidate_epoch)
        self.iommu.detach(st.slot)
        self.dirty_rows.add(st.slot)
        if self.sanitizer is not None:
            # every reference the sequence held must actually be gone
            self.sanitizer.check_release(free_pool, seq_id, st.pages, snap)

    # ------------------------------------------------- preemption (continuous)
    def register_progress(self, seq_id: int, tokens: Sequence[int],
                          computed: int) -> None:
        """Register a lazily-admitted prompt's COMPUTED pages in the prefix
        index (the chunked-prefill counterpart of the registration eager
        ``admit`` does up front). Called by the engine after each chunk's
        KV lands, so the index only ever references resident KV. Idempotent
        per page — each chunk re-walks the already-registered prefix and
        adds only its own new pages (plus the partial tail on the final
        chunk, exactly like eager registration)."""
        if self.prefix is None:
            return
        st = self.seqs[seq_id]
        toks = [int(t) for t in tokens[:computed]]
        n = -(-computed // self.page_size)
        self.prefix.register(toks, st.pages[:n], self.pool,
                             tenant=st.tenant)
        self.prefix.enforce_cap(self.pool)
        self._enforce_tenant_prefix_caps()

    def preempt(self, seq_id: int, resident_tokens:
                Optional[Sequence[int]] = None) -> None:
        """Evict a live sequence under pool pressure: release its slot,
        pages, and ASID exactly like :meth:`release` — but FIRST register
        its computed KV (``resident_tokens``: every token whose KV is
        actually written — the scheduler passes prompt+generated minus the
        one pending token, or the computed chunk prefix mid-prefill) in the
        prefix index. A prompt-sharing resume then re-matches those warm
        pages and skips their recompute entirely; under continued pressure
        they are ordinary evictable cache entries. The sanitizer sees the
        same snapshot/release discipline as a completion."""
        if self.layout != "global":
            raise ValueError("preemption requires the global layout")
        st = self.seqs.pop(seq_id)
        if self.prefix is not None and resident_tokens:
            toks = [int(t) for t in resident_tokens]
            n = -(-len(toks) // self.page_size)
            self.prefix.register(toks, st.pages[:n], self.pool,
                                 tenant=st.tenant)
        snap = (self.sanitizer.snapshot_rc(self.pool, st.pages)
                if self.sanitizer is not None else None)
        self.pool.free(st.pages)
        self.free_slots.append(st.slot)
        self.lengths[st.slot] = 0
        self.tables[st.slot] = self.null_page
        self.sva_stats.unmap_calls += 1
        self.preemptions += 1
        self.iommu.detach(st.slot)
        self.dirty_rows.add(st.slot)
        if self.sanitizer is not None:
            self.sanitizer.check_release(self.pool, seq_id, st.pages, snap)

    def resume(self, seq_id: int, prompt_len: int, max_tokens: int,
               tokens: Optional[Sequence[int]] = None,
               tenant: Optional[str] = None) -> Optional[SeqState]:
        """Re-admit a preempted sequence. The caller passes every
        KV-resident token it had as the new prompt (with ``max_tokens``
        rebased to the remaining budget); with ``tokens`` the prefix index
        re-matches the pages :meth:`preempt` registered — a warm resume
        costs one recomputed token — and without a match the KV is
        recomputed from tokens. Either way this is a fresh lazy admission:
        new slot, new ASID, new pages (owned by the same tenant)."""
        st = self.admit(seq_id, prompt_len, max_tokens, tokens=tokens,
                        lazy=True, tenant=tenant)
        if st is not None:
            self.resumes += 1
        return st

    # -------------------------------------------- disaggregated migration
    def reserve_slots(self, slots: Sequence[int]) -> None:
        """Withhold slots from ``admit``/``resume`` so a disaggregated
        front-end can dedicate them to a decode worker: migration targets
        them explicitly via :meth:`migrate`, admission never sees them."""
        for s in slots:
            if any(st.slot == s for st in self.seqs.values()):
                raise ValueError(f"slot {s} is occupied; cannot reserve")
            if s in self.free_slots:
                self.free_slots.remove(s)

    def migrate(self, seq_id: int, dst_slot: int, mode: str = "share",
                xfer_iommu: Optional[IOMMU] = None) -> SeqState:
        """Move a sequence's KV pages from its current ASID to ``dst_slot``
        over the shared pool — the single-process model of a prefill worker
        handing a finished prompt's KV to a decode worker by remote DMA.

        The hand-off is priced through the SVA layer: the SOURCE ASID
        translates every resident page (through ``xfer_iommu`` — the
        transfer fabric's IOMMU, e.g. a 4-entry IOTLB over ``Sv39Walk`` —
        or the manager's own when none is given), accumulating PTW/IOTLB
        cost in :class:`TransferStats`. Then either

        * ``mode="share"``: zero-copy re-attachment — ``PagePool.share``
          bumps every page's refcount before the source reference drops, so
          the physical pages never transit free and the decode side maps
          the SAME pages (only table entries move: ``table_bytes``); or
        * ``mode="copy"``: fresh pages are allocated for the decode side
          and queued on ``pending_cow`` for the engine's device-side
          batched copy (``payload_bytes`` = full KV payload). The copy is
          priced but the source pages are freed immediately — the engine
          MUST drain ``pending_cow`` before anything reallocates them.

        Source teardown and destination attach follow the exact
        release/admit discipline (snapshot + ``check_release``, per-ASID
        invalidation, delta-row dirtying), so migration is svasan-clean by
        construction. Raises ``OutOfPages`` (copy mode, nothing mutated)
        when the pool cannot back the duplicate — callers defer the
        transfer and retry."""
        if self.layout != "global":
            raise ValueError("migration requires the global layout")
        if mode not in ("share", "copy"):
            raise ValueError(f"mode={mode!r} (expected 'share' or 'copy')")
        st = self.seqs[seq_id]
        src_slot = st.slot
        if dst_slot == src_slot:
            raise ValueError(f"seq {seq_id} already occupies slot {dst_slot}")
        if any(s.slot == dst_slot for s in self.seqs.values()):
            raise ValueError(f"destination slot {dst_slot} is occupied")
        n = len(st.pages)
        # Copy mode allocates FIRST so OutOfPages leaves nothing mutated.
        if mode == "copy":
            new_pages = self._alloc_evicting(n, run=True)
        ts = self.transfer_stats
        # --- price the hand-off: source ASID translates every page through
        # the transfer fabric's IOMMU (remote DMA by virtual address).
        iommu = xfer_iommu if xfer_iommu is not None else self.iommu
        external = xfer_iommu is not None and xfer_iommu is not self.iommu
        if external:
            sp = iommu.space(src_slot)
            if sp is None:
                sp = iommu.attach(src_slot)
            # cold install: the fabric walks page tables it has never seen
            for lp, pp in enumerate(st.pages):
                sp.table[lp] = pp
        before = iommu.stats()["tlb"]
        for lp in range(n):
            # the hand-off DMA runs under the sequence's tenant identity
            _, cost, _ = iommu.translate(src_slot, lp, tenant=st.tenant)
            ts.ptw_cycles += cost
        after = iommu.stats()["tlb"]
        for k, attr in (("hits", "tlb_hits"), ("misses", "tlb_misses"),
                        ("prefetch_issued", "prefetch_issued"),
                        ("prefetch_useful", "prefetch_useful"),
                        ("prefetch_late", "prefetch_late")):
            setattr(ts, attr, getattr(ts, attr) + after[k] - before[k])
        if external:
            iommu.detach(src_slot)           # the fabric window closes
        ts.transfers += 1
        ts.table_bytes += n * 4              # int32 table entries handed off
        # --- hand off the physical pages.
        if mode == "share":
            # refcount++ BEFORE the source drop: pages never transit free
            self.pool.share(st.pages)
            new_pages = list(st.pages)
            ts.pages_shared += n
        else:
            self.pending_cow.extend(zip(st.pages, new_pages))
            ts.pages_copied += n
            ts.payload_bytes += n * self.page_size * self.kv_bytes_per_token
        # --- source teardown: exactly the release discipline.
        snap = (self.sanitizer.snapshot_rc(self.pool, st.pages)
                if self.sanitizer is not None else None)
        src_pages = list(st.pages)
        self.pool.free(src_pages)
        self.free_slots.append(src_slot)
        self.lengths[src_slot] = 0
        self.tables[src_slot] = self.null_page
        self.sva_stats.unmap_calls += 1
        self.iommu.detach(src_slot)
        self.dirty_rows.add(src_slot)
        if self.sanitizer is not None:
            self.sanitizer.check_release(self.pool, seq_id, src_pages, snap)
        # --- destination attach: exactly the admit discipline, targeting
        # the (possibly reserved) decode-side slot explicitly.
        if dst_slot in self.free_slots:
            self.free_slots.remove(dst_slot)
        st.slot = dst_slot
        st.pages = new_pages
        row = np.full((self.max_pages,), self.null_page, np.int32)
        row[:n] = new_pages
        self.tables[dst_slot] = row
        self.lengths[dst_slot] = st.length
        self.dirty_rows.add(dst_slot)
        self.sva_stats.map_calls += 1
        self.sva_stats.table_entries_written += n
        self.sva_stats.bytes_mapped += st.length * self.kv_bytes_per_token
        # the decode-side ASID keeps the sequence's tenant ownership
        self.iommu.attach(dst_slot, tenant=st.tenant).map(new_pages)
        return st

    def free_page_headroom(self) -> int:
        """Pages an allocation could obtain RIGHT NOW: free pages plus warm
        prefix-cache pages the index solely owns (``_alloc_evicting``
        reclaims those one eviction at a time). The scheduler compares this
        against :meth:`next_step_page_demand` to decide preemption and
        admission."""
        free = self.pool.n_free
        if self.prefix is not None:
            free += self.prefix.evictable_pages(self.pool)
        return free

    def next_step_page_demand(self) -> int:
        """Upper bound on pages the NEXT step's appends can allocate: one
        per live sequence whose next token write either crosses into an
        unallocated page (lazy-admission growth) or lands in a shared page
        (CoW duplication — counted even when a steal would avoid the
        allocation, so the bound stays conservative)."""
        demand = 0
        for st in self.seqs.values():
            if st.done:
                continue
            li = st.length // self.page_size
            if li >= len(st.pages):
                if len(st.pages) < self.max_pages:
                    demand += 1
            elif self.pool.is_shared(st.pages[li]):
                demand += 1
        return demand

    def observe_step(self) -> None:
        """Advance per-step online controllers (currently the prefix-cache
        cap tuner). The engine calls this once per decode step."""
        if self.prefix_tuner is not None:
            self.prefix_tuner.observe_step()

    # ------------------------------------------------------------ device view
    def delta_rows(self) -> List[int]:
        """Slot rows whose tables changed since last upload (delta upload —
        the serving-level analogue of a warm IOTLB)."""
        rows = sorted(self.dirty_rows)
        self.dirty_rows.clear()
        return rows

    def invalidate_epoch(self) -> None:
        """Full translation flush (paper Listing 1): the next device upload
        must re-send every table row."""
        self.iommu.invalidate()              # bumps the epoch exactly once
        self.dirty_rows.update(range(self.n_slots))

    def translate_step(self, resident: Optional[Dict[int, int]] = None
                       ) -> List[Tuple[int, int, int]]:
        """Run one decode step's page accesses through the IOMMU (ASID ==
        slot): every live sequence gathers its resident KV pages. Returns
        the (slot, logical_page, physical_page) access list — the serving
        hot path's translation trace, countable live (``CountingWalk``) or
        replayable through ``Sv39Walk`` for modeled PTW cost.

        ``resident`` (continuous batching) overrides the per-sequence
        resident-token count by seq_id: a mid-prefill sequence has KV for
        its computed chunks only, not ``SeqState.length`` (= the full
        prompt), so the step must not translate — or charge PTW cost for —
        pages no access touches yet."""
        out: List[Tuple[int, int, int]] = []
        for st in self.seqs.values():
            if st.done:
                continue
            toks = (st.length if resident is None
                    else resident.get(st.seq_id, st.length))
            n = min(-(-toks // self.page_size), len(st.pages))
            for lp in range(n):
                # the gather runs under the sequence's tenant identity, so
                # the live hot path exercises the isolation gate every step
                phys, _, _ = self.iommu.translate(st.slot, lp,
                                                  tenant=st.tenant)
                out.append((st.slot, lp, phys))
        if self.autotuner is not None:
            self.autotuner.observe_step()
        return out

    def device_tables(self) -> np.ndarray:
        return self.tables.copy()

    def device_lengths(self) -> np.ndarray:
        return self.lengths.copy()

    def active_seqs(self) -> List[SeqState]:
        return [s for s in self.seqs.values() if not s.done]

    def stats(self) -> dict:
        pools = [self.pool] if self.layout == "global" else self.pools
        used = sum(p.n_used for p in pools)
        free = sum(p.n_free for p in pools)
        high = sum(p.stats.high_water for p in pools)
        util = (sum(p.utilization * p.n_pages for p in pools)
                / max(sum(p.n_pages for p in pools), 1))
        io = self.iommu.stats()
        iommu_block = {"walk": io["walk"], "epoch": io["epoch"],
                       "asids": io["asids"],
                       "tlb_entries": self.iommu.tlb_config.n_entries,
                       "tlb_ways": self.iommu.tlb_config.resolved_ways,
                       "tlb_policy": self.iommu.tlb_config.policy}
        if self.autotuner is not None:
            iommu_block["autotune"] = self.autotuner.stats()
        if "range" in io:
            iommu_block["range"] = io["range"]
        out = {"sva": self.sva_stats.as_dict(),
               "tlb": io["tlb"],
               "iommu": iommu_block,
               "pool_used": used,
               "pool_free": free,
               "pool_high_water": high,
               "pool_utilization": round(util, 4),
               "pool_shares": sum(p.stats.shares for p in pools),
               "pool_run_allocs": sum(p.stats.run_allocs for p in pools),
               "pool_run_fallbacks": sum(p.stats.run_fallbacks
                                         for p in pools),
               "cow_copies": sum(p.stats.cow_copies for p in pools),
               "preemptions": self.preemptions,
               "resumes": self.resumes}
        if self.prefix is not None:
            out["prefix"] = {**self.prefix.stats.as_dict(),
                             "cached_pages": self.prefix.n_cached_pages,
                             "policy": self.prefix.policy,
                             "max_pages": self.prefix.max_pages}
            if self.prefix_tuner is not None:
                out["prefix"]["tuner"] = self.prefix_tuner.stats()
        if self.tenant_specs:
            io_tenant = io.get("tenant", {})
            prefix_by_tenant = (self.prefix.cached_pages_by_tenant()
                                if self.prefix is not None else {})
            tenant = {}
            for name, spec in sorted(self.tenant_specs.items()):
                blk = dict(
                    seqs=sum(1 for st in self.seqs.values()
                             if st.tenant == name),
                    pages_used=self.tenant_pages_used(name),
                    quota_pages=spec.get("quota_pages", 0),
                    prefix_pages=prefix_by_tenant.get(name, 0),
                    quota_prefix_pages=spec.get("quota_prefix_pages", 0))
                blk.update(io_tenant.get(name, {}))
                tenant[name] = blk
            out["tenant"] = tenant
        if self.transfer_stats.transfers:
            out["transfer"] = self.transfer_stats.as_dict()
        if self.sanitizer is not None:
            out["svasan"] = self.sanitizer.stats()
        return out
