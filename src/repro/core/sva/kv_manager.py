"""Paged KV-cache manager: binds the SVA layer to the compiled model's
cache view.

Two layouts:

  global   (zero-copy serving) ONE PagePool shared by every slot. The
           compiled step sees a single physical page pool per KV layer
           (``n_slots * max_pages`` pages) and per-slot int32 block tables
           indexing into it. Unallocated table entries hold the NULL page id
           (== total page count): device writes through them are dropped and
           gathers read as zero. Admission writes table rows only — KV data
           is produced in place by the batched prefill scatter.

  per_slot (copy baseline) one PagePool per slot; each table row is a
           permutation of [0, max_pages) over that slot's private pool. This
           is the layout the staging-copy admission path (the paper's
           baseline) uses.

Delta-upload bookkeeping: rows whose tables changed since the last device
upload accumulate in ``dirty_rows`` and are drained with ``delta_rows()`` —
the serving-level analogue of a warm IOTLB. ``invalidate_epoch()`` models
the paper's Listing-1 flush: every translation dies and the next upload must
be a full-table upload.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.sva.mapping import SVASpace
from repro.core.sva.page_pool import OutOfPages, PagePool
from repro.core.sva.tlb import TranslationCache


class CapacityError(ValueError):
    """Request can NEVER be admitted (prompt+max_tokens exceeds slot
    capacity) — distinct from a transient OutOfPages/no-slot condition."""


@dataclass
class SeqState:
    seq_id: int
    slot: int
    length: int                   # tokens in cache
    pages: List[int]              # physical page ids
    max_tokens: int
    tokens: List[int] = field(default_factory=list)   # generated so far
    done: bool = False


class PagedKVManager:
    """Page allocation + block tables for a fixed-B decode step."""

    def __init__(self, n_slots: int, max_pages_per_slot: int, page_size: int,
                 kv_bytes_per_token: int = 0, offload_mode: str = "zero_copy",
                 layout: Optional[str] = None):
        assert offload_mode in ("zero_copy", "copy")
        if layout is None:
            layout = "global" if offload_mode == "zero_copy" else "per_slot"
        assert layout in ("global", "per_slot")
        self.n_slots = n_slots
        self.max_pages = max_pages_per_slot
        self.page_size = page_size
        self.kv_bytes_per_token = kv_bytes_per_token
        self.offload_mode = offload_mode
        self.layout = layout
        self.total_pages = n_slots * max_pages_per_slot
        self.null_page = self.total_pages            # device drop/zero sentinel
        if layout == "global":
            self.pool = PagePool(self.total_pages, page_size)
            self.pools = None
            self.tables = np.full((n_slots, max_pages_per_slot),
                                  self.null_page, np.int32)
        else:
            # One pool per slot (the compiled step's pool rows are per-slot).
            self.pools = [PagePool(max_pages_per_slot, page_size)
                          for _ in range(n_slots)]
            self.pool = None
            self.tables = np.zeros((n_slots, max_pages_per_slot), np.int32)
        self.space = SVASpace(PagePool(1, page_size))   # stats aggregator
        self.tlb = TranslationCache(n_entries=4096)
        self.free_slots = list(range(n_slots - 1, -1, -1))
        self.seqs: Dict[int, SeqState] = {}
        self.lengths = np.zeros((n_slots,), np.int32)
        self.dirty_rows = set(range(n_slots))
        self.epoch = 0

    # ------------------------------------------------------------ admission
    def ensure_fits(self, prompt_len: int, max_tokens: int) -> int:
        """Single source of truth for the slot-capacity check (used by both
        ``admit`` and the engine's ``submit``). Returns the page count
        needed; raises :class:`CapacityError` when the request can never
        fit — silently truncating the reservation would later wrap page
        indices and corrupt other sequences' KV."""
        need = -(-(prompt_len + max_tokens) // self.page_size)
        if need > self.max_pages:
            raise CapacityError(
                f"prompt_len={prompt_len} + max_tokens={max_tokens} needs "
                f"{need} pages but a slot holds {self.max_pages} "
                f"({self.max_pages * self.page_size} tokens)")
        return need

    def admit(self, seq_id: int, prompt_len: int, max_tokens: int
              ) -> Optional[SeqState]:
        """Allocate a slot + pages for a prompt.

        Returns None when no slot/pages are free right now (continuous
        batching waits); raises :class:`CapacityError` for requests that can
        never fit (see ``ensure_fits``).
        """
        need = self.ensure_fits(prompt_len, max_tokens)
        if not self.free_slots:
            return None
        slot = self.free_slots[-1]
        alloc_pool = self.pool if self.layout == "global" else self.pools[slot]
        try:
            pages = alloc_pool.alloc(need)
        except OutOfPages:
            return None
        self.free_slots.pop()
        st = SeqState(seq_id, slot, prompt_len, pages, max_tokens)
        self.seqs[seq_id] = st
        if self.layout == "global":
            row = np.full((self.max_pages,), self.null_page, np.int32)
            row[:need] = pages
        else:
            # Row is kept a PERMUTATION of [0, max_pages): allocated pages
            # first, remaining physical pages as filler — the per-slot
            # prefill scatter inverts it.
            used = set(pages)
            filler = [p for p in range(self.max_pages) if p not in used]
            row = np.asarray(pages + filler, np.int32)
        self.tables[slot] = row
        self.lengths[slot] = prompt_len
        self.dirty_rows.add(slot)
        self.space.stats.map_calls += 1
        self.space.stats.table_entries_written += len(pages)
        self.space.stats.bytes_mapped += prompt_len * self.kv_bytes_per_token
        if self.offload_mode == "copy":
            self.space.stats.bytes_copied += prompt_len * self.kv_bytes_per_token
        for lp, pp in enumerate(pages):
            self.tlb.fill((slot, lp), pp)
        return st

    def append_token(self, seq_id: int, token: int) -> None:
        st = self.seqs[seq_id]
        st.tokens.append(token)
        st.length += 1
        self.lengths[st.slot] = st.length
        needed = -(-st.length // self.page_size)
        if needed > len(st.pages):
            # Admission reserves prompt+max_tokens upfront, so this only
            # fires for callers that under-reserved; grow or fail loudly.
            if len(st.pages) >= self.max_pages:
                raise CapacityError(
                    f"seq {seq_id} grew past its slot capacity "
                    f"({self.max_pages} pages)")
            alloc_pool = (self.pool if self.layout == "global"
                          else self.pools[st.slot])
            new = alloc_pool.alloc(1)
            lp = len(st.pages)
            st.pages.extend(new)
            if self.layout == "global":
                self.tables[st.slot, lp] = new[0]
            else:
                # swap to keep the row a permutation
                row = self.tables[st.slot]
                j = int(np.where(row == new[0])[0][0])
                row[lp], row[j] = row[j], row[lp]
            self.dirty_rows.add(st.slot)
            self.space.stats.table_entries_written += 1
            self.tlb.fill((st.slot, lp), new[0])
        if len(st.tokens) >= st.max_tokens:
            st.done = True

    def release(self, seq_id: int) -> None:
        st = self.seqs.pop(seq_id)
        free_pool = (self.pool if self.layout == "global"
                     else self.pools[st.slot])
        free_pool.free(st.pages)
        self.free_slots.append(st.slot)
        self.lengths[st.slot] = 0
        if self.layout == "global":
            self.tables[st.slot] = self.null_page
        self.space.stats.unmap_calls += 1
        # self-invalidation (paper Listing 1): translations for this slot die
        for lp in range(len(st.pages)):
            self.tlb.invalidate_key((st.slot, lp))
        self.dirty_rows.add(st.slot)

    # ------------------------------------------------------------ device view
    def delta_rows(self) -> List[int]:
        """Slot rows whose tables changed since last upload (delta upload —
        the serving-level analogue of a warm IOTLB)."""
        rows = sorted(self.dirty_rows)
        self.dirty_rows.clear()
        return rows

    def invalidate_epoch(self) -> None:
        """Full translation flush (paper Listing 1): the next device upload
        must re-send every table row."""
        self.tlb.invalidate()
        self.epoch += 1
        self.dirty_rows.update(range(self.n_slots))

    def device_tables(self) -> np.ndarray:
        return self.tables.copy()

    def device_lengths(self) -> np.ndarray:
        return self.lengths.copy()

    def active_seqs(self) -> List[SeqState]:
        return [s for s in self.seqs.values() if not s.done]

    def stats(self) -> dict:
        pools = [self.pool] if self.layout == "global" else self.pools
        used = sum(p.n_used for p in pools)
        free = sum(p.n_free for p in pools)
        high = sum(p.stats.high_water for p in pools)
        util = (sum(p.utilization * p.n_pages for p in pools)
                / max(sum(p.n_pages for p in pools), 1))
        return {"sva": self.space.stats.as_dict(),
                "tlb": self.tlb.stats.as_dict(),
                "pool_used": used,
                "pool_free": free,
                "pool_high_water": high,
                "pool_utilization": round(util, 4)}
