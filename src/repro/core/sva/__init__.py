from repro.core.sva.kv_manager import (CapacityError, PagedKVManager,
                                       SeqState)
from repro.core.sva.mapping import Mapping, SVASpace, SVAStats
from repro.core.sva.page_pool import OutOfPages, PagePool, PoolStats
from repro.core.sva.tlb import TLBStats, TranslationCache

__all__ = ["CapacityError", "Mapping", "OutOfPages", "PagePool", "PagedKVManager", "PoolStats",
           "SVASpace", "SVAStats", "SeqState", "TLBStats", "TranslationCache"]
