"""Shared-virtual-address layer: page pool, the unified IOMMU translation
front-end, host mapping API, and the paged KV manager binding them to the
serving engine.

One translation implementation serves every client
(:class:`~repro.core.sva.iommu.IOMMU`): the performance simulator attaches
a 4-entry ``lru`` IOTLB over the ``Sv39Walk`` cost model, the serving
engine a large delta-upload cache over ``CountingWalk`` — same class,
different :class:`~repro.core.sva.iommu.TLBConfig`. Prefix sharing +
copy-on-write: :class:`PrefixIndex` (kv_manager) gives the pool
RadixAttention-style content addressing — admissions map an already-
resident prompt prefix via refcount++ (zero-copy across *requests*, the
paper's map-don't-copy result one level up), writes into shared pages CoW,
and released prompts persist as a warm prefix cache with policy-pluggable
(lru/lfu, optionally capped) eviction.

Runtime checking: :mod:`~repro.core.sva.sanitizer` ("svasan") is an opt-in
ASan-style shadow-state checker over the whole layer — per-page
FREE/OWNED/SHARED state machine, translate-after-unmap and stale-prefetch
cross-checks, CoW-bypass and leak detection. Enable with ``REPRO_SVASAN=1``
or the per-constructor ``sanitize=True`` knobs; zero overhead when off.
"""
from repro.core.sva.iommu import (IOMMU, CountingWalk, IOAddressSpace,
                                  Sv39Walk, TLBConfig, WalkModel, WalkStats)
from repro.core.sva.kv_manager import (CapacityError, PagedKVManager,
                                       PrefixIndex, PrefixStats, SeqState)
from repro.core.sva.mapping import Mapping, SVASpace, SVAStats
from repro.core.sva.page_pool import OutOfPages, PagePool, PoolStats
from repro.core.sva.sanitizer import (SanitizerError, SVASanitizer,
                                      SvasanReport)
from repro.core.sva.tlb import TLBStats, TranslationCache

__all__ = ["CapacityError", "CountingWalk", "IOAddressSpace", "IOMMU",
           "Mapping", "OutOfPages", "PagePool", "PagedKVManager",
           "PoolStats", "PrefixIndex", "PrefixStats", "SVASanitizer",
           "SVASpace", "SVAStats", "SanitizerError", "SeqState",
           "Sv39Walk", "SvasanReport", "TLBConfig", "TLBStats",
           "TranslationCache", "WalkModel", "WalkStats"]
