"""Shared-virtual-address layer: page pool, host mapping API, IOTLB model,
and the paged KV manager binding them to the serving engine.

Prefix sharing + copy-on-write: :class:`PrefixIndex` (kv_manager) gives the
pool RadixAttention-style content addressing — admissions map an already-
resident prompt prefix via refcount++ (zero-copy across *requests*, the
paper's map-don't-copy result one level up), writes into shared pages CoW,
and released prompts persist as a warm prefix cache with LRU eviction.
"""
from repro.core.sva.kv_manager import (CapacityError, PagedKVManager,
                                       PrefixIndex, PrefixStats, SeqState)
from repro.core.sva.mapping import Mapping, SVASpace, SVAStats
from repro.core.sva.page_pool import OutOfPages, PagePool, PoolStats
from repro.core.sva.tlb import TLBStats, TranslationCache

__all__ = ["CapacityError", "Mapping", "OutOfPages", "PagePool",
           "PagedKVManager", "PoolStats", "PrefixIndex", "PrefixStats",
           "SVASpace", "SVAStats", "SeqState", "TLBStats",
           "TranslationCache"]
