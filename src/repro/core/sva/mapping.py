"""Host mapping API — the driver/ioctl analogue of the paper's §III-B.

``SVASpace`` owns a PagePool and hands out *mappings*; translation is
delegated to the unified :class:`~repro.core.sva.iommu.IOMMU` front-end —
every mapping handle is a PASID-style ASID with its own
:class:`~repro.core.sva.iommu.IOAddressSpace`. Two offload modes,
benchmarked against each other exactly like the paper's Fig. 2:

  zero_copy  map(): allocate pages, install IOMMU translations, write table
             entries (24 B per 4 KiB in the paper; here one int32 per page)
             — no data movement.
  copy       stage(): model/perform the physical copy into a contiguous
             staging region before the device can access it (physically
             addressed: no IOMMU mapping at all).

Costs are tracked in abstract units (bytes moved, table entries written,
map calls) so both the simulator and the TPU-level benchmarks can consume
them. The two modes use DISJOINT counters: ``map_calls`` /
``table_entries_written`` / ``bytes_mapped`` count only zero-copy mapping
work, ``stage_calls`` / ``bytes_copied`` only staging work — so a Fig.2-style
zero-copy-vs-copy A/B never sees one mode's admissions leak into the other
mode's columns.

TLB semantics mirror the paper's two invalidation granularities:
``map``/``extend`` warm per-page translations, ``unmap`` self-invalidates
only the unmapped ASID's entries (device translations for OTHER mappings
stay warm), and ``invalidate_epoch`` performs the Listing-1 full flush.

Stats schema (``stats_dict()``; see ARCHITECTURE.md): the ``sva:`` block
is ``SVAStats.as_dict()`` — map_calls / unmap_calls /
table_entries_written / bytes_mapped (zero-copy counters) + stage_calls /
bytes_copied (staging counters) + host_seconds — merged with the owning
IOMMU's ``tlb:`` / ``walk:`` / ``epoch`` / ``asids`` sections.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.sva.iommu import IOMMU, CountingWalk, TLBConfig
from repro.core.sva.page_pool import PagePool
from repro.core.sva.sanitizer import SVASanitizer
from repro.core.sva.sanitizer import resolve as _resolve_sanitize


@dataclass
class Mapping:
    handle: int
    pages: List[int]              # physical page ids, logical order
    n_bytes: int
    shared_prefix_pages: int = 0  # pages shared from another mapping

    @property
    def table(self) -> np.ndarray:
        return np.asarray(self.pages, dtype=np.int32)


@dataclass
class SVAStats:
    map_calls: int = 0
    unmap_calls: int = 0
    table_entries_written: int = 0
    bytes_copied: int = 0         # copy-mode staging traffic
    bytes_mapped: int = 0
    stage_calls: int = 0          # copy-mode admissions (dedicated counter)
    host_seconds: float = 0.0

    def as_dict(self):
        return dict(map_calls=self.map_calls, unmap_calls=self.unmap_calls,
                    table_entries_written=self.table_entries_written,
                    bytes_copied=self.bytes_copied,
                    bytes_mapped=self.bytes_mapped,
                    stage_calls=self.stage_calls,
                    host_seconds=round(self.host_seconds, 6))


class SVASpace:
    """A shared virtual address space over a page pool — a thin client of
    the unified IOMMU front-end (one ASID per mapping handle)."""

    def __init__(self, pool: PagePool, tlb_entries: int = 1024,
                 tlb_policy: str = "lru",
                 sanitize: Optional[bool] = None):
        self.pool = pool
        self.iommu = IOMMU(walk_model=CountingWalk(),
                           tlb=TLBConfig(tlb_entries, tlb_policy))
        self.stats = SVAStats()
        self._next = 1
        self._maps: Dict[int, Mapping] = {}
        # svasan (core/sva/sanitizer.py): ``sanitize=None`` defers to the
        # REPRO_SVASAN environment knob; off is the historical behavior.
        self.sanitizer = (SVASanitizer() if _resolve_sanitize(sanitize)
                          else None)
        if self.sanitizer is not None:
            self.sanitizer.attach_pool(pool)
            self.iommu.sanitizer = self.sanitizer

    @property
    def tlb(self):
        """The IOMMU's shared translation cache (stats / test hook)."""
        return self.iommu.tlb

    # ------------------------------------------------------------- internal
    def _allocate(self, n_bytes: int,
                  share_prefix_from: Optional[Mapping] = None,
                  prefix_pages: int = 0) -> Mapping:
        """Allocate pages + register a Mapping WITHOUT touching any mode
        counter (shared by ``map`` and ``stage`` so the two admission modes
        keep disjoint stats)."""
        page_bytes = self.pool.page_size
        n_pages = -(-n_bytes // page_bytes)
        shared: List[int] = []
        if share_prefix_from is not None and prefix_pages > 0:
            shared = share_prefix_from.pages[:prefix_pages]
            self.pool.share(shared)
        fresh = self.pool.alloc(n_pages - len(shared))
        m = Mapping(self._next, shared + fresh, n_bytes, len(shared))
        self._next += 1
        self._maps[m.handle] = m
        return m

    # ----------------------------------------------------------- zero-copy
    def map(self, n_bytes: int,
            share_prefix_from: Optional[Mapping] = None,
            prefix_pages: int = 0) -> Mapping:
        """Zero-copy: allocate pages and write block-table entries only."""
        t0 = time.perf_counter()
        m = self._allocate(n_bytes, share_prefix_from, prefix_pages)
        self.stats.map_calls += 1
        self.stats.table_entries_written += len(m.pages)
        self.stats.bytes_mapped += n_bytes
        self.iommu.attach(m.handle).map(m.pages)
        self.stats.host_seconds += time.perf_counter() - t0
        return m

    def extend(self, m: Mapping, n_new_pages: int = 1) -> List[int]:
        """Grow a mapping (decode appends crossing a page boundary).

        Keeps ``Mapping.n_bytes`` and ``stats.bytes_mapped`` in sync so
        decode-driven growth shows up in the memory-pressure stats (it used
        to grow ``m.pages`` silently, leaving both stale)."""
        t0 = time.perf_counter()
        fresh = self.pool.alloc(n_new_pages)
        grown_bytes = n_new_pages * self.pool.page_size
        sp = self.iommu.space(m.handle)
        if sp is not None:
            sp.extend(fresh)
        m.pages.extend(fresh)
        m.n_bytes += grown_bytes
        self.stats.bytes_mapped += grown_bytes
        self.stats.table_entries_written += n_new_pages
        self.stats.host_seconds += time.perf_counter() - t0
        return fresh

    def unmap(self, m: Mapping) -> None:
        """Release a mapping, invalidating ONLY its own translations.

        A whole-TLB (epoch) flush per unmap would force a full re-walk /
        full-table re-upload for every OTHER live mapping each time one
        request completes; per-ASID invalidation keeps their translations
        warm. The Listing-1 full flush is ``invalidate_epoch()``."""
        t0 = time.perf_counter()
        self.pool.free(m.pages)
        self._maps.pop(m.handle, None)
        self.stats.unmap_calls += 1
        self.iommu.detach(m.handle)
        self.stats.host_seconds += time.perf_counter() - t0

    def translate(self, m: Mapping, logical_page: int):
        """Device-side translation through the shared IOTLB: returns
        (physical page, walk cost, hit)."""
        sp = self.iommu.space(m.handle)
        if sp is None:
            raise KeyError(f"mapping {m.handle} has no IOMMU address space "
                           "(staged mappings are physically addressed)")
        return sp.translate(logical_page)

    def invalidate_epoch(self) -> None:
        """Full translation flush (paper Listing 1)."""
        self.iommu.invalidate()

    # ----------------------------------------------------------- copy mode
    def stage(self, n_bytes: int, do_copy=None) -> Mapping:
        """Copy-based baseline: contiguous staging (models the reserved
        physically-addressed DRAM region — no IOMMU mapping is created).
        ``do_copy(n_bytes)`` performs the actual data movement when the
        caller has real buffers.

        Tracked in DEDICATED counters (``stage_calls`` / ``bytes_copied``):
        it no longer routes through ``map()``, so copy-mode admissions never
        inflate ``map_calls`` / ``table_entries_written`` / ``bytes_mapped``
        and corrupt a zero-copy-vs-copy A/B."""
        t0 = time.perf_counter()
        m = self._allocate(n_bytes)
        m.shared_prefix_pages = 0
        if do_copy is not None:
            do_copy(n_bytes)
        self.stats.stage_calls += 1
        self.stats.bytes_copied += n_bytes    # pays the copy, not the map
        self.stats.host_seconds += time.perf_counter() - t0
        return m

    # --------------------------------------------------------------- stats
    def stats_dict(self) -> dict:
        """Unified stats schema: host-side counters + the IOMMU's
        translation sections (see ARCHITECTURE.md)."""
        return {"sva": self.stats.as_dict(), **self.iommu.stats()}
