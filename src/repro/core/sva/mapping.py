"""Host mapping API — the driver/ioctl analogue of the paper's §III-B.

``SVASpace`` owns a PagePool and hands out *mappings*: per-object block
tables (logical page -> physical page). Two offload modes, benchmarked
against each other exactly like the paper's Fig. 2:

  zero_copy  map(): allocate pages, write table entries (24 B per 4 KiB in
             the paper; here one int32 per page) — no data movement.
  copy       stage(): model/perform the physical copy into a contiguous
             staging region before the device can access it.

Costs are tracked in abstract units (bytes moved, table entries written,
map calls) so both the simulator and the TPU-level benchmarks can consume
them. The two modes use DISJOINT counters: ``map_calls`` /
``table_entries_written`` / ``bytes_mapped`` count only zero-copy mapping
work, ``stage_calls`` / ``bytes_copied`` only staging work — so a Fig.2-style
zero-copy-vs-copy A/B never sees one mode's admissions leak into the other
mode's columns.

TLB semantics mirror the paper's two invalidation granularities:
``map``/``extend`` warm per-page translations, ``unmap`` self-invalidates
only the unmapped pages' entries (device translations for OTHER mappings
stay warm), and ``invalidate_epoch`` performs the Listing-1 full flush.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.sva.page_pool import PagePool
from repro.core.sva.tlb import TranslationCache


@dataclass
class Mapping:
    handle: int
    pages: List[int]              # physical page ids, logical order
    n_bytes: int
    shared_prefix_pages: int = 0  # pages shared from another mapping

    @property
    def table(self) -> np.ndarray:
        return np.asarray(self.pages, dtype=np.int32)


@dataclass
class SVAStats:
    map_calls: int = 0
    unmap_calls: int = 0
    table_entries_written: int = 0
    bytes_copied: int = 0         # copy-mode staging traffic
    bytes_mapped: int = 0
    stage_calls: int = 0          # copy-mode admissions (dedicated counter)
    host_seconds: float = 0.0

    def as_dict(self):
        return dict(map_calls=self.map_calls, unmap_calls=self.unmap_calls,
                    table_entries_written=self.table_entries_written,
                    bytes_copied=self.bytes_copied,
                    bytes_mapped=self.bytes_mapped,
                    stage_calls=self.stage_calls,
                    host_seconds=round(self.host_seconds, 6))


class SVASpace:
    """A shared virtual address space over a page pool."""

    def __init__(self, pool: PagePool, tlb_entries: int = 1024):
        self.pool = pool
        self.tlb = TranslationCache(tlb_entries)
        self.stats = SVAStats()
        self._next = 1
        self._maps: Dict[int, Mapping] = {}

    # ------------------------------------------------------------- internal
    def _allocate(self, n_bytes: int,
                  share_prefix_from: Optional[Mapping] = None,
                  prefix_pages: int = 0) -> Mapping:
        """Allocate pages + register a Mapping WITHOUT touching any mode
        counter (shared by ``map`` and ``stage`` so the two admission modes
        keep disjoint stats)."""
        page_bytes = self.pool.page_size
        n_pages = -(-n_bytes // page_bytes)
        shared: List[int] = []
        if share_prefix_from is not None and prefix_pages > 0:
            shared = share_prefix_from.pages[:prefix_pages]
            self.pool.share(shared)
        fresh = self.pool.alloc(n_pages - len(shared))
        m = Mapping(self._next, shared + fresh, n_bytes, len(shared))
        self._next += 1
        self._maps[m.handle] = m
        return m

    # ----------------------------------------------------------- zero-copy
    def map(self, n_bytes: int,
            share_prefix_from: Optional[Mapping] = None,
            prefix_pages: int = 0) -> Mapping:
        """Zero-copy: allocate pages and write block-table entries only."""
        t0 = time.perf_counter()
        m = self._allocate(n_bytes, share_prefix_from, prefix_pages)
        self.stats.map_calls += 1
        self.stats.table_entries_written += len(m.pages)
        self.stats.bytes_mapped += n_bytes
        for lp, pp in enumerate(m.pages):
            self.tlb.fill((m.handle, lp), pp)
        self.stats.host_seconds += time.perf_counter() - t0
        return m

    def extend(self, m: Mapping, n_new_pages: int = 1) -> List[int]:
        """Grow a mapping (decode appends crossing a page boundary).

        Keeps ``Mapping.n_bytes`` and ``stats.bytes_mapped`` in sync so
        decode-driven growth shows up in the memory-pressure stats (it used
        to grow ``m.pages`` silently, leaving both stale)."""
        t0 = time.perf_counter()
        fresh = self.pool.alloc(n_new_pages)
        grown_bytes = n_new_pages * self.pool.page_size
        for lp, pp in enumerate(fresh, start=len(m.pages)):
            self.tlb.fill((m.handle, lp), pp)
        m.pages.extend(fresh)
        m.n_bytes += grown_bytes
        self.stats.bytes_mapped += grown_bytes
        self.stats.table_entries_written += n_new_pages
        self.stats.host_seconds += time.perf_counter() - t0
        return fresh

    def unmap(self, m: Mapping) -> None:
        """Release a mapping, invalidating ONLY its own translations.

        A whole-TLB (epoch) flush per unmap would force a full re-walk /
        full-table re-upload for every OTHER live mapping each time one
        request completes; per-key invalidation keeps their translations
        warm. The Listing-1 full flush is ``invalidate_epoch()``."""
        t0 = time.perf_counter()
        self.pool.free(m.pages)
        self._maps.pop(m.handle, None)
        self.stats.unmap_calls += 1
        for lp in range(len(m.pages)):
            self.tlb.invalidate_key((m.handle, lp))
        self.stats.host_seconds += time.perf_counter() - t0

    def invalidate_epoch(self) -> None:
        """Full translation flush (paper Listing 1)."""
        self.tlb.invalidate()

    # ----------------------------------------------------------- copy mode
    def stage(self, n_bytes: int, do_copy=None) -> Mapping:
        """Copy-based baseline: contiguous staging (models the reserved
        physically-addressed DRAM region). ``do_copy(n_bytes)`` performs the
        actual data movement when the caller has real buffers.

        Tracked in DEDICATED counters (``stage_calls`` / ``bytes_copied``):
        it no longer routes through ``map()``, so copy-mode admissions never
        inflate ``map_calls`` / ``table_entries_written`` / ``bytes_mapped``
        and corrupt a zero-copy-vs-copy A/B."""
        t0 = time.perf_counter()
        m = self._allocate(n_bytes)
        m.shared_prefix_pages = 0
        if do_copy is not None:
            do_copy(n_bytes)
        self.stats.stage_calls += 1
        self.stats.bytes_copied += n_bytes    # pays the copy, not the map
        self.stats.host_seconds += time.perf_counter() - t0
        return m
