#!/usr/bin/env python
"""Docs smoke checker (CI `docs` job; runnable locally).

Two checks, both driven from the repo's markdown itself so the docs cannot
drift from the code:

  1. **Intra-repo links resolve.** Every relative ``[text](target)`` link
     in the repo's markdown surface (README.md, benchmarks/README.md,
     ARCHITECTURE.md, ROADMAP.md, CHANGES.md, PAPER.md, PAPERS.md) must
     point at an existing file or directory (anchors are stripped;
     external http(s)/mailto links are skipped).

  2. **The README quickstart runs as-is.** Commands are extracted from
     README.md's fenced code blocks: any line starting with
     ``PYTHONPATH=src`` is considered an executable quickstart command
     (install lines like ``pip install ...`` are prose, not checked).
     ``--run`` executes each from the repo root and fails on a nonzero
     exit; without ``--run`` the commands are only listed (cheap local
     lint).

Exit status: 0 = all good, 1 = broken links or a failed command.

  python tools/check_docs.py            # link check + list commands
  python tools/check_docs.py --run      # CI: also execute the quickstart
"""
from __future__ import annotations

import argparse
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

DOC_FILES = ("README.md", "benchmarks/README.md", "ARCHITECTURE.md",
             "ROADMAP.md", "CHANGES.md", "PAPER.md", "PAPERS.md")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.S)


def check_links() -> list:
    errors = []
    for rel in DOC_FILES:
        path = REPO / rel
        if not path.exists():
            continue                      # optional docs are optional
        text = path.read_text()
        for target in LINK_RE.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            if target.startswith("#"):                     # in-page anchor
                continue
            clean = target.split("#", 1)[0]
            if not clean:
                continue
            resolved = (path.parent / clean).resolve()
            if not resolved.exists():
                errors.append(f"{rel}: broken link -> {target}")
    return errors


def quickstart_commands() -> list:
    readme = (REPO / "README.md").read_text()
    cmds = []
    for block in FENCE_RE.findall(readme):
        for line in block.splitlines():
            line = line.strip()
            if line.startswith("PYTHONPATH=src"):
                cmds.append(line)
    return cmds


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--run", action="store_true",
                    help="execute the extracted quickstart commands")
    args = ap.parse_args()

    errors = check_links()
    for e in errors:
        print(f"LINK FAIL  {e}")
    n_links = sum(1 for rel in DOC_FILES if (REPO / rel).exists())
    print(f"link check: {n_links} docs scanned, {len(errors)} broken")

    cmds = quickstart_commands()
    if not cmds:
        print("QUICKSTART FAIL: no PYTHONPATH=src commands found in "
              "README.md code blocks")
        return 1
    for cmd in cmds:
        if not args.run:
            print(f"quickstart (not run): {cmd}")
            continue
        print(f"quickstart RUN: {cmd}", flush=True)
        proc = subprocess.run(["bash", "-c", cmd], cwd=REPO)
        if proc.returncode != 0:
            print(f"QUICKSTART FAIL ({proc.returncode}): {cmd}")
            return 1
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
