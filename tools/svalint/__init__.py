"""svalint — repo-specific static analysis for the paged SVA stack.

The generic linters (ruff/mypy, run next to this in CI) know nothing about
THIS repo's invariants: one ``TranslationCache`` owner, a refcount-disciplined
page pool, a stats schema that must match ARCHITECTURE.md, jit-cache-key
hygiene in the serving hot path, and documented benchmark flags. svalint
checks exactly those, over the AST (no regex-on-source false positives) plus
two project-level cross-checks against the docs.

Rules (catalog with rationale in ARCHITECTURE.md):

  R001  no module outside src/repro/core/sva/iommu.py constructs a raw
        TranslationCache or touches its private state — the IOMMU front-end
        is the single owner (tests/test_iommu.py delegates here)
  R002  no raw PagePool refcount mutation (.alloc/.free/.share on a pool)
        or private-state access (._free/._ref) outside the SVA ownership
        layer; in the serving engine only ``_apply_cow`` may touch pool
        state (tests/ are exempt: they drive the pool API to test it)
  R003  every stats key emitted by stats()/as_dict()/stats_dict() in
        core/sva/ appears in ARCHITECTURE.md's "## Stats schema" section,
        and vice versa (docs-drift detector, both directions)
  R004  jit hazards in core/serving/ and kernels/: host materialization of
        traced values (.item(), int()/float()/bool() on non-static values,
        np.asarray/np.array) inside jit-traced functions, unhashable
        list/set/dict literals passed as static args, and shape-dependent
        Python branching (non-guard) that defeats the padded-bucket jit
        cache
  R005  every argparse ``--flag`` defined in benchmarks/*.py and
        examples/serve_paged.py is mentioned in README.md or
        benchmarks/README.md

Use as a CLI (``python -m tools.svalint src tests benchmarks``) or as a
library (``lint_sources({relpath: text, ...})`` — how the fixture tests in
tests/test_svalint.py feed minimal violations). Per-line suppression:
``# svalint: disable=R002`` (comma-separate for several rules).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES = ("R001", "R002", "R003", "R004", "R005")

#: files the CLI always loads for the project-level rules
DOC_FILES = ("ARCHITECTURE.md", "README.md", "benchmarks/README.md")

_SUPPRESS_RE = re.compile(r"#\s*svalint:\s*disable=([A-Z0-9,\s]+)")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


def _parse(path: str, text: str) -> Optional[ast.Module]:
    try:
        return ast.parse(text, filename=path)
    except SyntaxError:
        return None


def _terminal_name(node: ast.AST) -> str:
    """Rightmost identifier of a Name/Attribute/Subscript chain
    (``self.pools[slot]`` -> ``pools``)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


# --------------------------------------------------------------------- R001

#: private TranslationCache state (unique to tlb.py's implementation)
_TLB_INTERNALS = {"_sets", "_set0", "_freq", "_meta", "_bump_gdsfs",
                  "_set_index", "_range_index"}

#: the single module allowed to construct a TranslationCache (plus the
#: defining module itself)
_R001_ALLOWED = ("src/repro/core/sva/iommu.py", "src/repro/core/sva/tlb.py")


def _r001(path: str, tree: ast.Module) -> List[Finding]:
    if path in _R001_ALLOWED:
        return []
    # White-box tests may INSPECT internals (per-set occupancy bounds);
    # construction stays banned everywhere.
    check_internals = not path.startswith("tests/")
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                _terminal_name(node.func) == "TranslationCache":
            out.append(Finding(
                path, node.lineno, "R001",
                "raw TranslationCache construction outside the IOMMU "
                "front-end (go through IOMMU(...).tlb / TLBConfig)"))
        elif check_internals and isinstance(node, ast.Attribute) and \
                node.attr in _TLB_INTERNALS:
            out.append(Finding(
                path, node.lineno, "R001",
                f"access to TranslationCache internal '{node.attr}' "
                "outside core/sva/iommu.py"))
    return out


# --------------------------------------------------------------------- R002

_POOL_INTERNALS = {"_free", "_ref"}
_POOL_MUTATORS = {"alloc", "alloc_run", "free", "share"}
_R002_ALLOWED = ("src/repro/core/sva/page_pool.py",
                 "src/repro/core/sva/kv_manager.py",
                 "src/repro/core/sva/mapping.py",
                 "src/repro/core/sva/sanitizer.py")
_R002_ENGINE = "src/repro/core/serving/engine.py"


def _enclosing_functions(tree: ast.Module) -> Dict[int, str]:
    """Map line number -> name of the innermost enclosing function."""
    spans: List[Tuple[int, int, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            end = getattr(node, "end_lineno", node.lineno)
            spans.append((node.lineno, end, node.name))
    spans.sort(key=lambda s: s[1] - s[0])        # innermost first
    out: Dict[int, str] = {}
    for lo, hi, name in reversed(spans):
        for ln in range(lo, hi + 1):
            out[ln] = name
    return out


def _r002(path: str, tree: ast.Module) -> List[Finding]:
    if path in _R002_ALLOWED or path.startswith("tests/"):
        return []
    in_engine = path == _R002_ENGINE
    funcs = _enclosing_functions(tree) if in_engine else {}
    out = []
    for node in ast.walk(tree):
        if in_engine and funcs.get(node.lineno if hasattr(node, "lineno")
                                   else -1) == "_apply_cow":
            continue                              # the sanctioned CoW path
        if isinstance(node, ast.Attribute) and \
                node.attr in _POOL_INTERNALS and \
                "pool" in _terminal_name(node.value).lower():
            out.append(Finding(
                path, node.lineno, "R002",
                f"access to PagePool internal '{node.attr}' outside "
                "core/sva/page_pool.py"))
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _POOL_MUTATORS and \
                "pool" in _terminal_name(node.func.value).lower():
            out.append(Finding(
                path, node.lineno, "R002",
                f"raw page-pool mutation '.{node.func.attr}()' outside "
                "PagedKVManager / the engine's _apply_cow path"))
    return out


# --------------------------------------------------------------------- R003

_STATS_FUNCS = {"stats", "as_dict", "stats_dict"}
_R003_SCOPE = "src/repro/core/sva/"
_SCHEMA_HEADER = "## Stats schema"


def _emitted_stats_keys(sources: Dict[str, str]
                        ) -> Dict[str, Tuple[str, int]]:
    """Key -> (file, line) for every stats key emitted in core/sva/."""
    keys: Dict[str, Tuple[str, int]] = {}
    for path, text in sources.items():
        if not (path.startswith(_R003_SCOPE) and path.endswith(".py")):
            continue
        tree = _parse(path, text)
        if tree is None:
            continue
        for fn in ast.walk(tree):
            if not (isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and fn.name in _STATS_FUNCS):
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Dict):
                    for k in node.keys:
                        if isinstance(k, ast.Constant) and \
                                isinstance(k.value, str):
                            keys.setdefault(k.value, (path, k.lineno))
                elif isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id == "dict":
                    for kw in node.keywords:
                        if kw.arg:
                            keys.setdefault(kw.arg, (path, node.lineno))
                elif isinstance(node, ast.Assign):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Subscript) and \
                                isinstance(tgt.slice, ast.Constant) and \
                                isinstance(tgt.slice.value, str):
                            keys.setdefault(tgt.slice.value,
                                            (path, tgt.lineno))
    return keys


def _documented_stats_keys(arch: str) -> Optional[Set[str]]:
    """Keys named in ARCHITECTURE.md's stats-schema code fences.

    Format contract (see that section): keys are bare identifiers followed
    by ``:`` or listed inside ``{...}``; prose/value descriptions live in
    ``<...>`` or ``#`` comments, which are stripped before tokenizing."""
    lines = arch.splitlines()
    try:
        start = next(i for i, l in enumerate(lines)
                     if l.strip() == _SCHEMA_HEADER)
    except StopIteration:
        return None
    body: List[str] = []
    for l in lines[start + 1:]:
        if l.startswith("## "):
            break
        body.append(l)
    fences = re.findall(r"```(.*?)```", "\n".join(body), flags=re.S)
    keys: Set[str] = set()
    for block in fences:
        block = re.sub(r"#[^\n]*", " ", block)
        block = re.sub(r"<[^>]*>", " ", block)
        keys.update(re.findall(r"[A-Za-z_][A-Za-z0-9_]*", block))
    return keys


def _r003(sources: Dict[str, str]) -> List[Finding]:
    arch = sources.get("ARCHITECTURE.md")
    if arch is None:
        return []
    emitted = _emitted_stats_keys(sources)
    if not emitted:
        return []
    documented = _documented_stats_keys(arch)
    if documented is None:
        return [Finding("ARCHITECTURE.md", 1, "R003",
                        f"missing '{_SCHEMA_HEADER}' section (the stats "
                        "schema contract has no home)")]
    out = []
    for key in sorted(set(emitted) - documented):
        path, line = emitted[key]
        out.append(Finding(
            path, line, "R003",
            f"stats key '{key}' is emitted but not documented in "
            f"ARCHITECTURE.md's '{_SCHEMA_HEADER}' section"))
    for key in sorted(documented - set(emitted)):
        out.append(Finding(
            "ARCHITECTURE.md", 1, "R003",
            f"stats key '{key}' is documented in '{_SCHEMA_HEADER}' but "
            "no core/sva/ stats()/as_dict() emits it"))
    return out


# --------------------------------------------------------------------- R004

_R004_SCOPES = ("src/repro/core/serving/", "src/repro/kernels/")
_HOST_CASTS = {"int", "float", "bool"}
_NP_NAMES = {"np", "numpy", "onp"}
_NP_HOST = {"asarray", "array"}
_STATIC_SAFE = {"shape", "ndim", "size", "dtype"}
_UNHASHABLE = (ast.List, ast.Set, ast.Dict, ast.ListComp, ast.SetComp,
               ast.DictComp, ast.GeneratorExp)


def _is_jit_expr(node: ast.AST) -> bool:
    """True for ``jax.jit`` / ``jit`` and ``(functools.)partial(jax.jit,
    ...)`` expressions."""
    if isinstance(node, ast.Call):
        if _terminal_name(node.func) == "partial" and node.args:
            return _is_jit_expr(node.args[0])
        return _is_jit_expr(node.func)
    return _terminal_name(node) == "jit"


def _static_names_of(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
    return names


def _contains_static_marker(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_SAFE:
            return True
        if isinstance(n, ast.Call) and _terminal_name(n.func) == "len":
            return True
    return False


def _r004(path: str, tree: ast.Module) -> List[Finding]:
    if not any(path.startswith(s) for s in _R004_SCOPES):
        return []
    # Only module-level defs and class methods are resolvable call targets;
    # defs nested inside a function (the engine's `walk` tree-walkers) are
    # scanned as part of their parent's body, never as independent names —
    # registering them would alias unrelated helpers that share a name.
    funcs: Dict[str, ast.AST] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            funcs.setdefault(node.name, node)
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    funcs.setdefault(sub.name, sub)

    jitted: Set[str] = set()
    static_args: Dict[str, Set[str]] = {}
    for name, node in funcs.items():
        for dec in node.decorator_list:
            if _is_jit_expr(dec):
                jitted.add(name)
                if isinstance(dec, ast.Call):
                    static_args.setdefault(name, set()).update(
                        _static_names_of(dec))
    for node in ast.walk(tree):
        # jax.jit(self._fn, ...) references mark the wrapped def as traced
        if isinstance(node, ast.Call) and _is_jit_expr(node.func) and \
                node.args:
            tgt = _terminal_name(node.args[0])
            if tgt in funcs:
                jitted.add(tgt)
                static_args.setdefault(tgt, set()).update(
                    _static_names_of(node))

    # transitive closure over same-module calls (helpers called from a
    # jit-traced function are traced too)
    changed = True
    while changed:
        changed = False
        for name in list(jitted):
            for node in ast.walk(funcs[name]):
                if isinstance(node, ast.Call):
                    callee = _terminal_name(node.func)
                    if callee in funcs and callee not in jitted:
                        jitted.add(callee)
                        changed = True

    out: List[Finding] = []
    for name in sorted(jitted):
        fn = funcs[name]
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item":
                    out.append(Finding(
                        path, node.lineno, "R004",
                        f".item() in jit-traced '{name}' materializes a "
                        "traced value on the host"))
                elif isinstance(node.func, ast.Name) and \
                        node.func.id in _HOST_CASTS and node.args:
                    arg = node.args[0]
                    if not isinstance(arg, ast.Constant) and \
                            not _contains_static_marker(arg):
                        out.append(Finding(
                            path, node.lineno, "R004",
                            f"{node.func.id}() on a (possibly traced) "
                            f"value in jit-traced '{name}' — static "
                            "shape/len() derivations are fine, traced "
                            "values are a TracerConversionError"))
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _NP_HOST and \
                        _terminal_name(node.func.value) in _NP_NAMES:
                    out.append(Finding(
                        path, node.lineno, "R004",
                        f"np.{node.func.attr}() in jit-traced '{name}' "
                        "forces a host copy of a traced value"))
            elif isinstance(node, (ast.If, ast.While)):
                if _shape_branch(node.test) and not _is_guard(node):
                    out.append(Finding(
                        path, node.lineno, "R004",
                        f"shape-dependent Python branch in jit-traced "
                        f"'{name}' retraces per shape and defeats the "
                        "padded-bucket jit cache (raise-only guards are "
                        "exempt)"))
            elif isinstance(node, ast.IfExp) and _shape_branch(node.test):
                out.append(Finding(
                    path, node.lineno, "R004",
                    f"shape-dependent conditional expression in "
                    f"jit-traced '{name}' defeats the padded-bucket jit "
                    "cache"))

    # unhashable static args at call sites of jit-wrapped callables
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _terminal_name(node.func)
        statics = static_args.get(callee)
        if not statics:
            continue
        for kw in node.keywords:
            if kw.arg in statics and isinstance(kw.value, _UNHASHABLE):
                out.append(Finding(
                    path, node.lineno, "R004",
                    f"unhashable {type(kw.value).__name__.lower()} passed "
                    f"as static arg '{kw.arg}' of jitted '{callee}' — "
                    "static args key the jit cache and must be hashable "
                    "(use a tuple)"))
    return out


def _shape_branch(test: ast.AST) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "shape"
               for n in ast.walk(test))


def _is_guard(node: ast.AST) -> bool:
    """True for trace-time validation: every branch body is a bare raise."""
    bodies = list(node.body) + list(getattr(node, "orelse", []))
    return all(isinstance(s, ast.Raise) for s in bodies)


# --------------------------------------------------------------------- R005

_R005_READMES = ("README.md", "benchmarks/README.md")


def _r005(sources: Dict[str, str]) -> List[Finding]:
    docs = [sources[p] for p in _R005_READMES if p in sources]
    if not docs:
        return []
    out = []
    for path, text in sorted(sources.items()):
        if not (path.endswith(".py") and
                (path.startswith("benchmarks/") or
                 path == "examples/serve_paged.py")):
            continue
        tree = _parse(path, text)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "add_argument" and node.args and \
                    isinstance(node.args[0], ast.Constant) and \
                    isinstance(node.args[0].value, str) and \
                    node.args[0].value.startswith("--"):
                flag = node.args[0].value
                if not any(flag in d for d in docs):
                    out.append(Finding(
                        path, node.lineno, "R005",
                        f"flag '{flag}' is not mentioned in README.md or "
                        "benchmarks/README.md"))
    return out


# ------------------------------------------------------------------- driver

def _suppressed(sources: Dict[str, str], f: Finding) -> bool:
    text = sources.get(f.path)
    if text is None:
        return False
    lines = text.splitlines()
    if not 1 <= f.line <= len(lines):
        return False
    m = _SUPPRESS_RE.search(lines[f.line - 1])
    return bool(m) and f.rule in {r.strip() for r in m.group(1).split(",")}


def lint_sources(sources: Dict[str, str],
                 rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run svalint over in-memory sources: {repo-relative path: text}.
    Include ARCHITECTURE.md / README.md / benchmarks/README.md entries for
    the project-level rules (R003, R005) to run."""
    active = set(rules or RULES)
    findings: List[Finding] = []
    for path, text in sorted(sources.items()):
        if not path.endswith(".py"):
            continue
        tree = _parse(path, text)
        if tree is None:
            continue
        if "R001" in active:
            findings += _r001(path, tree)
        if "R002" in active:
            findings += _r002(path, tree)
        if "R004" in active:
            findings += _r004(path, tree)
    if "R003" in active:
        findings += _r003(sources)
    if "R005" in active:
        findings += _r005(sources)
    findings = [f for f in findings if not _suppressed(sources, f)]
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def load_tree(root, paths: Iterable[str]) -> Dict[str, str]:
    """Read every .py under ``paths`` (plus the doc files) into the
    {relpath: text} mapping ``lint_sources`` consumes."""
    from pathlib import Path
    root = Path(root)
    sources: Dict[str, str] = {}
    for rel in paths:
        p = root / rel
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            if f.suffix == ".py":
                sources[f.relative_to(root).as_posix()] = \
                    f.read_text(encoding="utf-8")
    for doc in DOC_FILES:
        f = root / doc
        if f.is_file():
            sources[doc] = f.read_text(encoding="utf-8")
    return sources


def lint_paths(root, paths: Iterable[str],
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    return lint_sources(load_tree(root, paths), rules=rules)
