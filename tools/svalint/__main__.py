"""CLI: ``python -m tools.svalint [paths...]`` from the repo root.

Exits 1 when any rule fires; 0 on a clean tree. Default paths cover
everything the rules scope to."""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.svalint import RULES, lint_paths

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="svalint", description="repo-specific SVA-stack lint")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="paths (relative to the repo root) to lint")
    ap.add_argument("--rules", default=",".join(RULES),
                    help="comma-separated rule ids to run")
    args = ap.parse_args(argv)
    root = Path(__file__).resolve().parents[2]
    findings = lint_paths(root, args.paths,
                          rules=[r.strip() for r in args.rules.split(",")])
    for f in findings:
        print(f)
    if findings:
        print(f"svalint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("svalint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
