"""Hypothesis property tests on the system's invariants (deliverable c)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.simulator.run import simulate_kernel
from repro.core.sva.iommu import IOMMU, CountingWalk, TLBConfig
from repro.core.sva.page_pool import OutOfPages, PagePool
from repro.kernels.mergesort.ops import mergesort
from repro.kernels.paged_attention.ref import paged_attention_ref

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 6)), min_size=1,
                max_size=60))
def test_page_pool_invariants(ops):
    """Alloc/free/share in any order never corrupts refcounts or the free
    list; allocations are unique live pages."""
    pool = PagePool(n_pages=24, page_size=64)
    live = []
    for is_alloc, n in ops:
        if is_alloc:
            try:
                pages = pool.alloc(n)
            except OutOfPages:
                assert pool.n_free < n
                continue
            assert len(set(pages)) == n
            for p in pages:
                assert pool.refcount(p) == 1
            live.append(pages)
        elif live:
            pool.free(live.pop())
        pool.check_invariants()
    for pages in live:
        pool.free(pages)
    pool.check_invariants()
    assert pool.n_free == 24


@settings(**SETTINGS)
@given(st.integers(2, 64), st.integers(1, 16))
def test_prefix_sharing_refcounts(n_pages, shared):
    pool = PagePool(n_pages=n_pages + 16, page_size=64)
    a = pool.alloc(n_pages)
    shared = min(shared, n_pages)
    pool.share(a[:shared])
    pool.free(a)                      # owner releases everything
    for p in a[:shared]:
        assert pool.refcount(p) == 1  # prefix still alive via the sharer
    pool.free(a[:shared])
    pool.check_invariants()
    assert pool.n_free == pool.n_pages


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 5)), min_size=1,
                max_size=80),
       st.integers(1, 4))
def test_kv_manager_cow_interleaving_invariants(ops, n_prompts):
    """Random admit / append (CoW) / release interleavings over a small
    prompt population (maximal sharing pressure) never corrupt the pool:
    refcounts match the free list, shared pages are never double-freed, and
    every queued CoW copy targets a freshly allocated (exclusively owned)
    destination page."""
    from repro.core.sva.kv_manager import PagedKVManager
    mgr = PagedKVManager(n_slots=3, max_pages_per_slot=6, page_size=4)
    prompts = [[100 + 10 * j + i for i in range(5 + j)]
               for j in range(n_prompts)]
    next_id = 0
    live = []
    for op, arg in ops:
        if op in (0, 1):                          # admit (two weights)
            prompt = prompts[arg % len(prompts)]
            try:
                s = mgr.admit(next_id, len(prompt), 8, tokens=prompt)
            except Exception:
                s = None
            if s is not None:
                live.append(next_id)
                next_id += 1
        elif op == 2 and live:                    # append -> may CoW/steal
            sid = live[arg % len(live)]
            if not mgr.seqs[sid].done:
                mgr.append_token(sid, arg)
        elif op == 3 and live:                    # release -> warm cache
            sid = live.pop(arg % len(live))
            mgr.release(sid)
        # drain like the engine does (one batch of device copies per step):
        # at queue time a dst is exclusively owned and a src still live.
        for src, dst in mgr.drain_cow_copies():
            assert mgr.pool.refcount(dst) == 1, "CoW dst must be exclusive"
            assert mgr.pool.refcount(src) >= 1, "CoW src still shared"
        mgr.pool.check_invariants()
    for sid in live:
        mgr.release(sid)
    mgr.pool.check_invariants()
    # every remaining page is held by the warm prefix cache alone
    assert mgr.pool.n_used == mgr.prefix.n_cached_pages


@settings(**SETTINGS)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=200),
       st.integers(1, 8),
       st.sampled_from(["lru", "fifo", "lfu", "random"]))
def test_tlb_policies(refs, entries, policy):
    """ANY replacement policy through the IOMMU front-end: capacity is
    never exceeded, a hit implies a previous walk, translations are always
    correct, every genuine miss walks exactly once, and a full invalidation
    empties the cache and bumps the epoch exactly once."""
    iommu = IOMMU(walk_model=CountingWalk(),
                  tlb=TLBConfig(entries, policy, seed=1))
    sp = iommu.attach(0)
    sp.map([r * 7 for r in range(31)], warm=False)     # table only, cold TLB
    walked = set()
    for r in refs:
        val, cost, hit = sp.translate(r)
        assert val == r * 7
        if hit:
            assert r in walked
        walked.add(r)
        assert len(iommu.tlb) <= entries
    assert iommu.tlb.stats.walks == iommu.tlb.stats.misses
    assert iommu.walk_model.stats.walks == iommu.tlb.stats.walks
    iommu.invalidate()
    assert len(iommu.tlb) == 0 and iommu.epoch == 1
    assert sp.translate(refs[0])[2] is False


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 256, 1024]))
def test_mergesort_is_sorted_permutation(seed, n):
    x = jax.random.normal(jax.random.key(seed), (n,))
    out = np.asarray(mergesort(x, block=min(64, n)))
    xs = np.asarray(x)
    assert np.all(np.diff(out) >= 0)
    assert np.array_equal(np.sort(xs), out)


@settings(**SETTINGS)
@given(st.sampled_from(["gemm", "gesummv", "heat3d", "mergesort"]),
       st.sampled_from(["baseline", "iommu", "iommu_llc"]))
def test_simulator_monotonic_in_latency(kernel, config):
    """Runtime never decreases with DRAM latency; IOMMU never beats baseline;
    the LLC never hurts the IOMMU config."""
    ts = [simulate_kernel(kernel, config, lat).total
          for lat in (200, 400, 600, 800, 1000)]
    assert all(b >= a * 0.999 for a, b in zip(ts, ts[1:]))
    for lat in (200, 600, 1000):
        base = simulate_kernel(kernel, "baseline", lat).total
        iommu = simulate_kernel(kernel, "iommu", lat).total
        llc = simulate_kernel(kernel, "iommu_llc", lat).total
        assert iommu >= base * 0.999
        assert llc <= iommu * 1.001


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1))
def test_paged_attention_table_permutation_invariance(seed):
    """softmax attention through ANY page permutation == identity placement
    (the zero-copy property: physical placement never changes results)."""
    kk = jax.random.split(jax.random.key(seed), 5)
    B, Hq, Hkv, P, T, D = 2, 4, 2, 4, 8, 16
    q = jax.random.normal(kk[0], (B, Hq, D))
    kp = jax.random.normal(kk[1], (B, P, T, Hkv, D))
    vp = jax.random.normal(kk[2], (B, P, T, Hkv, D))
    lens = jnp.asarray([P * T, P * T // 2], jnp.int32)
    ident = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    perm = jnp.stack([jax.random.permutation(k2, P)
                      for k2 in jax.random.split(kk[3], B)]).astype(jnp.int32)
    # permute the physical pages consistently with the table
    inv = jnp.argsort(perm, axis=1)
    kp2 = jnp.take_along_axis(kp, inv[:, :, None, None, None], axis=1)
    vp2 = jnp.take_along_axis(vp, inv[:, :, None, None, None], axis=1)
    o1 = paged_attention_ref(q, kp, vp, ident, lens)
    o2 = paged_attention_ref(q, kp2, vp2, perm, lens)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
