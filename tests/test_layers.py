"""Layer-level correctness: rwkv batched==scan, mamba chunk sizes, MoE
capacity behavior, chunked xent vs dense."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.configs.base import MoEConfig
from repro.models import forward_train, init_params
from repro.models.dist import NO_MESH
from repro.models.layers import chunked_xent, embedding_specs, logits_fn
from repro.models.params import materialize


def test_rwkv_batched_equals_scan(key):
    cfg = reduce_for_smoke(get_config("rwkv6-3b"))
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 128), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 128), 0, cfg.vocab_size)}
    l1 = forward_train(cfg, params, batch)
    l2 = forward_train(dataclasses.replace(cfg, unroll_scans=True),
                       params, batch)
    assert abs(float(l1 - l2)) < 1e-4


def test_mamba_chunk_invariance(key):
    """jamba loss must not depend on the ssm chunk size (associative scan)."""
    from repro.models.mamba import mamba_mix, mamba_specs, MambaState
    cfg = reduce_for_smoke(get_config("jamba-1.5-large-398b"))
    p = materialize(mamba_specs(cfg), key)
    x = jax.random.normal(key, (2, 64, cfg.d_model)) * 0.1
    st = MambaState(
        conv=jnp.zeros((2, cfg.ssm.d_conv - 1, cfg.ssm.expand * cfg.d_model)),
        ssm=jnp.zeros((2, cfg.ssm.expand * cfg.d_model, cfg.ssm.d_state)))
    y1, s1 = mamba_mix(p, x, cfg, NO_MESH, st, chunk=8)
    y2, s2 = mamba_mix(p, x, cfg, NO_MESH, st, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1.ssm), np.asarray(s2.ssm),
                               atol=1e-4, rtol=1e-3)


def test_moe_high_capacity_matches_dense_mixture(key):
    """With capacity_factor -> inf and top-k == n_experts the MoE output must
    equal the softmax-weighted dense mixture of experts."""
    from repro.models.moe import moe_ffn, moe_specs
    from repro.models.layers import glu_mlp
    cfg = reduce_for_smoke(get_config("olmoe-1b-7b"))
    cfg = dataclasses.replace(cfg, moe=MoEConfig(
        n_experts=4, experts_per_token=4, d_ff=32, capacity_factor=64.0))
    p = materialize(moe_specs(cfg), key)
    x = jax.random.normal(key, (2, 8, cfg.d_model)) * 0.5
    out = moe_ffn(p, x, cfg, NO_MESH)
    logits = x.astype(jnp.float32) @ p["router"]
    w = jax.nn.softmax(logits, -1)
    dense = 0.0
    for e in range(4):
        pe = {"w_gate": p["w_gate"][e], "w_up": p["w_up"][e],
              "w_down": p["w_down"][e]}
        dense = dense + w[..., e:e + 1].astype(x.dtype) * glu_mlp(pe, x, cfg.act)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(dense, np.float32),
                               atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_tokens(key):
    from repro.models.moe import moe_ffn, moe_specs
    cfg = reduce_for_smoke(get_config("olmoe-1b-7b"))
    tight = dataclasses.replace(cfg.moe, capacity_factor=0.25)
    cfg2 = dataclasses.replace(cfg, moe=tight)
    p = materialize(moe_specs(cfg2), key)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    out = moe_ffn(p, x, cfg2, NO_MESH)
    assert jnp.all(jnp.isfinite(out))    # dropped tokens -> shared/zero path


def test_chunked_xent_matches_dense(key):
    V, d, B, S = 128, 16, 2, 32
    espec = embedding_specs(V, d, jnp.float32, tie=True)
    ep = materialize(espec, key)
    x = jax.random.normal(key, (B, S, d))
    labels = jax.random.randint(key, (B, S), 0, V)
    loss = chunked_xent(ep, x, labels, None, n_chunks=4)
    logits = logits_fn(ep, x, None).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    dense = jnp.mean(lse - gold)
    assert abs(float(loss - dense)) < 1e-5
