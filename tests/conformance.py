"""Shared serving-conformance harness (NOT a test module).

Every scheduling/disaggregation/tenancy feature in this repo carries the
same contract: it may change WHEN work runs and HOW translation is
accounted, but never the tokens. The suites that pin that contract
(tests/test_scheduler.py, tests/test_disagg.py, tests/test_range_tlb.py,
tests/test_conformance.py, tests/test_multitenant.py) all drive engines
over the same pressure workload and compare outputs bit-for-bit — this
module is the single home for that machinery:

  Workload            prompts x max_tokens x arrival ticks x per-request
                      tenants, as one immutable value
  pressure_workload   the verified oversubscribed mix (mixed lengths,
                      POOL=8 pages forces preempt/resume on continuous
                      engines while the fixed engine waits)
  prefix_workload     the shared-system-prompt mix (CoW + prefix paths)
  make_engine         one constructor for every engine kind:
                      fixed | continuous | disagg-share | disagg-copy
  drive               arrival-faithful driver (requests injected between
                      steps at their tick; the engine never sees the
                      future), tenant-aware
  serve               make_engine + drive in one call
  assert_bit_identical  THE conformance assertion: two engines, one
                      workload, outputs must match token-for-token
"""
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.serving.disagg import DisaggEngine
from repro.core.serving.engine import ServingEngine

# The verified pressure workload: mixed lengths, tight pool -> the
# continuous engine preempts and resumes while the fixed engine waits.
LENS = (11, 23, 5, 17, 9, 13)
MAXTOKS = (10, 8, 12, 9, 11, 10)
POOL = 8

# Arrival interleavings every bit-identity suite parameterizes over.
ARRIVAL_CASES = [
    [0, 0, 0, 0, 0, 0],            # one burst
    [0, 0, 0, 5, 5, 5],            # two bursts
    [0, 1, 2, 3, 4, 5],            # steady trickle
    [0, 0, 9, 9, 0, 4],            # stragglers mid-serve
]

ENGINE_KINDS = ("fixed", "continuous", "disagg-share", "disagg-copy")


@dataclass(frozen=True)
class Workload:
    """One driveable workload. ``arrivals`` (per-request step ticks) are
    injected between steps; None submits everything up front.
    ``tenants`` names each request's TenantDomain (None = untenanted)."""
    prompts: Tuple[tuple, ...]
    maxtoks: Tuple[int, ...]
    arrivals: Optional[Tuple[int, ...]] = None
    tenants: Optional[Tuple[Optional[str], ...]] = None

    def __post_init__(self):
        n = len(self.prompts)
        for field_name in ("maxtoks", "arrivals", "tenants"):
            v = getattr(self, field_name)
            if v is not None and len(v) != n:
                raise ValueError(f"{field_name} has {len(v)} entries for "
                                 f"{n} prompts")

    def tenant_of(self, i: int) -> Optional[str]:
        return self.tenants[i] if self.tenants is not None else None


def pressure_workload(vocab: int, n: int = 6, seed: int = 3,
                      arrivals=None, tenants=None) -> Workload:
    """The canonical oversubscribed mix (LENS/MAXTOKS at POOL pages)."""
    rng = np.random.default_rng(seed)
    prompts = tuple(tuple(rng.integers(0, vocab, size=k).tolist())
                    for k in LENS[:n])
    return Workload(prompts, tuple(MAXTOKS[:n]),
                    arrivals=tuple(arrivals) if arrivals is not None
                    else None,
                    tenants=tuple(tenants) if tenants is not None else None)


def prefix_workload(vocab: int, n: int = 6, max_tokens: int = 6,
                    seed: int = 7) -> Workload:
    """Shared-system-prompt mix: most requests extend one common prefix
    (prefix sharing + CoW divergence), every third is unrelated."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, size=24).tolist()
    prompts = []
    for i in range(n):
        if i % 3 == 2:
            prompts.append(tuple(rng.integers(0, vocab, size=10).tolist()))
        else:
            prompts.append(tuple(system
                                 + rng.integers(0, vocab, size=5).tolist()))
    return Workload(tuple(prompts), (max_tokens,) * n)


def make_engine(cfg, params, kind: str, n_slots: int = 4, max_len: int = 64,
                page_size: int = 8, tenants: Optional[Dict[str, dict]] = None,
                **engine_kw):
    """One constructor for every engine kind (see ENGINE_KINDS).
    ``disagg-*`` splits ``n_slots`` evenly into prefill/decode workers so
    every kind serves at equal total slot width."""
    if kind not in ENGINE_KINDS:
        raise ValueError(f"kind={kind!r} (expected one of {ENGINE_KINDS})")
    if kind.startswith("disagg-"):
        return DisaggEngine(cfg, params, n_prefill_slots=n_slots // 2,
                            n_decode_slots=n_slots - n_slots // 2,
                            max_len=max_len, page_size=page_size,
                            disagg_mode=kind.split("-", 1)[1],
                            tenants=tenants, **engine_kw)
    return ServingEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                         page_size=page_size, scheduler=kind,
                         tenants=tenants, **engine_kw)


def drive(eng, workload: Workload):
    """Run one engine over the workload, arrival-faithfully. Returns
    (per-request output tokens, finished {req_id: Request})."""
    wl = workload
    finished = {}
    if wl.arrivals is None:
        rids = [eng.submit(list(p), max_tokens=m, tenant=wl.tenant_of(j))
                for j, (p, m) in enumerate(zip(wl.prompts, wl.maxtoks))]
        finished = eng.run()
    else:
        rids = [None] * len(wl.prompts)
        order = sorted(range(len(wl.prompts)), key=lambda j: wl.arrivals[j])
        i, clock = 0, 0
        while i < len(order) or eng.has_work:
            while i < len(order) and wl.arrivals[order[i]] <= clock:
                j = order[i]
                rids[j] = eng.submit(list(wl.prompts[j]),
                                     max_tokens=wl.maxtoks[j],
                                     tenant=wl.tenant_of(j))
                i += 1
            if eng.has_work:
                eng.step(finished)
            clock += 1
    return [finished[r].out_tokens for r in rids], finished


def serve(cfg, params, kind: str, workload: Workload, **engine_kw):
    """make_engine + drive. Returns (outputs, engine, finished)."""
    eng = make_engine(cfg, params, kind, **engine_kw)
    outs, finished = drive(eng, workload)
    return outs, eng, finished


def assert_bit_identical(engine_a, engine_b, workload: Workload) -> None:
    """Drive two FRESH engines over the same workload and require
    token-for-token identical outputs — the conformance contract every
    scheduling/tenancy/translation feature must satisfy."""
    outs_a, _ = drive(engine_a, workload)
    outs_b, _ = drive(engine_b, workload)
    assert outs_a == outs_b, (
        f"outputs diverged: {type(engine_a).__name__} vs "
        f"{type(engine_b).__name__} on {len(workload.prompts)} requests "
        f"(first mismatch at request "
        f"{next(i for i, (a, b) in enumerate(zip(outs_a, outs_b)) if a != b)})")
