"""Optimizer, grad clipping, int8 error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig
from repro.optim import (adamw_update, clip_by_global_norm, init_opt_state,
                         lr_schedule)
from repro.optim.compression import ef_compress, init_ef


def test_adamw_converges_on_quadratic(key):
    target = jax.random.normal(key, (16,))
    params = {"w": jnp.zeros(16)}
    tc = TrainConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                     total_steps=400)
    opt = init_opt_state(params)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        g, _ = clip_by_global_norm(g, 100.0)
        params, opt, _ = adamw_update(params, g, opt, tc)
    assert float(loss_fn(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(250.0)) < 1e-4
    total = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped))
    assert abs(total - 1.0) < 1e-4


def test_lr_schedule_shape():
    tc = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(tc, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] < 1e-5                      # cosine decayed


def test_ef_compression_error_feedback_unbiased(key):
    """Over repeated identical gradients, the accumulated compressed sum
    approaches the true sum (error feedback kills the bias)."""
    g = {"w": jax.random.normal(key, (64,)) * 0.1}
    ef = init_ef(g)
    acc = jnp.zeros(64)
    n = 50
    for _ in range(n):
        cg, ef = ef_compress(g, ef)
        acc = acc + cg["w"]
    rel = float(jnp.max(jnp.abs(acc - n * g["w"]))) / float(
        jnp.max(jnp.abs(n * g["w"])))
    assert rel < 0.02, rel


def test_ef_compression_single_step_is_quantized(key):
    g = {"w": jax.random.normal(key, (64,))}
    cg, ef = ef_compress(g, init_ef(g))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    lev = np.asarray(cg["w"]) / scale
    np.testing.assert_allclose(lev, np.round(lev), atol=1e-4)
