"""Flash attention (pure-JAX custom-VJP) vs dense oracle: fwd + grad."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import flash_attention


def naive(q, k, v, causal=True, window=None, softcap=None, q_offset=0):
    Sq, Skv, Hq, Hkv = q.shape[1], k.shape[1], q.shape[2], k.shape[2]
    D = q.shape[-1]
    G = Hq // Hkv
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bchd->bqhc", q, k).astype(jnp.float32) * D ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq) + q_offset
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp[None] <= qp[:, None]
    if window:
        mask &= qp[:, None] - kp[None] < window
    s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqhc,bchd->bqhd", p.astype(v.dtype), v)


CASES = [
    dict(Sq=64, Skv=64, Hq=4, Hkv=2, causal=True, window=None, softcap=None, off=0),
    dict(Sq=64, Skv=64, Hq=4, Hkv=4, causal=False, window=None, softcap=None, off=0),
    dict(Sq=128, Skv=128, Hq=8, Hkv=2, causal=True, window=16, softcap=None, off=0),
    dict(Sq=64, Skv=64, Hq=4, Hkv=2, causal=True, window=None, softcap=30.0, off=0),
    dict(Sq=32, Skv=96, Hq=4, Hkv=2, causal=True, window=None, softcap=None, off=64),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_fwd_and_grad(case, key):
    B, D = 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, case["Sq"], case["Hq"], D))
    k = jax.random.normal(ks[1], (B, case["Skv"], case["Hkv"], D))
    v = jax.random.normal(ks[2], (B, case["Skv"], case["Hkv"], D))
    kw = dict(causal=case["causal"], window=case["window"],
              softcap=case["softcap"], q_offset=case["off"],
              kv_chunk=32, q_chunk=16)

    f = lambda q, k, v: jnp.sum(jnp.sin(
        flash_attention(q, k, v, **kw).astype(jnp.float32)))
    g = lambda q, k, v: jnp.sum(jnp.sin(
        naive(q, k, v, case["causal"], case["window"], case["softcap"],
              case["off"]).astype(jnp.float32)))
    assert abs(float(f(q, k, v) - g(q, k, v))) < 1e-3
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype, key):
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    out = flash_attention(q, k, v, kv_chunk=16, q_chunk=16)
    ref = naive(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref))) < tol
