"""Paper-reproduction acceptance: Table II + Figs 2/3/5 claims within
documented tolerances (EXPERIMENTS.md §Paper-validation)."""
import numpy as np
import pytest

from repro.core.simulator.paper_targets import CLAIMS, TABLE2
from repro.core.simulator.run import (host_copy_cycles, host_map_cycles,
                                      offload_breakdown, simulate_kernel)

LATS = (200, 600, 1000)


def test_table2_reproduction():
    errs = []
    for k, tgt in TABLE2.items():
        for cfg in ("baseline", "iommu", "iommu_llc"):
            for lat in LATS:
                sim = simulate_kernel(k, cfg, lat).total
                errs.append(abs(sim - tgt[cfg][lat]) / tgt[cfg][lat])
    assert np.mean(errs) < 0.02, f"mean err {np.mean(errs):.3f}"
    assert max(errs) < 0.06, f"max err {max(errs):.3f}"


def test_dma_pct_reproduction():
    for k, tgt in TABLE2.items():
        for lat in LATS:
            sim = simulate_kernel(k, "baseline", lat).dma_pct
            assert abs(sim - tgt["dma_pct"][lat]) < 3.0, (k, lat, sim)


def test_gemm_overhead_claims():
    low = simulate_kernel("gemm", "iommu", 200).total \
        / simulate_kernel("gemm", "baseline", 200).total - 1
    high = simulate_kernel("gemm", "iommu", 1000).total \
        / simulate_kernel("gemm", "baseline", 1000).total - 1
    assert abs(100 * low - CLAIMS["gemm_overhead_low_pct"]) < 1.5
    assert abs(100 * high - CLAIMS["gemm_overhead_high_pct"]) < 3.0


def test_llc_overhead_small():
    for k in TABLE2:
        for lat in LATS:
            ratio = simulate_kernel(k, "iommu_llc", lat).total \
                / simulate_kernel(k, "baseline", lat).total
            assert ratio - 1 < 0.04, (k, lat, ratio)   # paper <2%; we bound 4%


def test_fig5_ptw_claims():
    no_llc = [simulate_kernel("axpy", "iommu", l).avg_ptw_host_cycles
              for l in LATS]
    llc = [simulate_kernel("axpy", "iommu_llc", l).avg_ptw_host_cycles
           for l in LATS]
    speedup = np.mean(no_llc) / np.mean(llc)
    assert 10 < speedup < 30          # paper: 15x average
    assert max(llc) <= CLAIMS["ptw_llc_max_cycles"]
    intf = [simulate_kernel("axpy", "iommu_llc", l,
                            host_interference=0.028).avg_ptw_host_cycles
            for l in LATS]
    slow = np.mean(intf) / np.mean(llc) - 1
    assert 0.1 < slow < 0.35          # paper: ~20%


def test_fig3_ratios():
    nb = 3 * 32768 * 4
    cr = host_copy_cycles(nb, 1000) / host_copy_cycles(nb, 200)
    mr = host_map_cycles(nb, 1000) / host_map_cycles(nb, 200)
    assert abs(cr - CLAIMS["copy_time_ratio_1000_200"]) < 0.2
    assert abs(mr - CLAIMS["map_time_ratio_1000_200"]) < 0.2


def test_fig2_zero_copy_speedup():
    cb = offload_breakdown("copy", 32768, 200).total
    zb = offload_breakdown("zero_copy", 32768, 200).total
    hb = offload_breakdown("host", 32768, 200).total
    speedup = 100 * (1 - zb / cb)
    assert abs(speedup - CLAIMS["zero_copy_speedup_pct"]) < 4.0
    assert cb > hb                    # copy-based offload beats host? NO (paper §IV-A)
    assert zb < hb                    # zero-copy wins outright
