"""Multi-tenant serving domains: ASID isolation, way-partitioned IOTLB,
tenant quotas, deployment descriptions, and scenario determinism.

The core property (hypothesis-randomized when hypothesis is installed,
fixed cases always): NO interleaving of admit / append / CoW / migrate /
release across two tenants ever translates a foreign page — the IOMMU's
isolation gate refuses cross-tenant and anonymous access to owned ASIDs,
and the translation sanitizer's independent shadow check
(cross-tenant-translate) watches the whole run. Manager-level tests are
jax-free; CI runs this file under ``REPRO_SVASAN=1`` (the manager tests
force ``sanitize=True`` regardless, so the property holds outside CI
too)."""
import dataclasses

import numpy as np
import pytest

from benchmarks.scenarios import (SCENARIO_KINDS, generate,
                                  trace_fingerprint)
from repro.configs import get_config, reduce_for_smoke
from repro.configs.deployment import (DeploymentConfig, TenantSpec,
                                      two_tenant_demo)
from repro.core.sva.iommu import (IOMMU, CountingWalk, IsolationError,
                                  TLBConfig)
from repro.core.sva.kv_manager import CapacityError, PagedKVManager
from repro.models import init_params
from tests.conformance import Workload, pressure_workload, serve

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def setup():
    import jax
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    return cfg, init_params(cfg, jax.random.key(0))


def _mgr(**kw):
    kw.setdefault("tenants", {"a": {}, "b": {}})
    return PagedKVManager(n_slots=4, max_pages_per_slot=4, page_size=8,
                          kv_bytes_per_token=256, sanitize=True, **kw)


# ------------------------------------------------------- isolation basics

def test_isolation_error_is_structured():
    mgr = _mgr()
    st_a = mgr.admit(1, prompt_len=10, max_tokens=4,
                     tokens=list(range(10)), lazy=True, tenant="a")
    with pytest.raises(IsolationError) as ei:
        mgr.iommu.translate(st_a.slot, 0, tenant="b")
    e = ei.value
    assert (e.tenant, e.owner, e.asid, e.page) == ("b", "a", st_a.slot, 0)
    assert isinstance(e, PermissionError)
    # anonymous access to an owned ASID is refused too
    with pytest.raises(IsolationError) as ei:
        mgr.iommu.translate(st_a.slot, 0)
    assert ei.value.tenant is None and ei.value.owner == "a"
    # denials are charged to the REQUESTING domain
    assert mgr.iommu._tenants["b"].denials == 1
    assert mgr.iommu._tenants["a"].denials == 0


def test_attach_unknown_tenant_rejected():
    mgr = _mgr()
    with pytest.raises(ValueError):
        mgr.admit(1, prompt_len=8, max_tokens=2, tokens=list(range(8)),
                  lazy=True, tenant="zeta")
    iommu = IOMMU(walk_model=CountingWalk())
    iommu.register_tenant("a")
    with pytest.raises(ValueError):
        iommu.attach(0, tenant="nope")


def test_quota_ensure_fits_rejects_unservable():
    """A request needing more pages than the tenant's quota can NEVER run
    — rejected at submit, not queued forever."""
    mgr = _mgr(tenants={"a": {"quota_pages": 2}, "b": {}})
    with pytest.raises(CapacityError):
        mgr.ensure_fits(prompt_len=20, max_tokens=8, tenant="a")  # 4 pages
    mgr.ensure_fits(prompt_len=20, max_tokens=8, tenant="b")      # no quota
    mgr.ensure_fits(prompt_len=8, max_tokens=4, tenant="a")       # 2 pages


def test_total_refs_reconciles_with_seq_pages():
    """pool.total_refs() is the gauge quotas meter against: with prefix
    sharing off it equals the sum of live sequences' page mappings, and
    returns to zero after release."""
    mgr = _mgr(prefix_sharing=False)
    mgr.admit(1, prompt_len=16, max_tokens=2, tokens=list(range(16)),
              lazy=True, tenant="a")
    mgr.admit(2, prompt_len=8, max_tokens=2, tokens=list(range(8)),
              lazy=True, tenant="b")
    assert mgr.pool.total_refs() == sum(len(s.pages)
                                        for s in mgr.seqs.values()) == 3
    assert mgr.tenant_pages_used("a") == 2
    assert mgr.tenant_pages_used("b") == 1
    mgr.release(1)
    mgr.release(2)
    assert mgr.pool.total_refs() == 0


# ----------------------------------------- the isolation property machine

def _run_tenant_ops(ops):
    """Interpret a list of (op, k) codes as a two-tenant admit / append /
    CoW(shared-prefix admit) / migrate / release interleaving; after every
    op, every live mapping must translate ONLY under its owner and refuse
    the other tenant — sanitizer watching throughout."""
    from repro.core.sva.page_pool import OutOfPages
    mgr = _mgr()
    next_id, live = 1, []
    common = list(range(12))                     # shared-prefix bait (CoW)
    for op, k in ops:
        try:
            if op == 0 and len(live) < 3:        # admit (alternating tenant)
                t = "ab"[next_id % 2]
                tokens = common + [100 + next_id] if k % 2 else \
                    list(range(20 + next_id, 30 + next_id))
                s = mgr.admit(next_id, prompt_len=len(tokens), max_tokens=4,
                              tokens=tokens, lazy=True, tenant=t)
                if s is not None:
                    live.append(next_id)
                next_id += 1
            elif op == 1 and live:               # append (CoW on shared)
                mgr.append_token(live[k % len(live)], 7)
            elif op == 2 and live:               # migrate to a free slot
                sid = live[k % len(live)]
                used = {s.slot for s in mgr.seqs.values()}
                free = [s for s in range(4) if s not in used]
                if free:
                    mgr.reserve_slots([free[0]])
                    mgr.migrate(sid, free[0],
                                mode="share" if k % 2 else "copy")
                    mgr.pending_cow.clear()      # engine-side copy queue
            elif op == 3 and live:               # release
                mgr.release(live.pop(k % len(live)))
        except OutOfPages:
            pass                                 # transient; invariants hold
        # invariant: every live mapping translates under its owner only
        mgr.translate_step()
        for sid in live:
            s = mgr.seqs[sid]
            owner = mgr.iommu._asid_tenant.get(s.slot)
            assert owner == s.tenant
            if s.pages:
                other = "b" if s.tenant == "a" else "a"
                phys, _, _ = mgr.iommu.translate(s.slot, 0,
                                                 tenant=s.tenant)
                assert phys == s.pages[0]
                with pytest.raises(IsolationError):
                    mgr.iommu.translate(s.slot, 0, tenant=other)
    assert mgr.sanitizer.stats()["reports"] == 0
    assert mgr.sanitizer.stats()["checks"] > 0


FIXED_OP_CASES = [
    [(0, 1), (0, 1), (1, 0), (1, 1), (3, 0), (3, 0)],          # CoW pair
    [(0, 0), (0, 1), (2, 0), (1, 0), (2, 1), (3, 1), (3, 0)],  # migrations
    [(0, 1), (1, 0), (0, 1), (1, 1), (2, 0), (3, 0), (0, 0), (3, 0)],
]


@pytest.mark.parametrize("ops", FIXED_OP_CASES)
def test_isolation_interleavings_fixed(ops):
    _run_tenant_ops(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)),
                    min_size=1, max_size=12))
    def test_isolation_interleaving_property(ops):
        """NO admit/append/CoW/migrate/release interleaving across two
        tenants ever translates a foreign page."""
        _run_tenant_ops(ops)


# ------------------------------------------------- way-partition bounds

def test_partition_occupancy_bounds():
    """A partitioned tenant's resident entries never exceed its way
    budget in any set, no matter how hard it thrashes — and the victim
    tenant's working set survives the noisy neighbor."""
    tlb = TLBConfig(8, "lru", ways=4, partitions={"a": 2, "b": 1})
    iommu = IOMMU(walk_model=CountingWalk(), tlb=tlb)
    iommu.register_tenant("a")
    iommu.register_tenant("b")
    iommu.attach(0, tenant="a")
    iommu.attach(1, tenant="b")
    for lp in range(2):                          # b's tiny working set
        iommu.translate(1, lp, phys=lp, tenant="b")
    for lp in range(64):                         # a thrashes
        iommu.translate(0, lp, phys=lp, tenant="a")
    occ = iommu.tlb.partition_occupancy()
    for si in range(iommu.tlb.n_sets):
        assert occ["a"][si] <= 2
        assert occ["b"][si] <= 1
        assert occ[None][si] <= 4 - 2 - 1        # leftover shared ways
    # b's most-recent entry outlived a's 64-page sweep
    _, _, hit = iommu.translate(1, 1, phys=1, tenant="b")
    assert hit
    ts = iommu.tlb.tenant_stats["a"].as_dict()
    assert ts["conflict_misses"] > 0             # budget-bound, not capacity


def test_partition_validation():
    with pytest.raises(ValueError):              # reserves more than ways
        TLBConfig(8, "lru", ways=4, partitions={"a": 3, "b": 2})
    with pytest.raises(ValueError):              # needs set-associativity
        PagedKVManager(n_slots=2, max_pages_per_slot=4, page_size=8,
                       tenants={"a": {"tlb_ways": 2}})
    cfgs = [TLBConfig(8, "lru", ways=4, partitions={"a": 2}),
            TLBConfig(8, "lru", ways=4, partitions=(("a", 2),))]
    assert cfgs[0] == cfgs[1] and hash(cfgs[0]) == hash(cfgs[1])


# --------------------------------------------- quota-pressure preemption

def test_quota_preemption_bit_identical(setup):
    """Pool is AMPLE but tenant a's quota is tight: decode growth pushes a
    over quota, the scheduler sheds a's newest sequence (sparing the
    oldest — no thrash), and outputs still match the unconstrained fixed
    engine token-for-token."""
    cfg, params = setup
    base = pressure_workload(cfg.vocab_size)
    prompts, maxtoks = base.prompts[:4], (10, 10, 8, 8)
    ref, _, _ = serve(cfg, params, "fixed", Workload(prompts, maxtoks))
    outs, eng, _ = serve(cfg, params, "continuous",
                         Workload(prompts, maxtoks,
                                  tenants=("a", "a", "b", "b")),
                         tenants={"a": {"quota_pages": 5}, "b": {}})
    s = eng.stats()
    assert outs == ref
    assert s["sched"]["preemptions"] >= 1
    assert s["sched"]["resumes"] >= 1
    assert s["tenant"]["a"]["quota_pages"] == 5
    assert s["tenant"]["a"]["denials"] == 0      # pressure, not isolation


# ------------------------------------------------ deployment descriptions

def test_deployment_validation_errors():
    with pytest.raises(ValueError, match="non-empty string"):
        TenantSpec("")
    with pytest.raises(ValueError, match="pool_share"):
        TenantSpec("a", pool_share=1.5)
    with pytest.raises(ValueError, match="tlb_ways"):
        TenantSpec("a", tlb_ways=-1)
    with pytest.raises(ValueError, match="at least one tenant"):
        DeploymentConfig(())
    with pytest.raises(ValueError, match="duplicate tenant names"):
        DeploymentConfig((TenantSpec("a"), TenantSpec("a")))
    with pytest.raises(ValueError, match="over-committed"):
        DeploymentConfig((TenantSpec("a", pool_share=0.7),
                          TenantSpec("b", pool_share=0.7)))
    with pytest.raises(ValueError, match="prefix_shares"):
        DeploymentConfig((TenantSpec("a", prefix_share=0.8),
                          TenantSpec("b", prefix_share=0.8)))
    with pytest.raises(ValueError, match="reserve 3 ways"):
        DeploymentConfig((TenantSpec("a", tlb_ways=2),
                          TenantSpec("b", tlb_ways=1)), tlb_ways=2)
    with pytest.raises(ValueError, match="mutually exclusive"):
        DeploymentConfig((TenantSpec("a", tlb_ways=1),),
                         autotune_interval=64)


def test_deployment_compile_and_quotas():
    base = reduce_for_smoke(get_config("llama3.2-1b"))
    dep = two_tenant_demo(partitioned=True, ways=4)
    cfg = dep.compile(base)
    assert cfg.serve_tlb_ways == 4
    td = dep.tenant_dict(16)
    assert td == {"a": {"quota_pages": 8, "tlb_ways": 2},
                  "b": {"quota_pages": 4, "tlb_ways": 1}}
    assert dep.names == ("a", "b")
    # a nonzero share always grants at least one page
    tiny = DeploymentConfig((TenantSpec("a", pool_share=0.01),))
    assert tiny.tenant_dict(8)["a"]["quota_pages"] == 1
    with pytest.raises(ValueError, match="pool_pages"):
        dep.tenant_dict(0)
    # compile-time errors need the resolved geometry
    with pytest.raises(ValueError, match="set-associative"):
        DeploymentConfig((TenantSpec("a", tlb_ways=2),)).compile(base)
    auto = dataclasses.replace(base, serve_tlb_ways=4,
                               serve_tlb_autotune=64)
    with pytest.raises(ValueError, match="mutually exclusive"):
        DeploymentConfig((TenantSpec("a", tlb_ways=2),)).compile(auto)


# --------------------------------------------------- scenario determinism

GOLDEN_FINGERPRINTS = {
    "bursty_tenants": "5262511097938705",
    "conversation_trees": "4c4a9606a15e2e88",
    "adversarial_prefix_collisions": "b26344952cfe8d65",
}


@pytest.mark.parametrize("kind", SCENARIO_KINDS)
def test_scenario_seed_determinism(kind):
    """Same (kind, tenants, vocab, n_req, seed) -> byte-identical trace:
    the A/B arms of paged_serving --tenants replay the exact workload, and
    these goldens pin the generator against silent drift."""
    a = generate(kind, ("a", "b"), vocab=256, n_req=12, seed=0)
    b = generate(kind, ("a", "b"), vocab=256, n_req=12, seed=0)
    assert a == b
    assert trace_fingerprint(a) == GOLDEN_FINGERPRINTS[kind]
    assert trace_fingerprint(
        generate(kind, ("a", "b"), vocab=256, n_req=12, seed=1)) \
        != GOLDEN_FINGERPRINTS[kind]
    assert all(r.tenant in ("a", "b") for r in a)
    assert sorted(set(r.tenant for r in a)) == ["a", "b"]
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals)          # merged by arrival tick


def test_scenario_generator_validation():
    with pytest.raises(ValueError, match="unknown scenario kind"):
        generate("flash_crowd", ("a",), vocab=64)
    with pytest.raises(ValueError, match="at least one tenant"):
        generate("bursty_tenants", (), vocab=64)


def test_collision_scenario_is_adversarial():
    """The adversarial trace really does submit byte-identical prompts
    under different tenants — the cross-tenant prefix-sharing bait."""
    reqs = generate("adversarial_prefix_collisions", ("a", "b"),
                    vocab=256, n_req=9, seed=7)
    by_prompt = {}
    for r in reqs:
        by_prompt.setdefault(r.prompt, set()).add(r.tenant)
    assert any(len(ts) > 1 for ts in by_prompt.values())
