"""SVA layer + continuous-batching engine correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core.sva.kv_manager import PagedKVManager
from repro.core.sva.mapping import SVASpace
from repro.core.sva.page_pool import PagePool
from repro.core.serving.engine import ServingEngine
from repro.models import (forward_decode, forward_prefill, init_cache,
                          init_params)


def test_mapping_zero_copy_vs_copy_costs():
    space = SVASpace(PagePool(128, 4096))
    m = space.map(16 * 4096)
    assert space.stats.bytes_copied == 0
    assert space.stats.table_entries_written == 16
    space.unmap(m)
    m2 = space.stage(16 * 4096)
    assert space.stats.bytes_copied == 16 * 4096   # the staging copy


def test_mapping_prefix_sharing():
    space = SVASpace(PagePool(64, 4096))
    a = space.map(8 * 4096)
    b = space.map(8 * 4096, share_prefix_from=a, prefix_pages=4)
    assert b.pages[:4] == a.pages[:4]
    assert space.pool.n_used == 12                 # 8 + 4 fresh
    space.unmap(a)
    assert space.pool.refcount(b.pages[0]) == 1    # prefix survives
    space.unmap(b)
    assert space.pool.n_used == 0


def test_kv_manager_global_pool_shared_across_slots():
    """Default layout: ONE pool shared by every slot; unallocated table
    entries hold the NULL sentinel."""
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=8, page_size=4)
    assert mgr.layout == "global" and mgr.pool.n_pages == 16
    a = mgr.admit(0, prompt_len=10, max_tokens=6)       # 4 pages
    b = mgr.admit(1, prompt_len=10, max_tokens=6)
    assert a is not None and b is not None
    rows = mgr.tables
    used = rows[a.slot][:4].tolist() + rows[b.slot][:4].tolist()
    assert sorted(used) == sorted(set(used)), "slots share one page space"
    assert all(p < 16 for p in used)
    assert (rows[a.slot][4:] == mgr.null_page).all()    # unmapped == NULL
    assert mgr.pool.n_used == 8
    mgr.release(0)
    assert (mgr.tables[a.slot] == mgr.null_page).all()
    assert mgr.pool.n_used == 4
    mgr.release(1)
    assert mgr.pool.n_used == 0 and len(mgr.free_slots) == 2


def test_kv_manager_per_slot_tables_are_permutations():
    """Copy-baseline layout keeps the per-slot permutation invariant."""
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=8, page_size=4,
                         offload_mode="copy")
    assert mgr.layout == "per_slot"
    st = mgr.admit(0, prompt_len=10, max_tokens=6)
    assert st is not None
    assert sorted(mgr.tables[st.slot].tolist()) == list(range(8))
    for i in range(6):
        mgr.append_token(0, i)
    assert sorted(mgr.tables[st.slot].tolist()) == list(range(8))
    mgr.release(0)
    assert mgr.free_slots and mgr.pools[st.slot].n_free == 8


def test_kv_manager_delta_rows_and_epoch():
    mgr = PagedKVManager(n_slots=4, max_pages_per_slot=4, page_size=4)
    mgr.delta_rows()                                    # drain initial dirt
    assert mgr.delta_rows() == []
    st = mgr.admit(0, prompt_len=4, max_tokens=4)
    assert mgr.delta_rows() == [st.slot]                # only the new row
    assert mgr.delta_rows() == []                       # nothing changed
    epoch = mgr.epoch
    mgr.invalidate_epoch()
    assert mgr.epoch == epoch + 1
    assert mgr.delta_rows() == [0, 1, 2, 3]             # full re-upload due


def _engine_outputs(mode, cfg, params, prompts, n=6):
    eng = ServingEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                        offload_mode=mode)
    rids = [eng.submit(p, max_tokens=n) for p in prompts]
    done = eng.run()
    return [done[r].out_tokens for r in rids], eng.stats()


def test_engine_matches_manual_loop(key):
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[5, 9, 2, 14], [100, 7], [3, 3, 3, 8, 1, 30], [42]]

    def manual(prompt, n=6):
        cache = init_cache(cfg, 1, max_len=64, page_size=8, per_seq=True)
        lg, cache = forward_prefill(
            cfg, params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
        toks = [int(jnp.argmax(lg[0, -1]))]
        pos = len(prompt)
        for _ in range(n - 1):
            lg, cache = forward_decode(
                cfg, params, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), cache)
            toks.append(int(jnp.argmax(lg[0, 0])))
            pos += 1
        return toks

    expected = [manual(p) for p in prompts]
    got, stats = _engine_outputs("zero_copy", cfg, params, prompts)
    assert got == expected
    assert stats["sva"]["bytes_copied"] == 0


def test_engine_copy_mode_same_tokens_more_copies(key):
    """copy-based admission produces identical TOKENS but pays staging."""
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[11, 4, 9], [87, 23, 1, 5]]
    zc, zc_stats = _engine_outputs("zero_copy", cfg, params, prompts)
    cp, cp_stats = _engine_outputs("copy", cfg, params, prompts)
    assert zc == cp
    assert cp_stats["staging_copies"] > 0
    assert cp_stats["sva"]["bytes_copied"] > 0
    assert zc_stats["staging_copies"] == 0


def test_engine_queueing_more_requests_than_slots(key):
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[i + 1, i + 2] for i in range(7)]   # 7 requests, 3 slots
    got, stats = _engine_outputs("zero_copy", cfg, params, prompts, n=4)
    assert len(got) == 7
    assert all(len(t) == 4 for t in got)
    assert stats["sva"]["unmap_calls"] == 7        # every seq released


def test_engine_zero_copy_no_admission_materialization(key):
    """Acceptance: zero_copy admission moves table entries (int32 per page),
    never KV bytes — no staging copies, no per-request cache; decode uses
    delta table uploads with a full upload only for the initial epoch."""
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[5, 9, 2, 14], [100, 7], [3, 3, 3, 8, 1, 30], [42]]
    _, s = _engine_outputs("zero_copy", cfg, params, prompts)
    assert s["staging_copies"] == 0
    assert s["sva"]["bytes_copied"] == 0
    assert s["table_uploads_full"] == 1            # initial epoch sync only
    assert s["table_uploads_delta"] >= 1
    # admission bytes: int32 table entries, not KV. Compare against what the
    # copy baseline would have staged for the same prompts.
    kv_bytes_staged = sum(len(p) for p in prompts) * 2 * cfg.n_kv_heads \
        * cfg.d_head * cfg.n_layers
    assert s["admit_table_bytes"] < kv_bytes_staged
    assert s["sva"]["table_entries_written"] == 6  # ceil((len+6)/8) per seq


def test_engine_epoch_invalidation_forces_full_upload(key):
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64, page_size=8,
                        offload_mode="zero_copy")
    eng.submit([1, 2, 3], max_tokens=4)
    eng.run()
    assert eng.stats()["table_uploads_full"] == 1
    eng.invalidate_epoch()                         # paper Listing 1 flush
    eng.submit([4, 5], max_tokens=4)
    eng.run()
    assert eng.stats()["table_uploads_full"] == 2
    assert eng.stats()["tlb"]["invalidations"] >= 1


def test_submit_rejects_over_capacity(key):
    """Regression: prompt+max_tokens beyond slot capacity must be rejected,
    not silently truncated (the old ``min(need, max_pages)``) — truncation
    later wrapped page indices into other sequences' KV."""
    from repro.core.sva.kv_manager import CapacityError
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=32, page_size=8,
                        offload_mode="zero_copy")
    with pytest.raises(CapacityError):
        eng.submit(list(range(30)), max_tokens=16)  # 46 > 32 tokens
    mgr = PagedKVManager(n_slots=1, max_pages_per_slot=4, page_size=8)
    with pytest.raises(CapacityError):
        mgr.admit(0, prompt_len=30, max_tokens=16)
    # boundary: exactly at capacity is fine
    assert mgr.admit(1, prompt_len=16, max_tokens=16) is not None


def test_engine_sliding_window_bucketed_prefill_matches_manual(key):
    """Regression: bucket-padding a prompt past the sliding window (12
    tokens -> bucket 16 > window 8) must not store pad-token KV in the
    window ring — each row keeps its own last min(len, window) REAL
    tokens."""
    cfg = reduce_for_smoke(get_config("gemma2-2b"))
    assert cfg.sliding_window and cfg.sliding_window < 16
    params = init_params(cfg, key)
    prompts = [[5, 9, 2, 14, 8, 1, 7, 3, 11, 13, 4, 6], [100, 7, 42]]

    def manual(prompt, n=4):
        cache = init_cache(cfg, 1, max_len=64, page_size=8, per_seq=True)
        lg, cache = forward_prefill(
            cfg, params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]},
            cache)
        toks = [int(jnp.argmax(lg[0, -1]))]
        pos = len(prompt)
        for _ in range(n - 1):
            lg, cache = forward_decode(
                cfg, params, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), cache)
            toks.append(int(jnp.argmax(lg[0, 0])))
            pos += 1
        return toks

    expected = [manual(p) for p in prompts]
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64, page_size=8,
                        offload_mode="zero_copy")
    rids = [eng.submit(p, max_tokens=4) for p in prompts]
    done = eng.run()
    assert [done[r].out_tokens for r in rids] == expected
    # copy mode can't map rows onto the smaller window leaves: fail fast
    with pytest.raises(NotImplementedError):
        ServingEngine(cfg, params, n_slots=2, max_len=64, page_size=8,
                      offload_mode="copy")


# --------------------------------------------------------- mapping.py fixes

def test_stage_keeps_zero_copy_stats_clean():
    """Regression: the copy baseline must NOT inflate the zero-copy
    counters (stage() used to call map() internally, so every copy-mode
    admission also bumped map_calls/table_entries_written/bytes_mapped,
    corrupting any Fig.2-style A/B)."""
    space = SVASpace(PagePool(128, 4096))
    space.stage(16 * 4096)
    assert space.stats.stage_calls == 1
    assert space.stats.bytes_copied == 16 * 4096
    assert space.stats.map_calls == 0
    assert space.stats.table_entries_written == 0
    assert space.stats.bytes_mapped == 0
    space.map(4 * 4096)
    assert space.stats.map_calls == 1 and space.stats.stage_calls == 1


def test_extend_updates_mapping_and_stats():
    """Regression: extend() used to grow m.pages but leave Mapping.n_bytes
    and stats.bytes_mapped stale — decode-driven growth was invisible to
    the memory-pressure stats."""
    space = SVASpace(PagePool(64, 4096))
    m = space.map(2 * 4096)
    assert m.n_bytes == 2 * 4096
    space.extend(m, n_new_pages=3)
    assert len(m.pages) == 5
    assert m.n_bytes == 5 * 4096
    assert space.stats.bytes_mapped == 5 * 4096
    assert space.stats.table_entries_written == 5


def test_unmap_invalidates_only_own_translations():
    """Regression: unmap() used to epoch-flush the WHOLE TLB, forcing a
    full re-walk for every other live mapping per completed request; it
    must drop only the unmapped pages' entries."""
    space = SVASpace(PagePool(64, 4096))
    a = space.map(4 * 4096)
    b = space.map(4 * 4096)
    assert len(space.tlb) == 8                   # map warms per-page entries
    space.unmap(a)
    assert space.tlb.stats.invalidations == 0    # no epoch flush
    for lp in range(4):
        assert space.tlb.lookup((b.handle, lp))[1], "b's translations died"
        assert not space.tlb.lookup((a.handle, lp))[1]
    space.invalidate_epoch()                     # Listing-1 flush is explicit
    assert space.tlb.stats.invalidations == 1
    assert len(space.tlb) == 0


# ---------------------------------------------------- CoW prefix sharing

SYS = list(range(200, 216))                      # 2 full pages @ page_size 8


def _share_engine_outputs(cfg, params, prompts, share, n=6):
    eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                        prefix_sharing=share)
    rids = [eng.submit(p, max_tokens=n) for p in prompts]
    done = eng.run()
    return [done[r].out_tokens for r in rids], eng.stats()


def test_prefix_sharing_bit_identical_to_unshared(key):
    """Acceptance: shared-prefix admissions prefill only the non-shared
    suffix (prefill_tokens_saved > 0, pages shared > 0) and decode outputs
    are bit-identical to unshared serving."""
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [SYS + [5, 9, 2], SYS + [7, 7], SYS + [5, 9, 2], [42, 43]]
    got_s, ss = _share_engine_outputs(cfg, params, prompts, True)
    got_u, su = _share_engine_outputs(cfg, params, prompts, False)
    assert got_s == got_u                        # placement never changes tokens
    assert ss["prefill_tokens_saved"] > 0
    assert ss["prefix"]["pages_shared"] > 0
    assert ss["shared_admissions"] == 2
    assert ss["cow_page_copies"] > 0             # identical prompt diverged
    assert su["prefill_tokens_saved"] == 0 and "prefix" not in su
    assert ss["sva"]["bytes_copied"] == 0        # still zero-copy admission


def test_prefix_cache_warm_across_completions(key):
    """release() leaves prompt pages behind as a warm prefix cache: a later
    request with the same system prompt maps them via refcount++."""
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64, page_size=8)
    eng.submit(SYS + [1, 2, 3], max_tokens=4)
    eng.run()
    assert eng.stats()["pool_used"] > 0          # cache retains pages
    eng.submit(SYS + [9, 8, 7], max_tokens=4)
    done = eng.run()
    s = eng.stats()
    assert s["prefix"]["hits"] == 1
    assert s["prefill_tokens_saved"] >= len(SYS)
    assert all(len(r.out_tokens) == 4 for r in done.values())


def test_cow_never_mutates_shared_page():
    """A CoW duplication must leave the original page untouched and still
    referenced by the other sharers; only the writer's table changes."""
    mgr = PagedKVManager(n_slots=3, max_pages_per_slot=8, page_size=4)
    prompt = list(range(40, 52))                 # 12 tokens: 3 full pages
    a = mgr.admit(0, 12, 6, tokens=prompt)
    b = mgr.admit(1, 12, 6, tokens=prompt)
    assert b.shared_pages == 3 and b.pages[:3] == a.pages[:3]
    shared_page = a.pages[2]
    # both write into their own FRESH page 3 first (position 12): no CoW
    mgr.append_token(0, 1)
    mgr.append_token(1, 1)
    assert mgr.pending_cow == []
    # force a divergence inside the shared region: identical 10-token
    # prompt c shares a's PARTIAL page; c's first append writes into it
    mgr2 = PagedKVManager(n_slots=3, max_pages_per_slot=8, page_size=4)
    p10 = prompt[:10]
    c = mgr2.admit(0, 10, 6, tokens=p10)
    d = mgr2.admit(1, 10, 6, tokens=p10)
    assert d.shared_pages == 3                   # 2 full + partial tail
    part = c.pages[2]
    rc_before = mgr2.pool.refcount(part)
    mgr2.append_token(0, 5)                      # c writes pos 10 -> CoW
    (src, dst), = mgr2.drain_cow_copies()
    assert src == part and dst == mgr2.seqs[0].pages[2] != part
    assert mgr2.seqs[1].pages[2] == part         # sharer untouched
    assert mgr2.pool.refcount(part) == rc_before - 1
    assert mgr2.tables[mgr2.seqs[1].slot][2] == part
    mgr2.pool.check_invariants()


def test_prefix_cache_lru_eviction_under_pressure():
    """OutOfPages pressure evicts warm-cache entries LRU instead of
    rejecting the admission."""
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=4, page_size=4)
    mgr.admit(0, 8, 8, tokens=list(range(8)))
    mgr.release(0)
    assert mgr.prefix.n_cached_pages == 2        # warm full pages
    assert mgr.admit(1, 8, 8, tokens=list(range(50, 58))) is not None
    # 8 pages total, 4 live + 2 cached: next 4-page admission must evict
    assert mgr.admit(2, 8, 8, tokens=list(range(80, 88))) is not None
    assert mgr.prefix.stats.evictions > 0
    mgr.pool.check_invariants()


def test_engine_pallas_decode_backend_matches_jax(key):
    """The Pallas paged-decode kernel on the hot path (interpret mode on
    CPU) produces the same tokens as the pure-JAX gather path."""
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [SYS + [5, 9, 2], [11, 4]]

    def run(backend):
        eng = ServingEngine(cfg, params, n_slots=2, max_len=32, page_size=8,
                            decode_backend=backend)
        rids = [eng.submit(p, max_tokens=4) for p in prompts]
        done = eng.run()
        return [done[r].out_tokens for r in rids]

    assert run("pallas") == run("jax")


def _prefix_policy_scenario(policy):
    """Hot 2-page prompt (matched twice, high frequency) vs a colder 2-page
    one-shot prompt, both RELEASED, competing under a 4-page warm-cache
    cap; a later 1-page admission overflows the cap by one and forces the
    policy to pick a victim. Returns the hot prompt's shared pages on
    re-admission."""
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=8, page_size=4,
                         prefix_policy=policy, prefix_cap_pages=4)
    hot = list(range(8))                         # exactly 2 full pages
    mgr.admit(0, 8, 8, tokens=hot)
    mgr.release(0)
    mgr.admit(1, 8, 8, tokens=hot)               # match bumps frequency
    mgr.release(1)
    mgr.admit(2, 8, 8, tokens=list(range(100, 108)))   # cold, 2 pages
    mgr.release(2)                               # cached: hot 2 + cold 2
    mgr.admit(3, 4, 4, tokens=list(range(200, 204)))   # +1 page > cap
    mgr.release(3)
    probe = mgr.admit(4, 8, 8, tokens=hot)
    mgr.pool.check_invariants()
    return probe.shared_pages, mgr


def test_prefix_cache_policy_lfu_keeps_hot_prompt():
    """Under a capped warm cache, LFU retains the frequently re-admitted
    prompt intact while LRU (recency) sheds its tail in favor of the newer
    one-shot prompt — the ROADMAP's frequency-aware eviction ask."""
    shared_lfu, mgr_lfu = _prefix_policy_scenario("lfu")
    shared_lru, _ = _prefix_policy_scenario("lru")
    assert shared_lfu == 2                       # hot prefix fully resident
    assert shared_lru < shared_lfu               # recency evicted its tail
    assert mgr_lfu.prefix.stats.evictions > 0
    assert mgr_lfu.stats()["prefix"]["policy"] == "lfu"


def test_prefix_cache_cap_enforced():
    """prefix_cache_pages bounds the warm cache's sole-owned footprint:
    after release, the next admission sheds entries down to the cap."""
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=8, page_size=4,
                         prefix_cap_pages=2)
    mgr.admit(0, 16, 4, tokens=list(range(300, 316)))    # 4 full pages
    mgr.release(0)
    assert mgr.prefix.n_cached_pages == 4        # live cap waits for release
    mgr.admit(1, 4, 4, tokens=list(range(400, 404)))
    assert mgr.prefix.n_cached_pages <= 2
    assert mgr.prefix.stats.evictions >= 2
    mgr.pool.check_invariants()


def test_engine_wires_prefix_policy_from_config(key):
    import dataclasses
    cfg = dataclasses.replace(reduce_for_smoke(get_config("llama3.2-1b")),
                              prefix_cache_policy="lfu",
                              prefix_cache_pages=8)
    params = init_params(cfg, key)
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64, page_size=8)
    assert eng.mgr.prefix.policy == "lfu"
    assert eng.mgr.prefix.max_pages == 8
    s = eng.stats()
    assert s["prefix"]["policy"] == "lfu" and s["prefix"]["max_pages"] == 8
    assert s["iommu"]["walk"]["model"] == "counting"


def test_map_tables_rejects_wraparound():
    """Regression: installing a table row into a leaf with fewer pages
    (sliding-window) must raise, not wrap entries modulo the pool size."""
    import jax.numpy as jnp
    from repro.core.serving.engine import _map_tables
    from repro.models import attention as attn
    kv = attn.PagedKV(
        k_pool=jnp.zeros((1, 4, 4, 1, 2)), v_pool=jnp.zeros((1, 4, 4, 1, 2)),
        block_table=jnp.zeros((1, 4), jnp.int32),
        length=jnp.zeros((1,), jnp.int32))
    row = np.asarray([[7, 0, 1, 2, 3, 4, 5, 6]], np.int32)   # entry 7 >= 4
    with pytest.raises(ValueError):
        _map_tables({"kv": kv}, row, np.zeros(1, np.int32))
