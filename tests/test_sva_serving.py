"""SVA layer + continuous-batching engine correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core.sva.kv_manager import PagedKVManager
from repro.core.sva.mapping import SVASpace
from repro.core.sva.page_pool import PagePool
from repro.core.serving.engine import ServingEngine
from repro.models import (forward_decode, forward_prefill, init_cache,
                          init_params)


def test_mapping_zero_copy_vs_copy_costs():
    space = SVASpace(PagePool(128, 4096))
    m = space.map(16 * 4096)
    assert space.stats.bytes_copied == 0
    assert space.stats.table_entries_written == 16
    space.unmap(m)
    m2 = space.stage(16 * 4096)
    assert space.stats.bytes_copied == 16 * 4096   # the staging copy


def test_mapping_prefix_sharing():
    space = SVASpace(PagePool(64, 4096))
    a = space.map(8 * 4096)
    b = space.map(8 * 4096, share_prefix_from=a, prefix_pages=4)
    assert b.pages[:4] == a.pages[:4]
    assert space.pool.n_used == 12                 # 8 + 4 fresh
    space.unmap(a)
    assert space.pool.refcount(b.pages[0]) == 1    # prefix survives
    space.unmap(b)
    assert space.pool.n_used == 0


def test_kv_manager_tables_are_permutations():
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=8, page_size=4)
    st = mgr.admit(0, prompt_len=10, max_tokens=6)
    assert st is not None
    assert sorted(mgr.tables[st.slot].tolist()) == list(range(8))
    for i in range(6):
        mgr.append_token(0, i)
    assert sorted(mgr.tables[st.slot].tolist()) == list(range(8))
    mgr.release(0)
    assert mgr.free_slots and mgr.pools[st.slot].n_free == 8


def _engine_outputs(mode, cfg, params, prompts, n=6):
    eng = ServingEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                        offload_mode=mode)
    rids = [eng.submit(p, max_tokens=n) for p in prompts]
    done = eng.run()
    return [done[r].out_tokens for r in rids], eng.stats()


def test_engine_matches_manual_loop(key):
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[5, 9, 2, 14], [100, 7], [3, 3, 3, 8, 1, 30], [42]]

    def manual(prompt, n=6):
        cache = init_cache(cfg, 1, max_len=64, page_size=8, per_seq=True)
        lg, cache = forward_prefill(
            cfg, params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
        toks = [int(jnp.argmax(lg[0, -1]))]
        pos = len(prompt)
        for _ in range(n - 1):
            lg, cache = forward_decode(
                cfg, params, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), cache)
            toks.append(int(jnp.argmax(lg[0, 0])))
            pos += 1
        return toks

    expected = [manual(p) for p in prompts]
    got, stats = _engine_outputs("zero_copy", cfg, params, prompts)
    assert got == expected
    assert stats["sva"]["bytes_copied"] == 0


def test_engine_copy_mode_same_tokens_more_copies(key):
    """copy-based admission produces identical TOKENS but pays staging."""
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[11, 4, 9], [87, 23, 1, 5]]
    zc, zc_stats = _engine_outputs("zero_copy", cfg, params, prompts)
    cp, cp_stats = _engine_outputs("copy", cfg, params, prompts)
    assert zc == cp
    assert cp_stats["staging_copies"] > 0
    assert cp_stats["sva"]["bytes_copied"] > 0
    assert zc_stats["staging_copies"] == 0


def test_engine_queueing_more_requests_than_slots(key):
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[i + 1, i + 2] for i in range(7)]   # 7 requests, 3 slots
    got, stats = _engine_outputs("zero_copy", cfg, params, prompts, n=4)
    assert len(got) == 7
    assert all(len(t) == 4 for t in got)
    assert stats["sva"]["unmap_calls"] == 7        # every seq released
