"""svasan (core/sva/sanitizer.py) — one injected-bug test per detector
(each deliberately breaks the discipline the detector watches and asserts
the precise report; disable the detector and the test fails), plus the
clean-path guarantees: a sanitized run of the real stack produces zero
reports and identical stats, and the env/constructor knobs resolve the
documented way."""
import numpy as np
import pytest

from repro.core.sva.iommu import (IOMMU, CountingWalk, IsolationError,
                                  PrefetchConfig, TLBConfig)
from repro.core.sva.kv_manager import PagedKVManager
from repro.core.sva.page_pool import PagePool
from repro.core.sva.sanitizer import (FREE, OWNED, SHARED, SanitizerError,
                                      SVASanitizer, resolve)


def mk_manager(**kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("page_size", 4)
    kw.setdefault("layout", "global")
    kw.setdefault("sanitize", True)
    return PagedKVManager(**kw)


def sanitized_pool(n_pages=16):
    pool = PagePool(n_pages, page_size=4096)
    san = SVASanitizer()
    san.attach_pool(pool)
    return pool, san


# ----------------------------------------------------------- state model

def test_shadow_state_machine():
    pool, san = sanitized_pool()
    (pg,) = pool.alloc(1)
    assert san.state(pool, pg) == OWNED
    pool.share([pg])
    assert san.state(pool, pg) == SHARED
    pool.free([pg])
    assert san.state(pool, pg) == OWNED
    pool.free([pg])
    assert san.state(pool, pg) == FREE
    assert san.reports == []


# ------------------------------------------------- detector: double-free

def test_double_free_detected():
    pool, san = sanitized_pool()
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(SanitizerError) as ei:
        pool.free(pages)
    assert ei.value.report.detector == "double-free"
    assert ei.value.report.state == FREE


def test_share_of_free_page_detected():
    pool, san = sanitized_pool()
    (pg,) = pool.alloc(1)
    pool.free([pg])
    with pytest.raises(SanitizerError) as ei:
        pool.share([pg])
    assert ei.value.report.detector == "double-free"


# --------------------------------------- detector: translate-after-unmap

def test_tlb_hit_after_stealth_unmap_detected():
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(16, "lru"))
    iommu.sanitizer = SVASanitizer()
    sp = iommu.attach(1)
    sp.map([10, 20, 30])              # warm=True: TLB holds all three
    # the bug: drop the mapping WITHOUT invalidating (table and TLB now
    # disagree) — the next hit is a use-after-free translation
    sp.table.pop(1)
    with pytest.raises(SanitizerError) as ei:
        sp.translate(1)
    rep = ei.value.report
    assert rep.detector == "translate-after-unmap"
    assert rep.key == (1, 1)


def test_tlb_hit_disagreeing_with_remap_detected():
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(16, "lru"))
    iommu.sanitizer = SVASanitizer()
    sp = iommu.attach(1)
    sp.map([10, 20])
    # the bug: CoW retargets the table but skips the invalidation (a
    # correct remap goes through IOAddressSpace.remap)
    sp.table[0] = 99
    with pytest.raises(SanitizerError) as ei:
        sp.translate(0)
    assert ei.value.report.detector == "translate-after-unmap"


def test_tlb_entry_surviving_unmap_detected(monkeypatch):
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(16, "lru"))
    iommu.sanitizer = SVASanitizer()
    sp = iommu.attach(1)
    sp.map([10, 20])
    # the bug: unmap "forgets" to invalidate — entries outlive the space
    monkeypatch.setattr(iommu, "invalidate", lambda *a, **k: None)
    with pytest.raises(SanitizerError) as ei:
        sp.unmap()
    assert ei.value.report.detector == "translate-after-unmap"


# --------------------------------------------- detector: stale-prefetch

def test_inflight_prefetch_surviving_unmap_detected(monkeypatch):
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(16, "lru"),
                  prefetch=PrefetchConfig("next_page", degree=1))
    iommu.sanitizer = SVASanitizer()
    sp = iommu.attach(1)
    sp.map([10, 20, 30], warm=False)
    sp.translate(0)                   # demand miss -> prefetch of lp 1
    assert (1, 1) in iommu._pending   # fill is in flight
    # the bug: the partial unmap skips invalidation, so the in-flight fill
    # survives and would install a dead translation later
    monkeypatch.setattr(iommu, "invalidate", lambda *a, **k: None)
    with pytest.raises(SanitizerError) as ei:
        sp.unmap([1, 2])
    rep = ei.value.report
    assert rep.detector == "stale-prefetch"
    assert rep.key == (1, 1)


def test_prefetch_fill_for_unmapped_page_detected():
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(16, "lru"),
                  prefetch=PrefetchConfig("next_page", degree=1))
    iommu.sanitizer = SVASanitizer()
    sp = iommu.attach(1)
    sp.map([10, 20, 30], warm=False)
    sp.translate(0)                   # prefetch of lp 1 now in flight
    # the bug: the mapping dies behind the IOMMU's back while the fill is
    # in flight; the install must be caught red-handed
    sp.table.pop(1)
    with pytest.raises(SanitizerError) as ei:
        sp.translate(2)               # next demand installs pending fills
    assert ei.value.report.detector == "stale-prefetch"


# -------------------------------------------- detector: cow-bypass-write

def test_cow_bypass_write_detected(monkeypatch):
    m = mk_manager()
    m.admit(1, 4, 8, tokens=[1, 2, 3, 4])
    st = m.seqs[1]
    write_pg = st.pages[1]            # the next append writes page index 1
    m.pool.share([write_pg])          # another mapping still references it
    # the bug: the CoW-before-write pass is skipped
    monkeypatch.setattr(m, "_cow_before_write", lambda st: None)
    with pytest.raises(SanitizerError) as ei:
        m.append_token(1, 5)
    rep = ei.value.report
    assert rep.detector == "cow-bypass-write"
    assert rep.page == write_pg
    assert rep.state == SHARED


def test_cow_before_write_keeps_shared_page_safe():
    """Control for the bypass test: with the real CoW pass in place the
    same scenario is sanitizer-clean (the write page is duplicated)."""
    m = mk_manager()
    m.admit(1, 4, 8, tokens=[1, 2, 3, 4])
    st = m.seqs[1]
    shared_pg = st.pages[1]
    m.pool.share([shared_pg])
    m.append_token(1, 5)              # CoW duplicates before the write
    assert st.pages[1] != shared_pg
    assert m.sanitizer.reports == []
    m.pool.free([shared_pg])          # drop the artificial reference


# --------------------------------------------- detector: leak-at-release

def test_page_leak_at_release_detected(monkeypatch):
    m = mk_manager(prefix_sharing=False)
    m.admit(1, 8, 4, tokens=list(range(8)))
    orig_free = m.pool.free
    # the bug: release drops all but one of the sequence's references
    monkeypatch.setattr(m.pool, "free",
                        lambda pages: orig_free(list(pages)[:-1]))
    with pytest.raises(SanitizerError) as ei:
        m.release(1)
    rep = ei.value.report
    assert rep.detector == "leak-at-release"
    assert rep.page is not None
    assert "leaked" in rep.message


# ------------------------------------- detector: cross-tenant-translate

def test_cross_tenant_translate_detected(monkeypatch):
    """The injected bug: the IOMMU's isolation gate is patched out
    entirely. The sanitizer re-derives ASID ownership from the registry
    INSIDE translate, so the foreign translation is still refused —
    a buggy or bypassed ``_check_tenant`` cannot leak a page silently."""
    m = mk_manager(tenants={"a": {}, "b": {}})
    m.admit(1, 8, 4, tokens=list(range(8)), tenant="a")
    slot = m.seqs[1].slot
    monkeypatch.setattr(m.iommu, "_check_tenant", lambda *a, **k: None)
    with pytest.raises(SanitizerError) as ei:
        m.iommu.translate(slot, 0, tenant="b")
    rep = ei.value.report
    assert rep.detector == "cross-tenant-translate"
    assert "bypassed" in rep.message
    # with the gate intact the same access raises IsolationError BEFORE
    # the sanitizer ever sees it (gate first, shadow check second)
    m2 = mk_manager(tenants={"a": {}, "b": {}})
    m2.admit(1, 8, 4, tokens=list(range(8)), tenant="a")
    with pytest.raises(IsolationError):
        m2.iommu.translate(m2.seqs[1].slot, 0, tenant="b")
    assert m2.sanitizer.stats()["reports"] == 0


# ------------------------------------------------------------ clean path

def _workload(m):
    m.admit(1, 8, 6, tokens=[1, 2, 3, 4, 5, 6, 7, 8])
    m.admit(2, 8, 6, tokens=[1, 2, 3, 4, 5, 6, 9, 10])
    for t in range(4):
        m.append_token(1, 100 + t)
        m.append_token(2, 200 + t)
    m.release(1)
    m.admit(3, 8, 6, tokens=[1, 2, 3, 4, 5, 6, 7, 8])
    for t in range(3):
        m.append_token(2, 300 + t)
        m.append_token(3, 400 + t)
    m.release(2)
    m.release(3)
    return m.stats()


def test_clean_run_zero_reports():
    st = _workload(mk_manager())
    assert st["svasan"]["reports"] == 0
    assert st["svasan"]["checks"] > 0


def test_sanitizer_observes_without_changing_behavior():
    """On vs off: identical stats (svasan only observes)."""
    on = _workload(mk_manager(sanitize=True))
    off = _workload(mk_manager(sanitize=False))
    assert "svasan" not in off
    on.pop("svasan")
    assert on == off


def test_env_knob_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SVASAN", raising=False)
    assert resolve(None) is False
    assert resolve(True) is True
    monkeypatch.setenv("REPRO_SVASAN", "1")
    assert resolve(None) is True
    assert resolve(False) is False   # explicit off beats the env
    monkeypatch.setenv("REPRO_SVASAN", "0")
    assert resolve(None) is False
    # and the manager picks the env default up through sanitize=None
    monkeypatch.setenv("REPRO_SVASAN", "1")
    assert mk_manager(sanitize=None).sanitizer is not None


def test_collect_mode_gathers_multiple_reports():
    pool = PagePool(8, page_size=4096)
    san = SVASanitizer(raise_on_report=False)
    san.attach_pool(pool)
    pages = pool.alloc(2)
    pool.free(pages)
    san.on_free(pool, pages)          # shadow-only double free, twice
    assert len(san.reports) == 2
    assert all(r.detector == "double-free" for r in san.reports)


# ----------------------------------------------------- property (fuzzing)

def test_random_interleavings_run_sanitizer_clean():
    """Random admit/append/release interleavings over a shared token
    alphabet (prefix sharing and CoW arise organically) never trip any
    detector."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_

    ops = st_.lists(
        st_.tuples(st_.sampled_from(["admit", "append", "release"]),
                   st_.integers(0, 3),          # seq id
                   st_.integers(0, 2)),         # token alphabet
        min_size=1, max_size=60)

    @settings(max_examples=40, deadline=None)
    @given(ops=ops)
    def prop(ops):
        m = mk_manager(n_slots=3, max_pages_per_slot=6)
        live = set()
        for op, sid, tok in ops:
            if op == "admit" and sid not in live:
                # shared alphabet -> admissions share prompt prefixes
                got = m.admit(sid, 4, 6, tokens=[tok, tok, 7, 8])
                if got is not None:
                    live.add(sid)
            elif op == "append" and sid in live:
                if not m.seqs[sid].done:
                    m.append_token(sid, tok)
            elif op == "release" and sid in live:
                m.release(sid)
                live.discard(sid)
        for sid in list(live):
            m.release(sid)
        assert m.sanitizer.reports == []

    prop()
