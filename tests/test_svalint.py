"""tools/svalint fixture tests: each rule R001-R005 must fire on a
minimal in-memory violation (via ``lint_sources``) and stay silent on the
minimal clean counterpart — so a refactor of the linter that silently
disables a rule fails here, not in review. The final test pins the real
tree clean (the repo's own acceptance gate, same check CI runs)."""
from pathlib import Path

import pytest

from tools.svalint import (DOC_FILES, RULES, Finding, lint_paths,
                           lint_sources)

ROOT = Path(__file__).resolve().parents[1]


def rules_of(findings):
    return {f.rule for f in findings}


# A minimal ARCHITECTURE.md whose schema section matches _STATS_SRC below.
_ARCH_OK = """# arch
## Stats schema
```
hits: <count>
```
## next
"""

_STATS_SRC = """
class S:
    def stats(self):
        return {"hits": 1}
"""


def _base_sources():
    """Smallest source tree that is clean under every rule."""
    return {
        "ARCHITECTURE.md": _ARCH_OK,
        "README.md": "docs\n",
        "benchmarks/README.md": "docs\n",
        "src/repro/core/sva/iommu.py": _STATS_SRC,
    }


def test_clean_fixture_is_clean():
    assert lint_sources(_base_sources()) == []


# ----------------------------------------------------------------- R001

def test_r001_fires_on_raw_translation_cache_construction():
    src = _base_sources()
    src["src/repro/core/serving/engine.py"] = (
        "from repro.core.sva.tlb import TranslationCache\n"
        "tlb = TranslationCache(cfg)\n")
    findings = lint_sources(src, rules=["R001"])
    assert rules_of(findings) == {"R001"}
    assert findings[0].path == "src/repro/core/serving/engine.py"
    assert findings[0].line == 2


def test_r001_fires_on_internals_access_outside_tests():
    src = _base_sources()
    src["benchmarks/sweep.py"] = "n = iommu.tlb._sets[0]\n"
    assert rules_of(lint_sources(src, rules=["R001"])) == {"R001"}


def test_r001_allows_iommu_and_whitebox_tests():
    src = _base_sources()
    # the front-end itself may construct; white-box tests may inspect
    src["src/repro/core/sva/iommu.py"] += "\nt = TranslationCache(cfg)\n"
    src["tests/test_geometry.py"] = "occ = iommu.tlb._sets[0]\n"
    assert lint_sources(src, rules=["R001"]) == []


def test_r001_suppression_comment():
    src = _base_sources()
    src["benchmarks/sweep.py"] = \
        "t = TranslationCache(cfg)  # svalint: disable=R001\n"
    assert lint_sources(src, rules=["R001"]) == []


# ----------------------------------------------------------------- R002

def test_r002_fires_on_raw_pool_mutation():
    src = _base_sources()
    src["src/repro/core/serving/engine.py"] = (
        "def admit(self):\n"
        "    self.pool._free.pop()\n")
    findings = lint_sources(src, rules=["R002"])
    assert rules_of(findings) == {"R002"}


def test_r002_fires_on_pool_alloc_outside_manager():
    src = _base_sources()
    src["benchmarks/bench.py"] = "pages = pool.alloc(4)\n"
    assert rules_of(lint_sources(src, rules=["R002"])) == {"R002"}


def test_r002_allows_manager_and_cow_path():
    src = _base_sources()
    src["src/repro/core/sva/kv_manager.py"] = (
        "def admit(self):\n"
        "    return self.pool.alloc(1)\n")
    src["src/repro/core/serving/engine.py"] = (
        "class E:\n"
        "    def _apply_cow(self):\n"
        "        return self.pool.alloc(1)\n")
    assert lint_sources(src, rules=["R002"]) == []


# ----------------------------------------------------------------- R003

def test_r003_fires_on_undocumented_emitted_key():
    src = _base_sources()
    src["src/repro/core/sva/iommu.py"] = (
        "class S:\n"
        "    def stats(self):\n"
        "        return {\"hits\": 1, \"novel_key\": 2}\n")
    findings = lint_sources(src, rules=["R003"])
    assert any("novel_key" in f.msg for f in findings)


def test_r003_fires_on_documented_but_never_emitted_key():
    src = _base_sources()
    src["ARCHITECTURE.md"] = _ARCH_OK.replace(
        "hits: <count>", "hits: <count>\nghost_key: <never emitted>")
    findings = lint_sources(src, rules=["R003"])
    assert any("ghost_key" in f.msg for f in findings)


def test_r003_fires_when_schema_section_missing():
    src = _base_sources()
    src["ARCHITECTURE.md"] = "# arch with no schema section\n"
    findings = lint_sources(src, rules=["R003"])
    assert findings and findings[0].path == "ARCHITECTURE.md"


# ----------------------------------------------------------------- R004

def test_r004_fires_on_item_in_jitted_function():
    src = _base_sources()
    src["src/repro/core/serving/engine.py"] = (
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return x.item()\n")
    findings = lint_sources(src, rules=["R004"])
    assert rules_of(findings) == {"R004"}


def test_r004_fires_transitively_and_on_shape_branch():
    src = _base_sources()
    src["src/repro/kernels/k.py"] = (
        "import jax\n"
        "def helper(x):\n"
        "    if x.shape[0] > 4:\n"
        "        return int(x)\n"
        "    return x\n"
        "@jax.jit\n"
        "def entry(x):\n"
        "    return helper(x)\n")
    findings = lint_sources(src, rules=["R004"])
    assert len(findings) >= 2          # the branch AND the int() cast


def test_r004_allows_static_shape_reads_and_guards():
    src = _base_sources()
    src["src/repro/core/serving/engine.py"] = (
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    n = int(x.shape[0])\n"     # static under trace
        "    if x.ndim != 2:\n"         # raise-only guard is exempt
        "        raise ValueError(\"rank\")\n"
        "    return x * n\n")
    assert lint_sources(src, rules=["R004"]) == []


def test_r004_ignores_host_side_code():
    src = _base_sources()
    src["src/repro/core/serving/engine.py"] = (
        "def host_helper(x):\n"
        "    return x.item()\n")       # never jitted -> fine
    assert lint_sources(src, rules=["R004"]) == []


# ----------------------------------------------------------------- R005

def test_r005_fires_on_undocumented_flag():
    src = _base_sources()
    src["benchmarks/bench.py"] = (
        "import argparse\n"
        "ap = argparse.ArgumentParser()\n"
        "ap.add_argument(\"--mystery-flag\")\n")
    findings = lint_sources(src, rules=["R005"])
    assert rules_of(findings) == {"R005"}
    assert "--mystery-flag" in findings[0].msg


def test_r005_documented_flag_is_clean():
    src = _base_sources()
    src["benchmarks/bench.py"] = (
        "import argparse\n"
        "ap = argparse.ArgumentParser()\n"
        "ap.add_argument(\"--depth\")\n")
    src["benchmarks/README.md"] = "Use `--depth N` to set depth.\n"
    assert lint_sources(src, rules=["R005"]) == []


# ------------------------------------------------------------ the gate

def test_finding_format():
    f = Finding("a/b.py", 7, "R001", "boom")
    assert str(f) == "a/b.py:7: R001 boom"


def test_rule_registry_and_doc_files():
    assert RULES == ("R001", "R002", "R003", "R004", "R005")
    for doc in DOC_FILES:
        assert (ROOT / doc).exists(), doc


def test_real_tree_is_clean():
    """The acceptance gate: the repo's own tree lints clean — identical to
    CI's `python -m tools.svalint src tests benchmarks examples`."""
    findings = lint_paths(ROOT, ["src", "tests", "benchmarks", "examples"])
    assert findings == [], "\n".join(str(f) for f in findings)
