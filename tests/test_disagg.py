"""Disaggregated prefill/decode serving (core/serving/disagg/): KV
migration between worker ASIDs over the shared pool, priced as remote DMA
through the SVA layer — and the PR's core contract: the disaggregated
engine's outputs are BIT-IDENTICAL to the colocated engines at equal
total slot width, in BOTH transfer modes, under pool pressure, arrival
interleavings, and preempt-during-pending-transfer races.

Manager-level ``migrate`` unit tests are jax-free; engine tests drive
the shared conformance harness (tests/conformance.py) over the same
pressure workload as tests/test_scheduler.py. The interleaving property
runs as fixed parameterized cases always, plus a hypothesis-randomized
version when hypothesis is installed."""
import dataclasses

import numpy as np
import pytest

from benchmarks.trace_replay import replay_trace
from repro.configs import get_config, reduce_for_smoke
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.serving.disagg import DisaggEngine
from repro.core.sva.iommu import (IOMMU, CountingWalk, Sv39Walk, TLBConfig)
from repro.core.sva.kv_manager import PagedKVManager
from repro.core.sva.page_pool import OutOfPages
from repro.models import init_params
from tests.conformance import (ARRIVAL_CASES, POOL, Workload,
                               pressure_workload, serve)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def setup():
    import jax
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    return cfg, init_params(cfg, jax.random.key(0))


# The shared pressure workload (tests/conformance.py): mixed lengths,
# tight pool -> transfers defer, decode-side preemption fires. The
# unconstrained fixed engine at the same total width is the ground truth
# every disaggregation policy must reproduce (serve(cfg, params, "fixed")).
def _serve_disagg(cfg, params, mode, workload, **engine_kw):
    return serve(cfg, params, f"disagg-{mode}", workload, **engine_kw)


# ------------------------------------------------- manager-level migrate

def _mgr(**kw):
    return PagedKVManager(n_slots=4, max_pages_per_slot=4, page_size=8,
                          kv_bytes_per_token=256, **kw)


def _admit(mgr, seq_id, n_tokens):
    st = mgr.admit(seq_id, prompt_len=n_tokens, max_tokens=2,
                   tokens=list(range(n_tokens)), lazy=True)
    assert st is not None
    return st


def test_migrate_share_is_zero_copy():
    mgr = _mgr()
    st = _admit(mgr, 1, 16)                      # 2 pages
    src_slot, src_pages = st.slot, list(st.pages)
    dst = next(s for s in range(4) if s != src_slot)
    mgr.reserve_slots([dst])
    out = mgr.migrate(1, dst, mode="share")
    assert out.slot == dst
    assert out.pages == src_pages                # SAME physical pages
    t = mgr.transfer_stats
    assert (t.transfers, t.pages_shared, t.pages_copied) == (1, 2, 0)
    assert t.payload_bytes == 0                  # zero-copy: table only
    assert t.table_bytes == 2 * 4
    assert not mgr.pending_cow                   # nothing to stage
    # source slot fully torn down, destination row installed
    assert src_slot in mgr.free_slots
    assert mgr.lengths[src_slot] == 0
    assert mgr.lengths[dst] == st.length
    assert list(mgr.tables[dst][:2]) == src_pages


def test_migrate_copy_stages_full_payload():
    mgr = _mgr()
    st = _admit(mgr, 1, 16)
    src_pages = list(st.pages)
    dst = next(s for s in range(4) if s != st.slot)
    mgr.reserve_slots([dst])
    out = mgr.migrate(1, dst, mode="copy")
    assert out.pages != src_pages                # fresh pages
    t = mgr.transfer_stats
    assert (t.transfers, t.pages_copied, t.pages_shared) == (1, 2, 0)
    assert t.payload_bytes == 2 * 8 * 256        # pages * page_size * bytes
    # device-side batched copy queued src->dst, drained by the engine
    assert sorted(mgr.pending_cow) == sorted(zip(src_pages, out.pages))


def test_migrate_prices_through_external_iommu():
    """An external transfer IOMMU (the paper's 4-entry IOTLB over a
    no-LLC Sv39 walk) sees every page COLD: full PTW cost lands in the
    transfer stats, and the fabric's window closes after the hand-off."""
    mgr = _mgr()
    st = _admit(mgr, 1, 24)                      # 3 pages
    dst = next(s for s in range(4) if s != st.slot)
    mgr.reserve_slots([dst])
    xfer = IOMMU(walk_model=Sv39Walk(llc=False), tlb=TLBConfig(4, "lru"))
    mgr.migrate(1, dst, mode="share", xfer_iommu=xfer)
    t = mgr.transfer_stats
    assert t.ptw_cycles > 0
    assert t.tlb_misses == 3 and t.tlb_hits == 0
    assert xfer.space(st.slot) is None           # detached after transfer


def test_migrate_validation_errors():
    mgr = _mgr()
    st1 = _admit(mgr, 1, 8)
    st2 = _admit(mgr, 2, 8)
    with pytest.raises(ValueError):              # same slot
        mgr.migrate(1, st1.slot)
    with pytest.raises(ValueError):              # destination occupied
        mgr.migrate(1, st2.slot)
    free = next(s for s in range(4) if s not in (st1.slot, st2.slot))
    with pytest.raises(ValueError):              # unknown mode
        mgr.migrate(1, free, mode="move")
    with pytest.raises(ValueError):              # reserving an occupied slot
        mgr.reserve_slots([st1.slot])


def test_migrate_copy_out_of_pages_mutates_nothing():
    mgr = _mgr(pool_pages=4)
    st = _admit(mgr, 1, 24)                      # 3 of 4 pool pages
    src_slot, src_pages = st.slot, list(st.pages)
    dst = next(s for s in range(4) if s != src_slot)
    mgr.reserve_slots([dst])
    headroom = mgr.free_page_headroom()
    with pytest.raises(OutOfPages):
        mgr.migrate(1, dst, mode="copy")         # needs 3, only 1 free
    # alloc-first ordering: the failed transfer left no trace
    assert (st.slot, st.pages) == (src_slot, src_pages)
    assert mgr.transfer_stats.transfers == 0
    assert not mgr.pending_cow
    assert mgr.free_page_headroom() == headroom
    # ...and share mode still succeeds on the same sequence
    mgr.migrate(1, dst, mode="share")


# ----------------------------------------------------- engine validation

def test_disagg_engine_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError):
        DisaggEngine(cfg, params, n_prefill_slots=2, n_decode_slots=2,
                     max_len=64, disagg_mode="move")
    with pytest.raises(ValueError):
        DisaggEngine(cfg, params, n_prefill_slots=0, n_decode_slots=4,
                     max_len=64)


# ------------------------------------------------------------ bit-identity

@pytest.mark.parametrize("mode", ["share", "copy"])
def test_disagg_bit_identical_ample_pool(setup, mode):
    """No pool pressure: prefill-worker chunking + migration + decode-
    worker masking reproduces the fixed engine token-for-token."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size)
    ref, _, _ = serve(cfg, params, "fixed", wl)
    outs, eng, done = _serve_disagg(cfg, params, mode, wl)
    assert outs == ref
    s = eng.stats()
    assert s["disagg"]["transfers"] >= 1
    # every decoded request carries the TTFDT stamp
    assert all(r.first_decode_step is not None
               and r.first_decode_step >= r.submitted_step
               for r in done.values())


@pytest.mark.parametrize("mode", ["share", "copy"])
def test_disagg_bit_identical_under_pressure(setup, mode):
    """Oversubscribed pool: transfers defer/cancel, prefills and decodes
    preempt — and outputs STILL match the unconstrained fixed engine."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size)
    ref, _, _ = serve(cfg, params, "fixed", wl)
    outs, eng, _ = _serve_disagg(cfg, params, mode, wl, pool_pages=POOL)
    assert outs == ref
    assert eng.stats()["disagg"]["transfers"] >= 1


@pytest.mark.parametrize("mode", ["share", "copy"])
@pytest.mark.parametrize("arrivals", ARRIVAL_CASES)
def test_disagg_interleaving_bit_identity(setup, mode, arrivals):
    cfg, params = setup
    ref, _, _ = serve(cfg, params, "fixed", pressure_workload(cfg.vocab_size))
    outs, _, _ = _serve_disagg(
        cfg, params, mode,
        pressure_workload(cfg.vocab_size, arrivals=arrivals),
        pool_pages=POOL)
    assert outs == ref


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 14), st.integers(1, 6),
                              st.integers(0, 3)),
                    min_size=1, max_size=4),
           st.integers(0, 2 ** 31 - 1))
    def test_disagg_interleaving_property(reqs, seed):
        """Any (prompt_len, max_tokens, arrival_gap) interleaving: the
        pool-constrained disaggregated engine (share mode, the zero-copy
        path with the most aliasing hazards) is bit-identical to the
        fixed engine on the same requests — svasan watching throughout."""
        import jax
        cfg = dataclasses.replace(
            reduce_for_smoke(get_config("llama3.2-1b")), svasan=True)
        params = init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(seed)
        prompts = tuple(tuple(rng.integers(0, cfg.vocab_size,
                                           size=n).tolist())
                        for n, _, _ in reqs)
        maxtoks = tuple(m for _, m, _ in reqs)
        arrivals = tuple(np.cumsum([g for _, _, g in reqs]).tolist())
        ref, _, _ = serve(cfg, params, "fixed", Workload(prompts, maxtoks))
        outs, eng, _ = _serve_disagg(
            cfg, params, "share",
            Workload(prompts, maxtoks, arrivals=arrivals), pool_pages=POOL)
        assert outs == ref
        assert eng.stats()["svasan"]["reports"] == 0


# ------------------------------------------------------------------ svasan

@pytest.mark.parametrize("mode", ["share", "copy"])
def test_migration_svasan_clean(setup, mode):
    """Migration follows the exact release/admit refcount discipline
    (share bumps BEFORE the source drop; copy allocates first), so the
    translation sanitizer sees balanced refcounts across every transfer,
    deferral, cancellation, and decode-side preemption."""
    cfg, params = setup
    cfg = dataclasses.replace(cfg, svasan=True)
    wl = pressure_workload(cfg.vocab_size, arrivals=[0, 0, 9, 9, 0, 4])
    outs, eng, _ = _serve_disagg(cfg, params, mode, wl, pool_pages=POOL)
    s = eng.stats()
    assert s["disagg"]["transfers"] >= 1
    assert s["svasan"]["reports"] == 0
    assert s["svasan"]["checks"] > 0


def test_preempt_during_pending_transfer(setup):
    """Regression: a sequence preempted while its transfer is QUEUED must
    cancel the transfer (its KV is gone) and re-queue after the resumed
    prefill completes — without this, the pump migrates a torn-down
    sequence. Copy mode under the straggler arrivals forces the race."""
    cfg, params = setup
    ref, _, _ = serve(cfg, params, "fixed", pressure_workload(cfg.vocab_size))
    outs, eng, _ = _serve_disagg(
        cfg, params, "copy",
        pressure_workload(cfg.vocab_size, arrivals=[0, 0, 9, 9, 0, 4]),
        pool_pages=POOL)
    d = eng.stats()["disagg"]
    assert d["cancelled"] >= 1                   # the race happened
    assert d["deferred"] >= 1                    # pool pressure deferred too
    assert outs == ref                           # and changed nothing


# ------------------------------------------------------------ trace replay

def test_xfer_trace_replays_end_to_end(setup):
    """A recorded disaggregated trace carries xfer annotations paired
    with the source unmap / destination map, and replays through the
    IOMMU cost model without error."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size)
    _, eng, _ = _serve_disagg(cfg, params, "share", wl, pool_pages=POOL,
                              record_translation_trace=True)
    trace = eng.translation_trace
    kinds = {ev[0] for ev in trace}
    assert {"xfer", "map", "unmap", "step"} <= kinds
    n_xfers = sum(1 for ev in trace if ev[0] == "xfer")
    assert n_xfers == eng.stats()["transfer"]["transfers"]
    # share-mode destination maps are zero-copy: no fresh pages
    for i, ev in enumerate(trace):
        if ev[0] == "xfer":
            assert ev[3] == "share"
            assert trace[i + 1][0] == "unmap"
            assert trace[i + 2][0] == "map" and trace[i + 2][1] == []
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(8, "lru"))
    per_step = replay_trace(trace, iommu, kv_bytes_per_token=256,
                            compute_per_token=10.0, soc=PaperSoCConfig(),
                            dram_latency=200)
    assert len(per_step) == sum(1 for ev in trace if ev[0] == "step")


# --------------------------------------------------- jit-cache boundedness

def test_disagg_bounded_jit_cache(setup):
    """The decode worker reuses the colocated masked-decode kernel at
    FULL slot width (non-decoding rows masked), so disaggregation adds
    ZERO decode shapes — the bit-identity argument and the no-retracing
    argument are the same argument."""
    cfg, params = setup
    _, eng, _ = _serve_disagg(cfg, params, "share",
                              pressure_workload(cfg.vocab_size),
                              pool_pages=POOL)
    assert eng._decode_m._cache_size() == 1
    assert eng._prefill._cache_size() <= np.log2(64) * np.log2(4) + 1
