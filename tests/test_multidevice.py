"""Multi-device tests (shard_map SP decode, pipeline parallelism, compressed
psum, sharded train step). Run in subprocesses so conftest keeps 1 device."""
import subprocess
import sys
import textwrap

import pytest


def _run(script: str, devices: int = 4):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, "src")
    """) + textwrap.dedent(script)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540, cwd=".")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_pipeline_parallel_fwd_grad():
    _run("""
        import jax, jax.numpy as jnp
        from repro.launch.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh, mesh_context
        mesh = make_mesh((4,), ("stage",))
        W = jax.random.normal(jax.random.key(0), (8, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.key(1), (6, 2, 4, 16))
        def apply_stage(w_loc, x):
            def body(x, w): return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, w_loc)[0]
        def ref_fn(Wp):
            def one(xx):
                return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None),
                                    xx, Wp)[0]
            return jnp.sum(jnp.sin(jax.vmap(one)(x)))
        with mesh_context(mesh):
            out = jax.jit(lambda W, x: pipeline_apply(
                W, x, apply_stage, mesh))(W, x)
            g1 = jax.jit(jax.grad(lambda Wp: jnp.sum(jnp.sin(
                pipeline_apply(Wp, x, apply_stage, mesh)))))(W)
        def one(xx):
            return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), xx, W)[0]
        ref = jax.vmap(one)(x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
        g2 = jax.grad(ref_fn)(W)
        assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4
        print("PP OK")
    """)


def test_compressed_psum_close_to_exact():
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.models.dist import shard_map
        mesh = make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.key(0), (4, 256))
        def f(x):
            return compressed_psum(x, "data"), jax.lax.psum(x, "data")
        with mesh_context(mesh):
            got, exact = jax.jit(shard_map(
                f, mesh=mesh, in_specs=P("data"),
                out_specs=(P("data"), P("data"))))(x)
        rel = float(jnp.max(jnp.abs(got - exact))) / float(jnp.max(jnp.abs(exact)))
        assert rel < 0.05, rel
        print("compressed psum OK", rel)
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import TrainConfig, get_config, reduce_for_smoke
        from repro.launch.mesh import make_host_mesh, mesh_context
        from repro.launch.steps import make_train_step
        from repro.models import MeshInfo, NO_MESH, init_params
        from repro.optim import init_opt_state
        cfg = reduce_for_smoke(get_config("llama3.2-1b"))
        tc = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        params = init_params(cfg, jax.random.key(0))
        batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 32), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.key(2), (4, 32), 0,
                                              cfg.vocab_size)}
        # single device
        s1 = make_train_step(cfg, tc, NO_MESH)
        p1, o1, m1 = s1(params, init_opt_state(params), batch)
        # 2x2 mesh
        mesh = make_host_mesh(data=2, model=2)
        s2 = make_train_step(cfg, tc, MeshInfo(mesh))
        with mesh_context(mesh):
            p2, o2, m2 = jax.jit(s2)(params, init_opt_state(params), batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 1e-4, d
        print("sharded train OK", d)
    """)


def test_sp_decode_long_context():
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.models.attention import PagedKV, sp_paged_decode
        from repro.models.attention import paged_decode_attention, paged_append
        from repro.launch.mesh import make_mesh, mesh_context
        mesh = make_mesh((4, 1), ("data", "model"))
        B, Hq, Hkv, P_, T, D = 1, 4, 2, 8, 4, 16
        ks = jax.random.split(jax.random.key(0), 5)
        q = jax.random.normal(ks[0], (B, 1, Hq, D))
        kn = jax.random.normal(ks[3], (B, 1, Hkv, D))
        vn = jax.random.normal(ks[4], (B, 1, Hkv, D))
        kp = jax.random.normal(ks[1], (B, P_, T, Hkv, D))
        vp = jax.random.normal(ks[2], (B, P_, T, Hkv, D))
        tbl = jnp.broadcast_to(jnp.arange(P_, dtype=jnp.int32), (B, P_))
        ln = jnp.int32(P_ * T - 3)
        kv = PagedKV(kp, vp, tbl, ln)
        # reference on one device: append + dense paged attention
        kv_ref = paged_append(kv, kn, vn)
        ref = paged_decode_attention(q, kv_ref)
        with mesh_context(mesh):
            out, kv2 = jax.jit(lambda q, kn, vn, kv: sp_paged_decode(
                q, kn, vn, kv, mesh))(q, kn, vn, kv)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        assert float(jnp.max(jnp.abs(kv2.k_pool - kv_ref.k_pool))) < 1e-6
        print("SP decode OK", err)
    """)
