"""Per-kernel interpret-mode validation: shape/dtype sweeps vs pure-jnp
oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.axpy.kernel import axpy
from repro.kernels.axpy.ref import axpy_ref
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gemm.kernel import gemm
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.gesummv.kernel import gesummv
from repro.kernels.gesummv.ref import gesummv_ref
from repro.kernels.heat3d.kernel import heat3d_step
from repro.kernels.heat3d.ref import heat3d_step_ref
from repro.kernels.mergesort.ops import mergesort
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 384, 64, 128, 128),
    (64, 64, 256, 32, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm(m, n, k, bm, bn, bk, dtype, key):
    a = jax.random.normal(key, (m, k), dtype)
    b = jax.random.normal(jax.random.key(1), (k, n), dtype)
    out = gemm(a, b, bm=bm, bn=bn, bk=bk)
    ref = gemm_ref(a, b)
    tol = 2e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol * np.sqrt(k), rtol=tol)


@pytest.mark.parametrize("n,block", [(1024, 1024), (32768, 4096), (4096, 512)])
def test_axpy(n, block, key):
    x = jax.random.normal(key, (n,))
    y = jax.random.normal(jax.random.key(2), (n,))
    out = axpy(jnp.float32(2.5), x, y, block=block)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(axpy_ref(2.5, x, y)),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,k,bm", [(512, 512, 128), (256, 384, 64)])
def test_gesummv(n, k, bm, key):
    a = jax.random.normal(key, (n, k))
    b = jax.random.normal(jax.random.key(3), (n, k))
    x = jax.random.normal(jax.random.key(4), (k,))
    out = gesummv(1.5, -0.5, a, b, x, bm=bm)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gesummv_ref(1.5, -0.5, a, b, x)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape,bz", [((34, 34, 34), 8), ((18, 10, 12), 4)])
def test_heat3d(shape, bz, key):
    u = jax.random.normal(key, shape)
    out = heat3d_step(u, bz=bz)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(heat3d_step_ref(u)), atol=1e-5)


@pytest.mark.parametrize("n,block", [(4096, 256), (65536, 1024), (1024, 64)])
def test_mergesort(n, block, key):
    x = jax.random.normal(key, (n,))
    assert bool(jnp.all(mergesort(x, block=block) == jnp.sort(x)))
    xi = jax.random.randint(jax.random.key(5), (n,), 0, 37).astype(jnp.float32)
    assert bool(jnp.all(mergesort(xi, block=block) == jnp.sort(xi)))


@pytest.mark.parametrize("B,Hq,Hkv,n_pages,page,cap", [
    (3, 8, 2, 4, 16, None),
    (2, 4, 4, 8, 8, None),
    (1, 16, 4, 4, 32, 30.0),
])
@pytest.mark.parametrize("residency", ["smem", "hbm"])
def test_paged_attention(B, Hq, Hkv, n_pages, page, cap, residency, key):
    D = 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kp = jax.random.normal(ks[1], (B, n_pages, page, Hkv, D))
    vp = jax.random.normal(ks[2], (B, n_pages, page, Hkv, D))
    tbl = jnp.stack([jax.random.permutation(kk, n_pages)
                     for kk in jax.random.split(ks[3], B)]).astype(jnp.int32)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, n_pages * page, B), jnp.int32)
    out = paged_attention(q, kp, vp, tbl, lens, softcap=cap,
                          table_residency=residency)
    ref = paged_attention_ref(q, kp, vp, tbl, lens, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("residency", ["smem", "hbm"])
@pytest.mark.parametrize("cap", [None, 30.0])
def test_paged_attention_global_layout(residency, cap, key):
    """Shared-global-pool kernel: slots may map the SAME physical page (CoW
    prefix sharing) and unallocated entries hold the NULL sentinel."""
    from repro.kernels.paged_attention.kernel import paged_attention_global
    from repro.kernels.paged_attention.ref import paged_attention_global_ref
    B, Hq, Hkv, total, P, page, D = 3, 8, 2, 12, 4, 16, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kp = jax.random.normal(ks[1], (total, page, Hkv, D))
    vp = jax.random.normal(ks[2], (total, page, Hkv, D))
    tbl = jnp.asarray([[0, 1, 2, total],       # slot 0
                       [0, 1, 5, total],       # slot 1 SHARES pages 0, 1
                       [total] * 4],           # empty slot: all NULL
                      jnp.int32)
    lens = jnp.asarray([3 * page - 5, 2 * page + 3, 0], jnp.int32)
    out = paged_attention_global(q, kp, vp, tbl, lens, softcap=cap,
                                 table_residency=residency)
    ref = paged_attention_global_ref(q, kp, vp, tbl, lens, softcap=cap)
    np.testing.assert_allclose(np.asarray(out[:2]), np.asarray(ref[:2]),
                               atol=1e-5)


@pytest.mark.parametrize("S,Hq,Hkv,causal", [
    (128, 4, 2, True), (64, 2, 2, False), (256, 8, 2, True)])
def test_flash_kernel(S, Hq, Hkv, causal, key):
    B, D = 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = flash_attention_op(q, k, v, causal=causal, bq=64, bkv=64)
    kr = jnp.repeat(k, Hq // Hkv, axis=2)
    vr = jnp.repeat(v, Hq // Hkv, axis=2)
    ref = attention_ref(q.swapaxes(1, 2), kr.swapaxes(1, 2),
                        vr.swapaxes(1, 2), causal=causal).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
