"""Contiguity-aware allocation + range-coalesced IOTLB entries (PR 9).

Covers the allocation->translation spine end to end: the PagePool's
address-ordered free list and ``alloc_run`` (the regression the refactor
pins down: alloc/free/alloc round-trips preserve run availability), the
TranslationCache/IOMMU range entries (map-time and demand-miss coalescing,
range-granular invalidation that SPLITS a range when a subset of its pages
is unmapped — with a pre-fix-failing shape: the split test fails against
any implementation that only drops exact keys), the svasan ``stale-range``
detector, a hypothesis property asserting no range entry ever translates a
page its sequence no longer owns, replay-side miss reduction, and the
serving bit-identity contract (range-on vs range-off outputs identical in
both continuous and disaggregated modes — ranges change translation
accounting only, never data movement)."""
import dataclasses

import numpy as np
import pytest

from benchmarks.trace_replay import (replay_trace, runs_in,
                                     trace_fragmentation)
from repro.configs import get_config, reduce_for_smoke
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.sva.iommu import (IOMMU, CountingWalk, Sv39Walk, TLBConfig,
                                  WalkCacheConfig)
from repro.core.sva.kv_manager import PagedKVManager
from repro.core.sva.page_pool import OutOfPages, PagePool
from repro.core.sva.sanitizer import SanitizerError, SVASanitizer


def mk_iommu(entries=16, ranges=8, walk=None, sanitize=False):
    iommu = IOMMU(walk_model=walk or CountingWalk(),
                  tlb=TLBConfig(entries, "lru", ranges=ranges))
    if sanitize:
        iommu.sanitizer = SVASanitizer()
    return iommu


def range_keys(iommu):
    return sorted(k for k in iommu.tlb.keys() if len(k) == 3)


# ------------------------------------------------------------- PagePool

def test_free_list_is_address_ordered_and_deterministic():
    """The documented policy: free pages are handed out lowest-first, and
    ``free`` re-inserts in address order — not LIFO."""
    pool = PagePool(8, page_size=64)
    assert pool.alloc(3) == [0, 1, 2]
    assert pool.alloc(2) == [3, 4]
    pool.free([0, 1, 2])
    # LIFO would hand back 2 (or the reversed run); address order gives 0.
    assert pool.alloc(1) == [0]
    pool.check_invariants()


def test_alloc_free_alloc_round_trip_preserves_runs():
    """The satellite regression: freeing a contiguous run re-forms it in
    the free list, so the next run allocation finds it again."""
    pool = PagePool(12, page_size=64)
    a = pool.alloc_run(4)
    assert a == [0, 1, 2, 3]
    b = pool.alloc_run(4)
    assert b == [4, 5, 6, 7]
    pool.free(a)
    pool.check_invariants()
    # the freed run is whole again — and is the first fit
    assert pool.alloc_run(4) == a
    pool.free(b)
    pool.free(a)
    # interior round-trip: free a middle run while neighbours stay live
    c, d, e = pool.alloc_run(3), pool.alloc_run(3), pool.alloc_run(3)
    pool.free(d)
    assert pool.alloc_run(3) == d
    pool.free(c), pool.free(d), pool.free(e)
    pool.check_invariants()
    assert pool.n_free == 12
    assert pool.stats.run_allocs >= 6
    assert pool.stats.run_fallbacks == 0


def test_alloc_run_falls_back_when_fragmented():
    pool = PagePool(6, page_size=64)
    held = pool.alloc(6)
    # free a non-contiguous subset: {0, 2, 4}
    pool.free([held[0], held[2], held[4]])
    got = pool.alloc_run(3)
    assert got == [0, 2, 4]                      # discontiguous fallback
    assert pool.stats.run_fallbacks == 1
    # first-fit skips leading fragments to find a real run
    pool.free(got)
    pool.free([held[1], held[3]])                # free list now 0..4
    assert pool.alloc_run(2) == [0, 1]
    with pytest.raises(OutOfPages):
        pool.alloc_run(4)
    pool.check_invariants()


def test_free_runs_reports_maximal_runs():
    pool = PagePool(8, page_size=64)
    pages = pool.alloc(8)
    pool.free([pages[0], pages[1], pages[3], pages[6], pages[7]])
    assert pool.free_runs() == [(0, 2), (3, 1), (6, 2)]


def test_shared_run_refcounting_preserves_runs():
    """share/free keep run availability: a shared run only returns to the
    free list when the LAST owner drops it — and returns whole."""
    pool = PagePool(8, page_size=64)
    run = pool.alloc_run(4)
    pool.share(run)
    pool.free(run)                               # first owner
    assert pool.n_free == 4                      # still live via sharer
    pool.free(run)                               # last owner
    assert pool.alloc_run(4) == run
    pool.check_invariants()


# ------------------------------------------------- TLB/IOMMU range entries

def test_map_time_coalescing_installs_range_entries():
    iommu = mk_iommu()
    sp = iommu.attach(1)
    sp.map([10, 11, 12, 13])
    assert range_keys(iommu) == [(1, 0, 4)]
    assert iommu.tlb.range_covering(1, 2) == (0, 4)
    for lp in range(4):
        pp, cost, hit = sp.translate(lp)
        assert (pp, hit) == (10 + lp, True)
    s = iommu.stats()["range"]
    assert s["fills"] == 1 and s["coalesced_pages"] == 4
    assert s["hits"] == 4 and s["n_ranges"] == 1


def test_map_time_coalescing_caps_at_range_max():
    iommu = mk_iommu(ranges=2)
    sp = iommu.attach(1)
    sp.map(list(range(20, 25)))                  # 5 contiguous pages, cap 2
    assert all(n <= 2 for _, _, n in range_keys(iommu))
    assert sum(n for _, _, n in range_keys(iommu)) == 4   # 2+2, singleton 4


def test_discontiguous_map_warms_per_page():
    iommu = mk_iommu()
    sp = iommu.attach(1)
    sp.map([10, 12, 14])
    assert range_keys(iommu) == []
    assert sp.translate(1) == (12, 0.0, True)


def test_demand_miss_coalesces_whole_run_from_one_walk():
    iommu = mk_iommu()
    sp = iommu.attach(1)
    sp.map([30, 31, 32, 33], warm=False)         # cold TLB, table installed
    pp, _, hit = sp.translate(0)
    assert (pp, hit) == (30, False)
    assert iommu.walk_model.stats.walks == 1
    # neighbours ride the range entry the single walk installed
    for lp in (1, 2, 3):
        assert sp.translate(lp) == (30 + lp, 0.0, True)
    assert iommu.walk_model.stats.walks == 1
    s = iommu.stats()["range"]
    assert s["fills"] == 1 and s["coalesced_pages"] == 4


def test_range_aware_is_constructor_opt_in():
    """ranges=0 keeps the per-page behaviour bit-identical — no range keys
    ever appear (the walk cache also uses 3-tuple keys internally, so
    range decoding must never be inferred from key arity)."""
    iommu = mk_iommu(ranges=0,
                     walk=Sv39Walk(llc=False,
                                   walk_cache=WalkCacheConfig(8)))
    sp = iommu.attach(1)
    sp.map([10, 11, 12, 13])
    assert range_keys(iommu) == []
    assert "range" not in iommu.stats()
    assert sp.translate(2)[0] == 12


def test_ranges_coexist_with_walk_cache():
    iommu = mk_iommu(walk=Sv39Walk(llc=False,
                                   walk_cache=WalkCacheConfig(8)))
    sp = iommu.attach(1)
    sp.map([10, 11, 12, 13], warm=False)
    for lp in range(4):
        assert sp.translate(lp)[0] == 10 + lp
    assert iommu.stats()["range"]["hits"] == 3


def test_tlb_config_rejects_degenerate_ranges():
    with pytest.raises(ValueError):
        TLBConfig(4, "lru", ranges=1)
    with pytest.raises(ValueError):
        TLBConfig(4, "lru", ranges=-2)


# --------------------------------------- range-granular invalidation/split

def test_partial_unmap_splits_range():
    """THE pre-fix-failing shape: unmapping a subset of a range's pages
    must split the entry into its surviving segments. An implementation
    that only drops exact ``(asid, lp)`` keys leaves the range translating
    the dead page and fails every assertion below."""
    iommu = mk_iommu()
    sp = iommu.attach(1)
    sp.map([40, 41, 42, 43])
    assert range_keys(iommu) == [(1, 0, 4)]
    sp.unmap([1])
    # no surviving entry covers the dead page
    assert iommu.tlb.range_covering(1, 1) is None
    assert (1, 1) not in iommu.tlb
    # survivors still translate, WITHOUT a new walk (re-filled on split)
    assert sp.translate(0) == (40, 0.0, True)
    assert sp.translate(2) == (42, 0.0, True)
    assert sp.translate(3) == (43, 0.0, True)
    assert iommu.walk_model.stats.walks == 0
    # split into exact (0) + range (2,2)
    assert range_keys(iommu) == [(1, 2, 2)]
    assert iommu.stats()["range"]["splits"] == 1
    # translating the dead page is a caller error on an attached space
    with pytest.raises(KeyError):
        sp.translate(1)


def test_unmap_edge_pages_narrows_range():
    iommu = mk_iommu()
    sp = iommu.attach(1)
    sp.map([50, 51, 52, 53])
    sp.unmap([0, 3])
    assert range_keys(iommu) == [(1, 1, 2)]
    assert sp.translate(1) == (51, 0.0, True)
    assert sp.translate(2) == (52, 0.0, True)


def test_unmap_all_pages_of_range_leaves_nothing():
    iommu = mk_iommu()
    sp = iommu.attach(1)
    sp.map([50, 51])
    sp.unmap([0, 1])
    assert range_keys(iommu) == []
    assert iommu.stats()["range"]["splits"] == 0   # no survivors: a drop,
    assert iommu.tlb.n_ranges == 0                 # not a split


def test_cow_remap_splits_shared_run():
    """A CoW divergence remaps ONE logical page of a shared run: the range
    must split around it and the fresh translation must win."""
    iommu = mk_iommu()
    sp = iommu.attach(1)
    sp.map([60, 61, 62, 63])
    sp.remap(2, 99)                               # CoW: lp 2 diverges
    assert iommu.tlb.range_covering(1, 2) is None
    assert sp.translate(2) == (99, 0.0, True)
    assert sp.translate(1)[0] == 61
    assert sp.translate(3)[0] == 63
    assert iommu.stats()["range"]["splits"] == 1


def test_asid_invalidation_drops_ranges():
    iommu = mk_iommu()
    sp1, sp2 = iommu.attach(1), iommu.attach(2)
    sp1.map([10, 11, 12])
    sp2.map([20, 21, 22])
    sp1.unmap()
    assert [k[0] for k in range_keys(iommu)] == [2]
    assert iommu.tlb.n_ranges == 1
    assert sp2.translate(1)[0] == 21


def test_range_entry_eviction_cleans_index():
    """An evicted range key must leave the range index too — a stale index
    entry would 'hit' a translation the set no longer holds."""
    iommu = mk_iommu(entries=2, ranges=4)
    sp = iommu.attach(1)
    sp.map([10, 11], warm=False)
    sp.map([20, 21], start=2, warm=False)
    assert sp.translate(0)[0] == 10               # range (0,2) fills
    assert sp.translate(2)[0] == 20               # range (2,2) fills
    # thrash the 2-entry TLB with exact fills until ranges evict
    sp.map([30, 31, 32, 33], start=4, warm=False)
    for lp in (4, 5, 6, 7):
        iommu.tlb.fill((1, lp), sp.table[lp])
    assert iommu.tlb.n_ranges == len(range_keys(iommu))
    assert iommu.tlb.n_ranges <= 2


# ----------------------------------------------------- svasan stale-range

def test_stale_range_detected_by_sanitizer():
    """Injected bug: drop a table entry WITHOUT invalidating — the range
    still covers the dead page and check_unmapped must flag it."""
    iommu = mk_iommu(sanitize=True)
    sp = iommu.attach(1)
    sp.map([10, 11, 12, 13])
    assert range_keys(iommu) == [(1, 0, 4)]
    sp.table.pop(1)                               # the bug: no invalidation
    with pytest.raises(SanitizerError) as ei:
        iommu.sanitizer.check_unmapped(iommu, 1, [1])
    assert ei.value.report.detector == "stale-range"
    assert ei.value.report.key == (1, 0, 4)


def test_clean_unmap_passes_sanitizer():
    iommu = mk_iommu(sanitize=True)
    sp = iommu.attach(1)
    sp.map([10, 11, 12, 13])
    sp.unmap([1])                                 # proper split path
    sp.unmap()                                    # full teardown
    assert iommu.sanitizer.reports == []


# ------------------------------------------------- manager + property test

def mk_manager(**kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_pages_per_slot", 8)
    kw.setdefault("page_size", 4)
    kw.setdefault("layout", "global")
    kw.setdefault("sanitize", True)
    kw.setdefault("tlb_ranges", 4)
    return PagedKVManager(**kw)


def assert_no_range_outlives_ownership(mgr):
    """The tentpole's correctness surface: every resident range entry must
    translate ONLY pages the owning sequence still holds, and agree with
    the live table."""
    iommu = mgr.iommu
    for key in list(iommu.tlb.keys()):
        if len(key) != 3:
            continue
        asid, base, n = key
        sp = iommu.space(asid)
        assert sp is not None, f"range {key} for a detached ASID"
        base_ppn = iommu.tlb.peek(key)
        for off in range(n):
            assert sp.table.get(base + off) == base_ppn + off, \
                f"range {key} disagrees with the table at lp {base + off}"
            assert mgr.pool.refcount(base_ppn + off) >= 1, \
                f"range {key} translates freed page {base_ppn + off}"


def test_admit_uses_contiguity_hint():
    mgr = mk_manager()
    st = mgr.admit(0, prompt_len=12, max_tokens=4,
                   tokens=list(range(12)))
    assert st is not None
    assert runs_in(st.pages) == 1                 # fresh admit: one run
    assert mgr.stats()["pool_run_allocs"] >= 1
    mgr.release(0)


def test_cow_write_splits_run_in_manager():
    """Two sequences share a prefix run; the sharer's first divergent
    append CoW-remaps a page — no range entry may keep translating the
    pre-CoW page for the writer."""
    mgr = mk_manager()
    toks = [1, 2, 3, 4, 5, 6, 7, 8]
    a = mgr.admit(0, prompt_len=8, max_tokens=4, tokens=toks)
    b = mgr.admit(1, prompt_len=8, max_tokens=4, tokens=list(toks))
    assert a is not None and b is not None
    assert b.shared_pages >= 1
    mgr.append_token(1, 42)                       # diverge: CoW fires
    mgr.drain_cow_copies()
    assert_no_range_outlives_ownership(mgr)
    mgr.append_token(0, 43)
    assert_no_range_outlives_ownership(mgr)
    mgr.release(0)
    mgr.release(1)
    assert_no_range_outlives_ownership(mgr)
    assert mgr.sanitizer.reports == []


def test_property_no_range_translates_unowned_page():
    """Hypothesis property (the CI tier-1 job runs this file under
    REPRO_SVASAN=1): random admit/append/release interleavings over a
    shared token alphabet — prefix sharing, CoW and eviction arise
    organically — never leave a range entry translating a page its
    sequence no longer owns, and never trip a detector."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_

    ops = st_.lists(
        st_.tuples(st_.sampled_from(["admit", "append", "release"]),
                   st_.integers(0, 3),           # seq id
                   st_.integers(0, 2)),          # token alphabet
        min_size=1, max_size=60)

    @settings(max_examples=40, deadline=None)
    @given(ops=ops)
    def prop(ops):
        m = mk_manager(n_slots=3, max_pages_per_slot=6)
        live = set()
        for op, sid, tok in ops:
            if op == "admit" and sid not in live:
                got = m.admit(sid, 4, 6, tokens=[tok, tok, 7, 8])
                if got is not None:
                    live.add(sid)
            elif op == "append" and sid in live:
                if not m.seqs[sid].done:
                    m.append_token(sid, tok)
                    m.drain_cow_copies()
            elif op == "release" and sid in live:
                m.release(sid)
                live.discard(sid)
            assert_no_range_outlives_ownership(m)
        for sid in list(live):
            m.release(sid)
            assert_no_range_outlives_ownership(m)
        assert m.sanitizer.reports == []

    prop()


# ------------------------------------------------------------ trace replay

def _synthetic_trace(n_pages=8, base_pp=100):
    row = list(range(base_pp, base_pp + n_pages))
    accesses = [(0, lp, row[lp]) for lp in range(n_pages)]
    return [("map", list(row), 0, list(row)),
            ("step", accesses, n_pages),
            ("step", accesses, n_pages),
            ("unmap", 0, n_pages)]


def _replay_misses(trace, ranges):
    iommu = IOMMU(walk_model=CountingWalk(),
                  tlb=TLBConfig(4, "lru", ranges=ranges))
    replay_trace(trace, iommu, kv_bytes_per_token=64,
                 compute_per_token=1.0, soc=PaperSoCConfig(),
                 dram_latency=200)
    return iommu


def test_replay_range_reduces_demand_misses_at_equal_entries():
    """The acceptance shape: a contiguous 8-page mapping through a 4-entry
    IOTLB thrashes per-page but fits in ONE range entry."""
    trace = _synthetic_trace()
    per_page = _replay_misses(trace, ranges=0)
    ranged = _replay_misses(trace, ranges=8)
    assert ranged.tlb.stats.misses < per_page.tlb.stats.misses
    assert ranged.walk_model.stats.walks < per_page.walk_model.stats.walks
    assert ranged.stats()["range"]["coalesced_pages"] >= 8


def test_trace_fragmentation_summary():
    contiguous = _synthetic_trace()
    assert runs_in([5, 6, 7]) == 1 and runs_in([5, 7, 9]) == 3
    assert runs_in([]) == 0
    frag = trace_fragmentation(contiguous)
    assert frag["sequences"] == 1 and frag["runs_per_seq"] == 1.0
    scattered = [("map", [3, 5, 9], 1, [3, 5, 9])]
    assert trace_fragmentation(scattered)["runs_per_seq"] == 3.0
    assert trace_fragmentation([])["runs_per_seq"] == 0.0


# ------------------------------------------------- serving bit-identity

@pytest.fixture(scope="module")
def setup():
    import jax
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    from repro.models import init_params
    return cfg, init_params(cfg, jax.random.key(0))


def _serve_continuous(cfg, params, ranges):
    # shared-system-prompt workload + driver from tests/conformance.py
    from tests.conformance import prefix_workload, serve
    outs, eng, _ = serve(dataclasses.replace(cfg, serve_tlb_ranges=ranges),
                         params, "continuous",
                         prefix_workload(cfg.vocab_size), pool_pages=8,
                         translation_stats=True)
    return outs, eng


def test_continuous_serving_bit_identical_with_ranges(setup):
    cfg, params = setup
    off, _ = _serve_continuous(cfg, params, 0)
    on, eng = _serve_continuous(cfg, params, 8)
    assert on == off
    s = eng.stats()
    assert s["iommu"]["range"]["coalesced_pages"] > 0
    assert "range" not in _serve_continuous(cfg, params, 0)[1] \
        .stats()["iommu"]


@pytest.mark.parametrize("mode", ["share", "copy"])
def test_disagg_serving_bit_identical_with_ranges(setup, mode):
    from tests.conformance import prefix_workload, serve
    cfg, params = setup
    wl = prefix_workload(cfg.vocab_size, n=4)

    def serve_ranges(ranges):
        outs, eng, _ = serve(dataclasses.replace(cfg,
                                                 serve_tlb_ranges=ranges),
                             params, f"disagg-{mode}", wl,
                             translation_stats=True)
        return outs, eng

    off, _ = serve_ranges(0)
    on, eng = serve_ranges(8)
    assert on == off
    assert eng.stats()["disagg"]["transfers"] >= 1
