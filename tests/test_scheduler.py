"""Continuous-batching scheduler: token-budget steps, chunked prefill,
preemption/resume under pool pressure — and the PR's core contract: the
continuous engine's outputs are BIT-IDENTICAL to the fixed engine's for
every workload and arrival interleaving (scheduling policy never changes
tokens), including across preempt/resume round-trips.

Workload constants, the arrival-faithful driver, and the bit-identity
assertion live in tests/conformance.py (shared with test_disagg.py,
test_range_tlb.py, and the cross-engine matrix in test_conformance.py).
The interleaving property runs as fixed parameterized cases always, plus a
hypothesis-randomized version when hypothesis is installed."""
import dataclasses

import numpy as np
import pytest

from benchmarks.trace_replay import replay_trace
from repro.configs import get_config, reduce_for_smoke
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.serving.scheduler import Scheduler
from repro.core.serving.sequence_buffer import SequenceBuffer
from repro.core.sva.iommu import IOMMU, CountingWalk, TLBConfig
from repro.core.sva.kv_manager import PagedKVManager
from repro.models import init_params
from tests.conformance import (ARRIVAL_CASES, POOL, Workload,
                               pressure_workload, serve)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def setup():
    import jax
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    return cfg, init_params(cfg, jax.random.key(0))


# -------------------------------------------------------------- validation

def test_scheduler_knob_validation():
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=4, page_size=8)
    buf = SequenceBuffer(2, 32)
    with pytest.raises(ValueError):
        Scheduler(mgr, buf, token_budget=0, prefill_chunk=8)
    with pytest.raises(ValueError):
        Scheduler(mgr, buf, token_budget=8, prefill_chunk=0)
    sched = Scheduler(mgr, buf, token_budget=8, prefill_chunk=8)
    with pytest.raises(ValueError):
        sched.submit(0, [], max_tokens=4)


def test_config_sched_knob_validation():
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    with pytest.raises(ValueError):
        dataclasses.replace(cfg, sched_token_budget=0)
    with pytest.raises(ValueError):
        dataclasses.replace(cfg, sched_prefill_chunk=0)
    with pytest.raises(ValueError):
        dataclasses.replace(cfg, prefix_cache_autotune=-1)


def test_pool_pages_validation():
    with pytest.raises(ValueError):
        PagedKVManager(n_slots=2, max_pages_per_slot=4, page_size=8,
                       pool_pages=3)      # < max_pages_per_slot
    with pytest.raises(ValueError):
        PagedKVManager(n_slots=2, max_pages_per_slot=4, page_size=8,
                       pool_pages=9)      # > n_slots * max_pages_per_slot
    mgr = PagedKVManager(n_slots=2, max_pages_per_slot=4, page_size=8,
                         pool_pages=5)
    assert mgr.pool.n_pages == 5
    # a request that fits a slot but not the shrunken pool is rejected
    with pytest.raises(Exception):
        mgr.ensure_fits(prompt_len=30, max_tokens=18)


# ------------------------------------------------------------ bit-identity

def test_continuous_matches_fixed_ample_pool(setup):
    """No pool pressure: continuous (chunked prefill + masked decode)
    reproduces the fixed engine token-for-token."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size)
    fixed, _, _ = serve(cfg, params, "fixed", wl)
    cont, eng, _ = serve(cfg, params, "continuous", wl)
    assert cont == fixed
    assert eng.stats()["sched"]["preemptions"] == 0


def test_preempt_resume_bit_identical_under_pressure(setup):
    """Oversubscribed pool: the continuous engine preempts and resumes at
    least once, and STILL produces the unconstrained outputs (the KV
    rebuild after resume is content-addressed, the pending token is
    re-injected, max_tokens is rebased)."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size)
    ref, _, _ = serve(cfg, params, "fixed", wl)
    cont, eng, _ = serve(cfg, params, "continuous", wl, pool_pages=POOL)
    s = eng.stats()
    assert s["sched"]["preemptions"] >= 1
    assert s["sched"]["resumes"] >= 1
    assert s["preemptions"] == s["sched"]["preemptions"]   # mgr mirror
    assert cont == ref


def test_preemption_svasan_clean(setup):
    """The preempt path mirrors release exactly under the translation
    sanitizer: no stale-mapping, leak, or double-free reports across
    preempt/resume round-trips."""
    cfg, params = setup
    cfg = dataclasses.replace(cfg, svasan=True)
    wl = pressure_workload(cfg.vocab_size)
    cont, eng, _ = serve(cfg, params, "continuous", wl, pool_pages=POOL)
    s = eng.stats()
    assert s["sched"]["preemptions"] >= 1
    assert s["svasan"]["reports"] == 0
    assert s["svasan"]["checks"] > 0


# ----------------------------------------------------- arrival interleaving

@pytest.mark.parametrize("arrivals", ARRIVAL_CASES)
def test_interleaving_bit_identity(setup, arrivals):
    cfg, params = setup
    ref, _, _ = serve(cfg, params, "fixed", pressure_workload(cfg.vocab_size))
    cont, _, _ = serve(cfg, params, "continuous",
                       pressure_workload(cfg.vocab_size, arrivals=arrivals),
                       pool_pages=POOL)
    assert cont == ref


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 14), st.integers(1, 6),
                              st.integers(0, 3)),
                    min_size=1, max_size=4),
           st.integers(0, 2 ** 31 - 1))
    def test_interleaving_property(reqs, seed):
        """Any (prompt_len, max_tokens, arrival_gap) interleaving: the
        pool-constrained continuous engine is bit-identical to the fixed
        engine on the same requests."""
        import jax
        cfg = reduce_for_smoke(get_config("llama3.2-1b"))
        params = init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(seed)
        prompts = tuple(tuple(rng.integers(0, cfg.vocab_size,
                                           size=n).tolist())
                        for n, _, _ in reqs)
        maxtoks = tuple(m for _, m, _ in reqs)
        arrivals = tuple(np.cumsum([g for _, _, g in reqs]).tolist())
        ref, _, _ = serve(cfg, params, "fixed", Workload(prompts, maxtoks))
        cont, _, _ = serve(cfg, params, "continuous",
                           Workload(prompts, maxtoks, arrivals=arrivals),
                           pool_pages=POOL)
        assert cont == ref


# --------------------------------------------------- jit-cache boundedness

def test_bounded_jit_cache_across_mixed_burst(setup):
    """Chunked prefill buckets (suffix length, batch rows) to powers of
    two and masked decode always runs at full slot width, so a
    mixed-length burst compiles a BOUNDED set of shapes — retracing per
    request would make continuous batching slower than what it replaces."""
    cfg, params = setup
    _, eng, _ = serve(cfg, params, "continuous",
                      pressure_workload(cfg.vocab_size), pool_pages=POOL)
    assert eng._decode_m._cache_size() == 1       # one masked-decode shape
    n_prefill = eng._prefill._cache_size()
    # power-of-two buckets: suffix lengths up to max_len x row counts up
    # to n_slots
    assert n_prefill <= np.log2(64) * np.log2(4) + 1


# ------------------------------------------------------------ trace replay

def test_preemption_trace_replays_end_to_end(setup):
    """A recorded continuous-scheduler trace carries preempt/resume
    events and replays through the IOMMU cost model without error."""
    cfg, params = setup
    _, eng, _ = serve(cfg, params, "continuous",
                      pressure_workload(cfg.vocab_size), pool_pages=POOL,
                      record_translation_trace=True)
    trace = eng.translation_trace
    kinds = {ev[0] for ev in trace}
    assert {"preempt", "resume", "map", "unmap", "step"} <= kinds
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(8, "lru"))
    per_step = replay_trace(trace, iommu, kv_bytes_per_token=256,
                            compute_per_token=10.0, soc=PaperSoCConfig(),
                            dram_latency=200)
    assert len(per_step) == sum(1 for ev in trace if ev[0] == "step")
