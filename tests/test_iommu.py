"""Unified IOMMU front-end: walk-count regression, replacement policies,
trace parity between the simulator- and serving-configured IOMMUs, ASID
isolation invariants, and the no-raw-TranslationCache acceptance check."""
from pathlib import Path

import numpy as np
import pytest

from repro.configs.paper_soc import PaperSoCConfig
from repro.core.simulator.platform import H2A, MemorySystem, SimConfig
from repro.core.sva.iommu import (IOMMU, CountingWalk, Sv39Walk, TLBConfig)
from repro.core.sva.kv_manager import PagedKVManager


# ------------------------------------------------------- walk accounting

def test_fill_counts_walk_only_on_genuine_miss():
    """Regression: refreshing an already-resident key (e.g. re-warming on
    ``extend``) used to increment ``stats.walks``, inflating Fig.5-style
    walk counts; only a genuine insert is a page-table walk."""
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(8))
    tlb = iommu.tlb
    tlb.fill("k", 1)
    assert tlb.stats.walks == 1
    tlb.fill("k", 2)                       # refresh, NOT a walk
    assert tlb.stats.walks == 1
    val, hit = tlb.lookup("k")
    assert hit and val == 2

    # through the address-space API: a host pre-warm at map time is a PTE
    # write, not a device walk — and re-warming must not re-count either
    sp = iommu.attach(0)
    sp.map([40, 41])
    assert tlb.stats.walks == 1            # warm fills never count
    sp.map([40, 41])                       # re-warm (extend-style refresh)
    assert tlb.stats.walks == 1
    # the TLB's walk counter and the walk model's agree on translate traffic
    sp.translate(0)                        # hit: no walk
    assert iommu.walk_model.stats.walks == 0
    iommu.invalidate(pages=[(0, 0)])
    sp.translate(0)                        # genuine miss: both count
    assert iommu.walk_model.stats.walks == 1
    assert tlb.stats.walks == 2            # the direct fill above + this walk


def test_translate_walks_once_then_hits():
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(4))
    sp = iommu.attach(7)
    sp.map([99], warm=False)
    phys, cost, hit = sp.translate(0)
    assert (phys, hit) == (99, False)
    assert iommu.walk_model.stats.walks == 1
    phys, cost, hit = sp.translate(0)
    assert (phys, cost, hit) == (99, 0.0, True)
    assert iommu.walk_model.stats.walks == 1


def test_translate_unmapped_page_of_attached_space_raises():
    """A hole in an attached space's table is a caller error — walking it
    would cache a bogus translation in the shared TLB. Unattached ASIDs
    keep the identity fallback (the simulator's raw-page mode)."""
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(4))
    sp = iommu.attach(0)
    sp.map([10])
    with pytest.raises(KeyError):
        sp.translate(5)
    phys, _, _ = iommu.translate(1, 7)       # unattached: identity
    assert phys == 7


# ----------------------------------------------------- replacement policies

def _touch(policy, refs, entries=2):
    # unattached ASID: identity translation (the simulator's raw-page mode)
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(entries, policy))
    for r in refs:
        iommu.translate(0, r)
    return iommu


def test_lru_vs_fifo():
    """1,2,1,3 on a 2-entry TLB: LRU keeps the re-touched 1, FIFO evicts it."""
    lru = _touch("lru", [1, 2, 1, 3])
    fifo = _touch("fifo", [1, 2, 1, 3])
    assert (0, 1) in lru.tlb and (0, 2) not in lru.tlb
    assert (0, 1) not in fifo.tlb and (0, 2) in fifo.tlb


def test_lfu_keeps_hot_entry():
    """1,1,2,3 on a 2-entry TLB: LFU evicts the cold 2, keeping hot 1."""
    lfu = _touch("lfu", [1, 1, 2, 3])
    assert (0, 1) in lfu.tlb and (0, 2) not in lfu.tlb
    # plain LRU would have evicted 1 (least recent)
    lru = _touch("lru", [1, 1, 2, 3])
    assert (0, 1) not in lru.tlb


def test_random_policy_is_seeded_deterministic():
    refs = list(range(12)) * 3
    a = _touch("random", refs, entries=4)
    b = _touch("random", refs, entries=4)
    assert a.stats() == b.stats()
    assert len(a.tlb) <= 4


def test_tlb_config_validation():
    with pytest.raises(ValueError):
        TLBConfig(4, "mru")
    with pytest.raises(ValueError):
        TLBConfig(0)


# ------------------------------------------------------------ trace parity

def _record_trace():
    """One recorded page-access trace off the REAL serving manager (admit /
    decode-step gathers / CoW / release), replayable through any IOMMU."""
    mgr = PagedKVManager(n_slots=3, max_pages_per_slot=4, page_size=4)
    trace = []
    prompt = list(range(100, 110))                      # 10 tokens
    a = mgr.admit(0, 10, 4, tokens=prompt)
    trace.append(("map", list(a.pages)))
    b = mgr.admit(1, 10, 4, tokens=prompt)              # shares the prefix
    trace.append(("map", list(b.pages[b.shared_pages:])))
    for step in range(4):
        for sid in (0, 1):
            if sid in mgr.seqs and not mgr.seqs[sid].done:
                mgr.append_token(sid, step)             # may CoW
        for _, dst in mgr.drain_cow_copies():
            trace.append(("map", [dst]))
        trace.append(("step", mgr.translate_step()))
    mgr.release(0)
    c = mgr.admit(2, 8, 4, tokens=list(range(50, 58)))  # slot reuse
    trace.append(("map", list(c.pages)))
    trace.append(("step", mgr.translate_step()))
    return trace


def _replay(trace, iommu):
    for ev in trace:
        if ev[0] == "map":
            iommu.host_map_pass(ev[1])
        else:
            for slot, lp, phys in ev[1]:
                # stale hits (CoW remaps) are re-walked inside translate()
                val, _, _ = iommu.translate(slot, lp, phys=phys)
                assert val == phys
    return iommu.stats()


SIM_IOMMU = lambda: IOMMU(
    walk_model=Sv39Walk(levels=3, dram_access_cycles=235.0, llc=True,
                        to_accel=H2A, seed=0),
    tlb=TLBConfig(4, "lru"))
SERVING_IOMMU = lambda: IOMMU(walk_model=CountingWalk(),
                              tlb=TLBConfig(4096, "lru"))
RANDOM_IOMMU = lambda: IOMMU(walk_model=CountingWalk(),
                             tlb=TLBConfig(4, "random", seed=3))


@pytest.mark.parametrize("make", [SIM_IOMMU, SERVING_IOMMU, RANDOM_IOMMU],
                         ids=["simulator", "serving", "random-policy"])
def test_trace_parity_exactly_reproducible(make):
    """The SAME recorded trace through the same IOMMU config yields
    EXACTLY the same hit/miss/walk/eviction stats — and recording itself is
    deterministic."""
    t1, t2 = _record_trace(), _record_trace()
    assert t1 == t2
    assert _replay(t1, make()) == _replay(t2, make())


def test_trace_serving_config_hits_more_than_iotlb():
    """Same traffic, two design points: the serving-sized cache must hit
    at least as often as the paper's 4-entry IOTLB."""
    trace = _record_trace()
    small = _replay(trace, SIM_IOMMU())["tlb"]
    big = _replay(trace, SERVING_IOMMU())["tlb"]
    assert big["hit_rate"] >= small["hit_rate"]
    assert big["walks"] <= small["walks"]


# ------------------------------------------------------- address spaces

def test_extend_after_partial_unmap_never_remaps_live_page():
    """Regression: ``extend()`` used ``start=len(self.table)``, which after
    a partial ``unmap()`` (holes shrink the table, not the address range)
    collided with live logical pages and silently remapped them."""
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(64))
    sp = iommu.attach(0)
    sp.map([100, 101, 102, 103])
    sp.unmap([1])                          # hole: len(table)==3, max lp==3
    sp.extend([200])
    assert sp.table[3] == 103              # live page NOT remapped
    assert sp.table[4] == 200              # appended past the live max
    assert 1 not in sp.table
    phys, _, _ = sp.translate(3)
    assert phys == 103
    # an emptied space restarts at logical page 0
    sp.unmap()
    sp.extend([300])
    assert sp.table == {0: 300}


# ------------------------------------------------------- Sv39 walk model

def test_sv39_llc_warming_and_interference():
    base = dict(levels=3, dram_access_cycles=235.0, to_accel=1.0)
    off = Sv39Walk(llc=False, **base)
    assert off.walk(0, 40) == pytest.approx(3 * 235.0)
    on = Sv39Walk(llc=True, pte_evict_prob=0.0, **base)
    cold = on.walk(0, 40)            # upper levels cached, leaf line cold
    on.host_map_pass([40])           # Listing-1 map pass warms the PTE line
    warm = on.walk(0, 40)
    assert cold == pytest.approx(10 + 10 + 235.0)
    assert warm == pytest.approx(30.0)
    assert on.stats.walks == 2
    assert on.stats.cycles == pytest.approx(cold + warm)


def test_sv39_refill_installs_leaf_pte_line():
    """Regression: the walk's DRAM refill never installed the leaf PTE
    line, so a cold line stayed DRAM-priced forever even with the LLC on —
    only a host map pass could ever warm it."""
    w = Sv39Walk(levels=3, dram_access_cycles=235.0, llc=True,
                 pte_evict_prob=0.0, to_accel=1.0)
    cold = w.walk(0, 40)                  # leaf line never warmed
    warm = w.walk(0, 40)                  # the refill just installed it
    assert cold == pytest.approx(10 + 10 + 235.0)
    assert warm == pytest.approx(30.0)


def test_sv39_eviction_drops_line_then_refill_rewarns():
    """An eviction roll removes the leaf PTE line from the LLC resident
    set; the walk's refill re-installs it, so the next walk sees a warm
    line again (it must NOT 'hit' on the evicted line without a refill)."""
    w = Sv39Walk(levels=3, dram_access_cycles=235.0, llc=True,
                 pte_evict_prob=1.0, to_accel=1.0)
    w.host_map_pass([40])
    assert 40 // 8 in w.llc_resident
    assert w.walk(0, 40) == pytest.approx(10 + 10 + 235.0)   # always evicted
    w.pte_evict_prob = 0.0
    assert w.walk(0, 40) == pytest.approx(30.0)              # refill warmed it


def test_memory_system_delegates_to_iommu():
    cfg = SimConfig(soc=PaperSoCConfig(), iommu=True, llc=True)
    mem = MemorySystem(cfg)
    assert isinstance(mem.iommu.walk_model, Sv39Walk)
    assert mem.iotlb is mem.iommu.tlb
    assert mem.iommu.tlb_config.n_entries == cfg.soc.iotlb_entries
    mem.host_map_pass([0, 1, 2])
    c1, hit1 = mem.translate(0)
    assert not hit1 and c1 > 0
    c2, hit2 = mem.translate(0)
    assert hit2 and c2 == 0.0


# ----------------------------------------------------- ASID invariants

def test_unmap_one_asid_keeps_others_warm():
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(256))
    a, b = iommu.attach(1), iommu.attach(2)
    a.map([10, 11, 12])
    b.map([20, 21])
    iommu.detach(1)
    assert (1, 0) not in iommu.tlb
    for lp, pp in enumerate([20, 21]):
        assert (2, lp) in iommu.tlb              # still resident, no re-walk
        phys, _, hit = b.translate(lp)
        assert hit and phys == pp
    assert iommu.epoch == 0                      # detach is NOT a full flush
    iommu.invalidate()
    assert iommu.epoch == 1 and len(iommu.tlb) == 0


def test_iommu_hypothesis_invariants():
    hypothesis = pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)),
                    min_size=1, max_size=50))
    def prop(ops):
        iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(1024))
        tables = {}
        phys = iter(range(100, 100_000))
        flushes = 0
        for kind, a in ops:
            if kind in (0, 1):                       # map one more page
                sp = iommu.space(a) or iommu.attach(a)
                lp = len(sp.table)
                pp = next(phys)
                sp.map([pp], start=lp)
                tables.setdefault(a, {})[lp] = pp
            elif kind == 2 and a in tables:          # unmap the whole ASID
                epoch = iommu.epoch
                iommu.detach(a)
                del tables[a]
                assert iommu.epoch == epoch          # never bumps the epoch
                # unmap on one ASID NEVER invalidates another ASID's entries
                for aa, tbl in tables.items():
                    for lp in tbl:
                        assert (aa, lp) in iommu.tlb
            else:                                    # full flush
                epoch = iommu.epoch
                iommu.invalidate()
                assert iommu.epoch == epoch + 1      # bumps EXACTLY once
                assert len(iommu.tlb) == 0
                flushes += 1
            # every live translation remains correct (re-walk on demand)
            for aa, tbl in tables.items():
                for lp, pp in tbl.items():
                    got, _, _ = iommu.translate(aa, lp)
                    assert got == pp
        assert iommu.epoch == flushes

    prop()


# ------------------------------------------------------------- acceptance

def test_no_raw_translation_cache_outside_iommu():
    """API acceptance: no module outside core/sva/iommu.py instantiates a
    raw TranslationCache — everything goes through the IOMMU front-end.

    The check itself lives in ``tools/svalint`` rule R001 (an AST-based
    lint, so comments/strings mentioning the class no longer trip it);
    this test delegates so the invariant keeps running in plain pytest
    even when CI's dedicated static-analysis job is skipped."""
    from tools.svalint import lint_paths

    root = Path(__file__).resolve().parents[1]
    findings = [f for f in lint_paths(root, ["src", "benchmarks",
                                             "examples", "tests"],
                                      rules=["R001"])]
    assert not findings, "raw TranslationCache access outside the " \
        "IOMMU front-end:\n" + "\n".join(str(f) for f in findings)
