"""Adaptive translation front-end: IOTLB prefetching (issue/useful/late
accounting, never-fabricate), online geometry auto-tuning (mid-serve resize
correctness, convergence), the GDSFS size-aware replacement policy, and
adaptive-off bit-identity with the PR 4 static front-end."""
import dataclasses
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.tlb_sweep import Geometry, replay_geometry
from repro.core.sva.iommu import (IOMMU, AutoTuneConfig, CountingWalk,
                                  PrefetchConfig, Sv39Walk, TLBAutoTuner,
                                  TLBConfig, WalkCacheConfig,
                                  default_autotune_candidates)
from repro.core.sva.kv_manager import PagedKVManager, PrefixIndex
from repro.core.sva.page_pool import PagePool
from repro.core.sva.tlb import POLICIES


def _mk(entries=8, policy="lru", walk=None, prefetch=None):
    return IOMMU(walk_model=walk or CountingWalk(),
                 tlb=TLBConfig(entries, policy),
                 prefetch=prefetch or PrefetchConfig())


def _cache(entries, policy):
    """A bare TranslationCache for unit-testing replacement policies —
    obtained through the IOMMU front-end (its documented test hook), never
    constructed raw (tests/test_iommu.py enforces that repo-wide)."""
    return _mk(entries, policy).tlb


def _sv39(**kw):
    kw.setdefault("levels", 3)
    kw.setdefault("dram_access_cycles", 100.0)
    kw.setdefault("llc", False)
    kw.setdefault("to_accel", 1.0)
    return Sv39Walk(**kw)


# ----------------------------------------------------------- prefetch core

def test_prefetch_config_validation():
    with pytest.raises(ValueError):
        PrefetchConfig("nope")
    with pytest.raises(ValueError):
        PrefetchConfig("stream", degree=0)
    with pytest.raises(ValueError):
        PrefetchConfig("stream", distance=0)
    assert not PrefetchConfig().enabled
    assert PrefetchConfig("stream").enabled


def test_prefetch_off_is_bit_identical():
    """The default PrefetchConfig() reproduces the PR 4 front-end exactly:
    same stats, same TLB contents, same costs, for every access."""
    refs = [0, 1, 2, 3, 9, 1, 2, 17, 3, 0, 9, 25, 2, 4, 5, 6]
    for policy in POLICIES:
        a = IOMMU(walk_model=_sv39(), tlb=TLBConfig(4, policy))
        b = IOMMU(walk_model=_sv39(), tlb=TLBConfig(4, policy),
                  prefetch=PrefetchConfig())
        for r in refs:
            assert a.translate(0, r) == b.translate(0, r)
        assert a.stats() == b.stats()
        assert sorted(a.tlb.keys()) == sorted(b.tlb.keys())


def test_prefetch_useful_accounting_hand_trace():
    """next_page degree=2 on a hand-built sequential miss trace: the miss
    at page p issues fills for p+1/p+2; p+1 is demanded on the very next
    access (walk still in flight -> late, full cost), p+2 two accesses
    later (timely, free)."""
    iommu = _mk(entries=8, walk=_sv39(),
                prefetch=PrefetchConfig("next_page", degree=2))
    costs = [iommu.translate(0, p)[1] for p in range(6)]
    s = iommu.tlb.stats
    # pages 0 and 3 are demand misses (full 3-level walk = 300); pages 1
    # and 4 are late prefetches (full cost charged, but no second walk);
    # pages 2 and 5 are timely prefetched hits (free).
    assert costs == [300.0, 300.0, 0.0, 300.0, 300.0, 0.0]
    assert s.misses == 2 and s.hits == 4
    assert s.prefetch_issued == 4
    assert s.prefetch_useful == 4
    assert s.prefetch_late == 2
    # the TLB's demand-walk counter excludes prefetch walks; the walk
    # model's counter includes them
    assert s.walks == 2
    assert iommu.walk_model.stats.walks == 6


def test_stream_prefetch_runs_ahead_of_demand():
    """Once a +1 stride is detected the stream prefetcher triggers on HITS
    too, keeping the run-ahead window full: after the 2-access ramp every
    demand access is a prefetched hit and almost all are timely."""
    iommu = _mk(entries=16, walk=_sv39(),
                prefetch=PrefetchConfig("stream", degree=2, distance=4))
    costs = [iommu.translate(7, p)[1] for p in range(12)]
    s = iommu.tlb.stats
    assert s.misses == 2                      # the ramp (pages 0 and 1)
    assert costs[3:] == [0.0] * 9             # steady state: all timely
    assert s.prefetch_useful >= 9
    assert s.prefetch_late <= 1
    # exposed demand cost beats the no-prefetch replay of the same stream
    base = IOMMU(walk_model=_sv39(), tlb=TLBConfig(16))
    base_cost = sum(base.translate(7, p)[1] for p in range(12))
    assert sum(costs) < base_cost


def test_prefetch_fills_walk_cache():
    """A completed IOTLB prefetch installs its walk's non-leaf PTE lines
    into the Sv39 walk cache too (deferred to COMPLETION time — an
    in-flight prefetch must not warm the walk cache early), counted by the
    IOMMU-owned ``walk_cache_prefills`` stat. CountingWalk (no walk-cache
    attribute) keeps the counter at zero."""
    # The stream crosses a 2 MiB (512-page) region boundary, so the
    # run-ahead prefetch walks are the FIRST to touch the next region's
    # non-leaf lines (within one region they'd just hit the lines the
    # initial demand walk installed).
    iommu = _mk(entries=16,
                walk=_sv39(walk_cache=WalkCacheConfig(16)),
                prefetch=PrefetchConfig("stream", degree=2, distance=4))
    for p in range(504, 520):
        iommu.translate(3, p)
    s = iommu.stats()
    assert s["walk"]["prefetch"]["walk_cache_prefills"] > 0
    counting = _mk(entries=16,
                   prefetch=PrefetchConfig("stream", degree=2, distance=4))
    for p in range(504, 520):
        counting.translate(3, p)
    assert counting.stats()["walk"]["prefetch"]["walk_cache_prefills"] == 0


def test_prefetch_never_fabricates_unmapped_translation():
    """An attached address space with a hole: the prefetcher skips the
    unmapped page cleanly (no TLB entry, no walk), and demanding it still
    raises — prefetching must never manufacture a translation."""
    iommu = _mk(entries=8, prefetch=PrefetchConfig("next_page", degree=4))
    sp = iommu.attach(1)
    sp.map([50, 51], warm=False)              # lp 0,1 mapped; 2.. are holes
    iommu.translate(1, 0)                     # miss -> prefetch lp 1..4
    iommu.translate(1, 1)                     # installs pending fills
    assert (1, 1) in iommu.tlb
    for hole in (2, 3, 4):
        assert (1, hole) not in iommu.tlb
        assert (1, hole) not in iommu._pending
    assert iommu.tlb.stats.prefetch_issued == 1     # only the mapped lp 1
    with pytest.raises(KeyError):
        iommu.translate(1, 2)
    # identity (unattached) ASIDs prefetch identity, like their demand path
    iommu.translate(0, 10)
    iommu.translate(0, 11)
    phys, _, hit = iommu.translate(0, 12)
    assert phys == 12


def test_prefetch_dies_with_unmap_and_epoch():
    """In-flight prefetches are dropped by per-ASID teardown and by the
    epoch flush — a stale fill never installs after its mapping died."""
    iommu = _mk(entries=8, prefetch=PrefetchConfig("next_page", degree=2))
    sp = iommu.attach(1)
    sp.map([50, 51, 52], warm=False)
    iommu.translate(1, 0)                     # pending: lp 1, lp 2
    assert iommu._pending
    iommu.detach(1)
    assert not iommu._pending
    a = iommu.attach(2)
    a.map([60, 61, 62], warm=False)
    iommu.translate(2, 0)
    assert iommu._pending
    iommu.invalidate()                        # Listing-1 epoch flush
    assert not iommu._pending and not iommu._streams
    assert (2, 1) not in iommu.tlb


# ------------------------------------------------------------- auto-tuner

def test_autotune_config_validation():
    with pytest.raises(ValueError):
        AutoTuneConfig(interval_steps=0, candidates=(TLBConfig(4),))
    with pytest.raises(ValueError):
        AutoTuneConfig(candidates=())
    ladder = default_autotune_candidates(TLBConfig(4096))
    assert [c.n_entries for c in ladder] == [256, 1024, 4096]


def test_autotuner_explores_and_converges():
    """Working set of 8 pages, candidates 2 vs 16 entries: after exploring
    both (with a discarded warm-up window per switch) the tuner exploits
    the 16-entry geometry; every switch is a flush + epoch bump."""
    iommu = _mk(entries=2)
    tuner = TLBAutoTuner(iommu, AutoTuneConfig(
        interval_steps=1, candidates=(TLBConfig(2), TLBConfig(16))))
    for _ in range(10):
        for p in range(8):
            iommu.translate(0, p)
        tuner.observe_step()
    assert tuner.converged
    assert iommu.tlb_config.n_entries == 16
    assert tuner.switches == 1 and iommu.epoch == 1
    # monotonic cumulative stats survived the resize
    s = iommu.tlb.stats
    assert s.hits + s.misses == 80
    assert s.invalidations == 1


def test_autotuner_prefers_smaller_geometry_on_tie():
    """Identical hit rates: the tuner picks the cheaper (fewer entries)
    candidate, regardless of candidate order."""
    iommu = _mk(entries=64)
    tuner = TLBAutoTuner(iommu, AutoTuneConfig(
        interval_steps=1, candidates=(TLBConfig(64), TLBConfig(8))))
    for _ in range(10):
        for p in range(4):                    # tiny working set: both tie
            iommu.translate(0, p)
        tuner.observe_step()
    assert tuner.converged
    assert iommu.tlb_config.n_entries == 8


def test_autotune_resize_replay_deterministic():
    """The same trace + the same tuner config reproduce the same sweep row
    (switch sequence included) — trace parity extends to adaptive rows."""
    trace = []
    for step in range(12):
        trace.append(("step", [(0, lp, lp + 100) for lp in range(6)], 6))
    tune = AutoTuneConfig(interval_steps=2,
                          candidates=(TLBConfig(4), TLBConfig(16)))
    kw = dict(kv_bytes_per_token=64, compute_per_token=32.0)
    r1 = replay_geometry(trace, Geometry(4, 0, "lru", 0), autotune=tune, **kw)
    r2 = replay_geometry(trace, Geometry(4, 0, "lru", 0), autotune=tune, **kw)
    assert r1 == r2
    assert r1["adaptive"] == "static"  # label is the caller's, default kept


# --------------------------------------------------- engine-level autotune

def test_autotune_mid_serve_resize_is_bit_identical(key):
    """A geometry switch mid-serve is a flush + epoch bump and nothing
    else: decode outputs with the auto-tuner switching underneath are
    bit-identical to a static-TLB run, and the engine absorbed each switch
    as a full table upload."""
    import jax  # noqa: PLC0415 (jax-dependent test, gated like the others)

    from repro.configs import get_config, reduce_for_smoke  # noqa: PLC0415
    from repro.core.serving.engine import ServingEngine  # noqa: PLC0415
    from repro.models import init_params  # noqa: PLC0415

    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    prompts = [[5, 9, 2, 14, 3, 1], [100, 7, 9], [3, 3, 3, 8, 1, 30], [42]]

    def serve(autotune, candidates=()):
        c = dataclasses.replace(cfg, serve_tlb_autotune=autotune,
                                serve_tlb_autotune_candidates=candidates)
        eng = ServingEngine(c, params, n_slots=2, max_len=64, page_size=8)
        rids = [eng.submit(p, max_tokens=6) for p in prompts]
        done = eng.run()
        return [done[r].out_tokens for r in rids], eng.stats()

    out_static, s_static = serve(0)
    out_tuned, s_tuned = serve(1, candidates=((2, 0, "lru"), (64, 0, "lru")))
    assert out_tuned == out_static                 # placement-invariant
    at = s_tuned["iommu"]["autotune"]
    assert at["switches"] >= 1                     # it really resized
    assert s_tuned["iommu"]["epoch"] >= at["switches"]
    assert s_tuned["table_uploads_full"] >= 1 + at["switches"]
    assert "autotune" not in s_static["iommu"]


# ------------------------------------------------------------------ gdsfs

def test_gdsfs_keeps_high_walk_cost_page():
    """At equal frequency, gdsfs evicts the entry that was cheap to walk
    and keeps the expensive one — lfu (frequency only) cannot tell them
    apart and evicts by insertion order instead."""
    def build(policy):
        t = _cache(2, policy)
        t.fill("cheap", 1, cost=100.0)    # cost ignored by lfu
        t.lookup("cheap")                 # cheap: frequency 2
        t.fill("pricey", 2, cost=300.0)   # pricey: frequency 1, 3x the walk
        t.fill("new", 3, cost=100.0)      # forces one eviction
        return t

    g = build("gdsfs")                    # 2*100 < 1*300: evict cheap
    assert "pricey" in g and "cheap" not in g
    lfu = build("lfu")                    # frequency only: evict pricey
    assert "cheap" in lfu and "pricey" not in lfu

    # frequency still dominates: a hot cheap entry beats a cold pricey one
    g2 = _cache(2, "gdsfs")
    g2.fill("hot", 1, cost=100.0)
    for _ in range(5):
        assert g2.lookup("hot")[1]
    g2.fill("pricey", 2, cost=300.0)
    g2.fill("new", 3, cost=100.0)
    assert "hot" in g2 and "pricey" not in g2


def test_gdsfs_aging_clock_prevents_starvation():
    """GDSF aging: after enough evictions raise the set clock, a once-hot
    entry that stopped being used is eventually replaced by fresh
    traffic."""
    g = _cache(2, "gdsfs")
    g.fill("old", 1, cost=100.0)
    for _ in range(3):
        g.lookup("old")
    for i in range(40):                        # churning fresh traffic
        g.fill(f"n{i}", i, cost=100.0)
    assert "old" not in g


def test_gdsfs_via_iommu_uses_real_walk_costs():
    """IOMMU.translate feeds each demand walk's modeled cost into the fill,
    so a gdsfs IOTLB retains the translations that were expensive to
    produce (e.g. LLC-cold walks) over re-walkable cheap ones."""
    walker = _sv39(llc=True, pte_evict_prob=0.0)
    iommu = IOMMU(walk_model=walker, tlb=TLBConfig(2, "gdsfs"))
    walker.host_map_pass([7])                 # page 7's leaf PTE LLC-warm
    c_cheap = iommu.translate(0, 7)[1]
    c_cold = iommu.translate(0, 50)[1]        # cold: full DRAM walk
    assert c_cold > c_cheap
    iommu.translate(0, 99)                    # forces an eviction
    assert (0, 50) in iommu.tlb               # kept the expensive walk
    assert (0, 7) not in iommu.tlb


def test_gdsfs_prefix_index_sheds_partial_pages_first():
    """Size-aware prefix-cache eviction: at equal frequency a partial tail
    page covering 2 tokens frees the same page as a full 4-token page but
    saves less recompute per hit — gdsfs evicts it first, lfu (frequency
    only, recency tiebreak) evicts the older full page."""
    def build(policy):
        pool = PagePool(16, 4)
        idx = PrefixIndex(4, policy=policy)
        full = pool.alloc(1)
        idx.register([1, 2, 3, 4], full, pool)          # one full page
        partial = pool.alloc(1)
        idx.register([9, 9], partial, pool)             # partial: 2 tokens
        pool.free(full)                                 # index sole owner
        pool.free(partial)
        return pool, idx, full[0], partial[0]

    pool, idx, full_pg, part_pg = build("gdsfs")
    assert idx.evict_one(pool)
    assert part_pg in [p for p in range(16) if pool.refcount(p) == 0]
    assert pool.refcount(full_pg) == 1                  # full page survives
    pool, idx, full_pg, part_pg = build("lru")
    assert idx.evict_one(pool)
    assert pool.refcount(full_pg) == 0                  # recency: oldest dies


def test_gdsfs_in_sweep_grid_and_config_validation():
    from repro.configs import get_config  # noqa: PLC0415
    cfg = get_config("llama3.2-1b")
    ok = dataclasses.replace(cfg, serve_tlb_policy="gdsfs",
                             prefix_cache_policy="gdsfs")
    assert ok.serve_tlb_policy == "gdsfs"
    with pytest.raises(ValueError):
        dataclasses.replace(cfg, serve_tlb_policy="bogus")
    with pytest.raises(ValueError):
        dataclasses.replace(cfg, serve_tlb_prefetch_policy="bogus")
    with pytest.raises(ValueError):
        dataclasses.replace(cfg, serve_tlb_autotune=-1)
    assert "gdsfs" in POLICIES


# ----------------------------------------------- adaptive replay vs static

def _serving_shaped_trace():
    """A trace in the engine's EXTENDED format: the map events carry each
    slot's logical->physical table (what ServingEngine records), two slots
    whose decode steps scan their resident pages sequentially — the serving
    gather pattern that thrashes a small static TLB."""
    trace = []
    tables = {0: list(range(100, 112)), 1: list(range(200, 212))}
    for slot, row in tables.items():
        trace.append(("map", list(row), slot, list(row)))
    for _ in range(6):
        acc = [(slot, lp, row[lp]) for slot, row in tables.items()
               for lp in range(12)]
        trace.append(("step", acc, 24))
    return trace


def test_stream_prefetch_lowers_demand_walk_cost_on_serving_trace():
    """The tentpole claim at test scale: on a serving-shaped trace whose
    working set (24 pages) exceeds the 16-entry TLB, stream prefetch
    resolves upcoming pages through the recorded tables and turns the
    thrash misses into timely hits — demand-exposed PTW cost drops well
    below the same static geometry. The static row's demand cost equals
    its total walk cost (no off-demand walks)."""
    trace = _serving_shaped_trace()
    kw = dict(kv_bytes_per_token=64, compute_per_token=32.0)
    geom = Geometry(16, 0, "lru", 0)
    static = replay_geometry(trace, geom, **kw)
    assert static["demand_ptw_cycles"] == static["ptw_cycles"]
    assert static["adaptive"] == "static"
    pf = replay_geometry(trace, geom, **kw,
                         prefetch=PrefetchConfig("stream", degree=4,
                                                 distance=8),
                         adaptive="prefetch:stream")
    assert pf["demand_ptw_cycles"] < static["demand_ptw_cycles"]
    assert pf["prefetch_useful"] > 0
    assert pf["tlb_misses"] < static["tlb_misses"]


def test_short_map_events_still_replay_with_prefetch():
    """Hand-built traces with the SHORT ("map", pages) form stay
    replayable with prefetch armed: the prefetcher has no tables to read
    for attached... (no spaces exist), falls back to identity fills, and a
    stale identity fill is re-walked on demand — degraded, never wrong."""
    from tests.test_tlb_geometry import _record_manager_trace  # noqa: PLC0415
    trace = _record_manager_trace()
    kw = dict(kv_bytes_per_token=64, compute_per_token=32.0)
    geom = Geometry(16, 0, "lru", 0)
    pf = replay_geometry(trace, geom, **kw,
                         prefetch=PrefetchConfig("stream", degree=2,
                                                 distance=2))
    static = replay_geometry(trace, geom, **kw)
    # identical translations delivered (the row totals differ only in
    # hit/miss accounting); replay is still deterministic
    assert pf == replay_geometry(trace, geom, **kw,
                                 prefetch=PrefetchConfig("stream", degree=2,
                                                         distance=2))
    assert static["demand_ptw_cycles"] == static["ptw_cycles"]


def test_adaptive_off_replay_matches_pr4_hypothesis():
    """Hypothesis property: replaying ANY trace with every adaptive knob at
    its default produces bit-identical rows to the pre-adaptive replay —
    prefetch-off and no-tuner are true no-ops."""
    pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    access = st.tuples(st.integers(0, 3), st.integers(0, 7),
                       st.integers(0, 63))
    step = st.tuples(st.just("step"), st.lists(access, max_size=12),
                     st.integers(0, 64))
    mapev = st.tuples(st.just("map"), st.lists(st.integers(0, 63),
                                               max_size=8))

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.one_of(step, mapev), min_size=1, max_size=30),
           st.sampled_from([Geometry(4, 1, "lru", 0),
                            Geometry(8, 2, "random", 8),
                            Geometry(16, 0, "gdsfs", 4)]))
    def prop(trace, geom):
        trace = [tuple(ev) for ev in trace]
        kw = dict(kv_bytes_per_token=16, compute_per_token=8.0)
        plain = replay_geometry(trace, geom, **kw)
        off = replay_geometry(trace, geom, prefetch=PrefetchConfig(), **kw)
        assert plain == off
        assert plain["prefetch_issued"] == 0
        assert plain["demand_ptw_cycles"] == plain["ptw_cycles"]

    prop()


def test_manager_wires_prefetch_and_autotune():
    """PagedKVManager plumbs both adaptive knobs into its IOMMU and drives
    the tuner from translate_step; stats expose the autotune block."""
    mgr = PagedKVManager(
        n_slots=2, max_pages_per_slot=4, page_size=4,
        tlb_entries=4,
        tlb_prefetch=PrefetchConfig("stream", degree=2, distance=2),
        autotune=AutoTuneConfig(interval_steps=1,
                                candidates=(TLBConfig(4), TLBConfig(32))))
    mgr.admit(0, 10, 4, tokens=list(range(200, 210)))
    mgr.admit(1, 10, 4, tokens=list(range(300, 310)))
    for step in range(8):
        for sid in (0, 1):
            if not mgr.seqs[sid].done:
                mgr.append_token(sid, step)
        mgr.translate_step()
    s = mgr.stats()
    assert s["iommu"]["autotune"]["windows"] >= 1
    assert mgr.iommu.prefetch_config.policy == "stream"
    assert s["iommu"]["tlb_entries"] in (4, 32)
