"""Checkpointing (roundtrip, atomicity, async, elastic placement) and the
fault-tolerance machinery (restart loop, straggler, heartbeat, injection)."""
import pathlib
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import latest_step, restore, save
from repro.runtime.ft import (FailureInjector, HeartbeatMonitor,
                              StragglerDetector, WorkerFailure,
                              run_with_restarts)


def _state(key, scale=1.0):
    return {"w": jax.random.normal(key, (8, 16)) * scale,
            "nested": {"b": jnp.arange(4.0), "c": jnp.int32(7)}}


def test_ckpt_roundtrip(tmp_path, key):
    st = _state(key)
    save(str(tmp_path), 5, st)
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), 5, jax.tree.map(jnp.zeros_like, st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_async_and_keep(tmp_path, key):
    ex = ThreadPoolExecutor(max_workers=1)
    futs = [save(str(tmp_path), s, _state(key, s), keep=2, executor=ex)
            for s in (1, 2, 3, 4)]
    for f in futs:
        f.result()
    kept = sorted(int(p.name.split("_")[1])
                  for p in pathlib.Path(tmp_path).glob("step_*"))
    assert kept == [3, 4]
    out = restore(str(tmp_path), 4, _state(key))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(_state(key, 4.0)["w"]))


def test_ckpt_atomic_no_partial(tmp_path, key):
    save(str(tmp_path), 9, _state(key))
    # a stale tmp dir from a crashed writer must not be visible
    (pathlib.Path(tmp_path) / "step_11.tmp").mkdir()
    assert latest_step(str(tmp_path)) == 9


def test_restart_loop_restores():
    calls = []

    def loop(resume):
        calls.append(resume)
        if len(calls) < 3:
            raise WorkerFailure("boom")
        return 42

    assert run_with_restarts(loop, max_restarts=3) == 42
    assert calls == [None, -1, -1]


def test_restart_loop_gives_up():
    def loop(resume):
        raise WorkerFailure("always")
    with pytest.raises(WorkerFailure):
        run_with_restarts(loop, max_restarts=2)


def test_failure_injection_fires_once():
    inj = FailureInjector(fail_at_steps=(3,))
    for s in range(3):
        inj.check(s)
    with pytest.raises(WorkerFailure):
        inj.check(3)
    inj.check(3)          # second pass (post-restart) does not re-fire


def test_straggler_detector():
    det = StragglerDetector(window=16, factor=3.0)
    for s in range(10):
        assert not det.record(s, 1.0)
    assert det.record(10, 10.0)
    assert det.events[0]["step"] == 10


def test_heartbeats():
    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=1000)
    mon.assert_alive()
    mon.last["w1"] -= 5000
    assert mon.dead_workers() == ["w1"]
    with pytest.raises(WorkerFailure):
        mon.assert_alive()
