"""End-to-end behaviour: train with checkpoint/restart via the CLI, then
serve the trained weights — the full framework loop on CPU."""
import os
import subprocess
import sys

import pytest


def _cli(mod, args, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-m", mod] + args,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=".", env=env)
    return r


def test_train_cli_with_failure_restart(tmp_path):
    r = _cli("repro.launch.train",
             ["--arch", "llama3.2-1b", "--smoke", "--steps", "14",
              "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
              "--ckpt-every", "5", "--inject-failure-at", "7",
              "--log-every", "100"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "injected worker failure at step 7" in r.stdout
    assert "restored step 5" in r.stdout
    assert "[train] done" in r.stdout


def test_train_cli_grad_compression(tmp_path):
    r = _cli("repro.launch.train",
             ["--arch", "llama3.2-1b", "--smoke", "--steps", "6",
              "--batch", "2", "--seq", "32", "--grad-compression", "int8_ef",
              "--log-every", "100"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[train] done" in r.stdout


def test_serve_cli():
    r = _cli("repro.launch.serve",
             ["--arch", "llama3.2-1b", "--smoke", "--requests", "5",
              "--slots", "3", "--max-tokens", "6", "--prompt-len", "8"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


def test_dryrun_cli_single_cell():
    """The multi-pod dry-run proves sharding coherence for one cell (the
    full 40-cell sweep runs via --all; see EXPERIMENTS.md)."""
    r = _cli("repro.launch.dryrun",
             ["--arch", "llama3.2-1b", "--shape", "decode_32k",
              "--multi-pod", "multi", "--out", "/tmp/dryrun_test"],
             timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK " in r.stdout
