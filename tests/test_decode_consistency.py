"""The serving-correctness property: prefill(S)+decode == prefill(S+1).

MoE archs run with a large capacity factor: GShard capacity assignment
depends on the token count, so exact decode==prefill equality only holds
when no tokens are dropped (drop behavior itself is covered in
test_layers.test_moe_capacity_drops_tokens).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models import (forward_decode, forward_prefill, init_cache,
                          init_params)

ARCHS = ["llama3.2-1b", "gemma2-2b", "qwen2-7b", "olmoe-1b-7b",
         "llama-3.2-vision-90b", "rwkv6-3b", "seamless-m4t-medium",
         "jamba-1.5-large-398b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, key):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(cfg, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    extra = {}
    if cfg.n_image_tokens:
        extra["img_x"] = jax.random.normal(key, (B, cfg.n_image_tokens,
                                                 cfg.d_model))
    if cfg.is_encdec:
        extra["enc_x"] = jax.random.normal(key, (B, 16, cfg.d_model))
    src = 16 if cfg.is_encdec else 3072

    ca = init_cache(cfg, B, max_len=32, page_size=8, src_len=src)
    ref, _ = forward_prefill(cfg, params, {"tokens": toks, **extra}, ca)
    cb = init_cache(cfg, B, max_len=32, page_size=8, src_len=src)
    _, cb = forward_prefill(cfg, params, {"tokens": toks[:, :S], **extra}, cb)
    dec, _ = forward_decode(cfg, params, toks[:, S:S + 1], jnp.int32(S), cb)
    rel = float(jnp.max(jnp.abs(ref - dec))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 1e-4, rel


def test_decode_matches_prefill_sliding_window_unaligned(key):
    """Regression: prompt length NOT a multiple of the sliding window
    (20 % 8 != 0) — the ring must stay aligned (token t at slot t % window)
    so the decode append overwrites the OLDEST token, not an in-window
    one."""
    cfg = reduce_for_smoke(get_config("gemma2-2b"))
    assert cfg.sliding_window == 8
    params = init_params(cfg, key)
    B, S = 2, 20
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    ca = init_cache(cfg, B, max_len=32, page_size=8)
    ref, _ = forward_prefill(cfg, params, {"tokens": toks}, ca)
    cb = init_cache(cfg, B, max_len=32, page_size=8)
    _, cb = forward_prefill(cfg, params, {"tokens": toks[:, :S]}, cb)
    dec, _ = forward_decode(cfg, params, toks[:, S:S + 1], jnp.int32(S), cb)
    rel = float(jnp.max(jnp.abs(ref - dec))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 1e-4, rel


def test_decode_through_permuted_tables(key):
    """The SVA property: decode output is invariant to the PHYSICAL page
    placement (any block-table permutation gives identical logits)."""
    from repro.models import attention as attn

    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)

    def permute_tables(cache, seed):
        def walk(tree):
            if isinstance(tree, attn.PagedKV):
                bt = tree.block_table
                n = bt.shape[-1]
                perms = jnp.stack([
                    jax.random.permutation(jax.random.key(seed + i), n)
                    for i in range(bt.shape[-2])])
                new = jnp.broadcast_to(perms, bt.shape).astype(jnp.int32)
                return tree._replace(block_table=new)
            if isinstance(tree, dict):
                return {k: walk(v) for k, v in tree.items()}
            return tree
        return walk(cache)

    outs = []
    for seed in (0, 123):
        cache = init_cache(cfg, B, max_len=32, page_size=8)
        cache = permute_tables(cache, seed) if seed else cache
        _, cache = forward_prefill(cfg, params, {"tokens": toks[:, :S]}, cache)
        dec, _ = forward_decode(cfg, params, toks[:, S:S + 1],
                                jnp.int32(S), cache)
        outs.append(dec)
    assert float(jnp.max(jnp.abs(outs[0] - outs[1]))) < 1e-5
