"""TLB hardware geometry: set associativity (conflict misses,
fully-associative equivalence), refresh-as-use replacement accounting, the
Sv39 walk cache, and trace-parity reproducibility of the design-space
sweep over a recorded serving-manager trace."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.tlb_sweep import Geometry, replay_geometry, sweep_grid
from repro.core.sva.iommu import (IOMMU, CountingWalk, Sv39Walk, TLBConfig,
                                  WalkCacheConfig)
from repro.core.sva.kv_manager import PagedKVManager
from repro.core.sva.tlb import POLICIES


def _mk(entries, policy="lru", ways=0, seed=0):
    return IOMMU(walk_model=CountingWalk(),
                 tlb=TLBConfig(entries, policy, seed=seed, ways=ways))


# ------------------------------------------------- refresh-as-use (lfu fix)

def test_fill_refresh_counts_as_use_under_lfu():
    """Regression: re-filling a resident entry (map/extend re-warm) did not
    bump the lfu frequency, so a page kept hot by the host looked cold to
    the replacement policy and was wrongly evicted."""
    iommu = _mk(2, "lfu")
    tlb = iommu.tlb
    tlb.fill("a", 1)
    tlb.fill("a", 1)                      # refresh == a use (freq 2)
    tlb.fill("b", 1)                      # freq 1
    tlb.fill("c", 1)                      # evicts the cold b, NOT a
    assert "a" in tlb and "b" not in tlb and "c" in tlb


def test_fill_refresh_semantics_all_policies():
    """Refresh behavior is pinned per policy: lru re-ups recency, lfu
    frequency; fifo keeps insertion order; random stays seeded-
    deterministic."""
    # lru: refreshing a makes b the LRU victim
    iommu = _mk(2, "lru")
    iommu.tlb.fill("a", 1)
    iommu.tlb.fill("b", 1)
    iommu.tlb.fill("a", 2)                # refresh: a is MRU now
    iommu.tlb.fill("c", 1)
    assert "a" in iommu.tlb and "b" not in iommu.tlb
    # fifo: a refresh never reorders — a is still the oldest insertion
    iommu = _mk(2, "fifo")
    iommu.tlb.fill("a", 1)
    iommu.tlb.fill("b", 1)
    iommu.tlb.fill("a", 2)
    iommu.tlb.fill("c", 1)
    assert "a" not in iommu.tlb and "b" in iommu.tlb
    # random: same seed + same op sequence (with refreshes) => same state
    def rand_state():
        iommu = _mk(2, "random", seed=5)
        for k in ("a", "b", "a", "c", "b", "d"):
            iommu.tlb.fill(k, 1)
        return sorted(map(str, iommu.tlb.keys())), iommu.stats()
    assert rand_state() == rand_state()


# ----------------------------------------------------- set associativity

def test_fully_associative_ways_equals_entries_identical():
    """``ways == n_entries`` (and ways omitted) must reproduce the
    fully-associative behavior bit-identically, for every policy."""
    refs = [1, 2, 1, 3, 9, 1, 2, 17, 3, 1, 9, 25, 2]
    for policy in POLICIES:
        base = _mk(4, policy, seed=7)
        same = _mk(4, policy, ways=4, seed=7)
        for r in refs:
            base.translate(0, r)
            same.translate(0, r)
        assert base.stats() == same.stats()
        assert sorted(base.tlb.keys()) == sorted(same.tlb.keys())
        assert base.tlb.stats.conflict_misses == 0
        assert same.tlb.stats.conflict_misses == 0


def test_same_set_thrash_counts_conflict_misses():
    """Direct-mapped 4-entry TLB, pages 0/4/8 all land in set 0: they
    thrash one way while 3 sets sit empty — every re-miss is a conflict
    miss. The fully-associative cache of the same size absorbs all three."""
    dm = _mk(4, "lru", ways=1)
    fa = _mk(4, "lru")
    refs = [0, 4, 8, 0, 4, 8, 0, 4, 8]
    for r in refs:
        dm.translate(0, r)
        fa.translate(0, r)
    assert fa.tlb.stats.hits == 6                 # warm after first pass
    assert fa.tlb.stats.conflict_misses == 0
    assert dm.tlb.stats.hits == 0                 # same-set thrash
    # every miss after the first fill finds set 0 full while 3 sets sit
    # empty — 8 of the 9 misses are conflict misses by the documented
    # definition (set full, cache not full)
    assert dm.tlb.stats.conflict_misses == 8
    assert len(dm.tlb) == 1                       # one way of one set live
    # different sets don't conflict: pages 0..3 fill all 4 sets and stay
    dm2 = _mk(4, "lru", ways=1)
    for r in (0, 1, 2, 3) * 3:
        dm2.translate(0, r)
    assert dm2.tlb.stats.hits == 8
    assert dm2.tlb.stats.conflict_misses == 0


def test_set_occupancy_bounds():
    """No set ever exceeds ``ways``; total never exceeds ``n_entries``."""
    iommu = _mk(8, "lru", ways=2)
    for r in range(64):
        iommu.translate(0, r)
        assert len(iommu.tlb) <= 8
        assert all(len(s) <= 2 for s in iommu.tlb._sets)
    assert len(iommu.tlb) == 8                    # all sets full


def test_set_indexing_uses_logical_page_across_asids():
    """Keys are (asid, logical_page): the set is chosen by the PAGE, so two
    ASIDs touching the same page land in the same set."""
    iommu = IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(4, ways=1))
    a, b = iommu.attach(1), iommu.attach(2)
    a.map([50], warm=False)
    b.map([60], warm=False)
    a.translate(0)
    b.translate(0)                        # same page 0 -> same set: evicts
    assert (1, 0) not in iommu.tlb
    assert (2, 0) in iommu.tlb
    assert iommu.tlb.stats.evictions == 1


def test_tlb_config_ways_validation():
    with pytest.raises(ValueError):
        TLBConfig(4, ways=3)              # does not divide
    with pytest.raises(ValueError):
        TLBConfig(4, ways=8)              # exceeds entries
    assert TLBConfig(4, ways=0).resolved_ways == 4
    assert TLBConfig(8, ways=2).n_sets == 4


# ------------------------------------------------------------ walk cache

def test_walk_cache_skips_upper_levels():
    """A hit on a cached non-leaf PTE skips every level above it: same
    2 MiB region -> leaf access only; same 1 GiB region -> two accesses."""
    w = Sv39Walk(levels=3, dram_access_cycles=235.0, llc=False,
                 to_accel=1.0, walk_cache=WalkCacheConfig(8))
    assert w.walk(0, 0) == pytest.approx(3 * 235.0)       # cold: full walk
    assert w.walk(0, 1) == pytest.approx(235.0)           # L1 hit: leaf only
    assert w.walk_cache.stats.hits == 1
    assert w.walk(0, 512) == pytest.approx(2 * 235.0)     # L0 hit: 2 levels
    assert w.walk(0, 1 << 18) == pytest.approx(3 * 235.0)  # new 1 GiB region
    assert w.stats.walks == 4


def test_walk_cache_off_is_bit_identical():
    """``WalkCacheConfig(0)`` (and no config at all) reproduces the plain
    sequential walker, and the stats schema carries no walk_cache block."""
    plain = Sv39Walk(levels=3, dram_access_cycles=235.0, llc=True,
                     pte_evict_prob=0.1, to_accel=1.0, seed=3)
    off = Sv39Walk(levels=3, dram_access_cycles=235.0, llc=True,
                   pte_evict_prob=0.1, to_accel=1.0, seed=3,
                   walk_cache=WalkCacheConfig(0))
    plain.host_map_pass(range(32))
    off.host_map_pass(range(32))
    for p in list(range(32)) * 3:
        assert plain.walk(0, p) == off.walk(0, p)
    assert off.walk_cache is None
    assert "walk_cache" not in IOMMU(walk_model=off).stats()["walk"]
    on = IOMMU(walk_model=Sv39Walk(walk_cache=WalkCacheConfig(8, ways=2)))
    wc = on.stats()["walk"]["walk_cache"]
    assert wc == dict(hits=0, misses=0, evictions=0, n_entries=8, ways=2)


def test_walk_cache_geometry_is_set_associative():
    """The walk cache is a TranslationCache too: a 1-way config conflicts
    on same-set region tags where the fully-associative one holds both."""
    mk = lambda ways: Sv39Walk(levels=3, dram_access_cycles=100.0,
                               llc=False, to_accel=1.0,
                               walk_cache=WalkCacheConfig(2, ways=ways))
    fa, dm = mk(0), mk(1)
    # regions 0 and 2 (L1 tags 0 and 2) collide in a 2-set 1-way cache
    for w in (fa, dm):
        w.walk(0, 0)
        w.walk(0, 2 * 512)
        w.walk(0, 1)                       # L1 tag 0 again
    assert fa.walk_cache.stats.hits >= 1   # tag 0 still resident
    assert dm.walk_cache.stats.conflict_misses >= 1


# ----------------------------------------------------- sweep trace parity

def _record_manager_trace():
    """Engine-format translation trace (map / step+tokens / unmap) off the
    REAL serving manager — the sweep's input, without needing jax."""
    mgr = PagedKVManager(n_slots=3, max_pages_per_slot=4, page_size=4)
    trace = []
    prompt = list(range(100, 110))
    a = mgr.admit(0, 10, 4, tokens=prompt)
    trace.append(("map", list(a.pages)))
    b = mgr.admit(1, 10, 4, tokens=prompt)              # shares the prefix
    trace.append(("map", list(b.pages[b.shared_pages:])))
    for step in range(4):
        for sid in (0, 1):
            if sid in mgr.seqs and not mgr.seqs[sid].done:
                mgr.append_token(sid, step)             # may CoW
        for _, dst in mgr.drain_cow_copies():
            trace.append(("map", [dst]))
        accesses = mgr.translate_step()
        tokens = int(mgr.device_lengths().sum())
        trace.append(("step", accesses, tokens))
    st = mgr.seqs[0]
    trace.append(("unmap", st.slot, len(st.pages)))
    mgr.release(0)
    c = mgr.admit(2, 8, 4, tokens=list(range(50, 58)))
    trace.append(("map", list(c.pages)))
    trace.append(("step", mgr.translate_step(),
                  int(mgr.device_lengths().sum())))
    return trace


def test_sweep_replay_is_trace_parity_reproducible():
    """The SAME recorded manager trace through the SAME geometry yields
    EXACTLY the same sweep row — across associative, set-associative,
    walk-cached, and seeded-random design points."""
    t1, t2 = _record_manager_trace(), _record_manager_trace()
    assert t1 == t2
    for geom in (Geometry(4, 0, "lru", 0), Geometry(4, 1, "lru", 8),
                 Geometry(8, 2, "random", 8), Geometry(16, 0, "lfu", 0)):
        r1 = replay_geometry(t1, geom, kv_bytes_per_token=64,
                             compute_per_token=32.0)
        r2 = replay_geometry(t2, geom, kv_bytes_per_token=64,
                             compute_per_token=32.0)
        assert r1 == r2


def test_sweep_grid_covers_axes_without_duplicates():
    grid = sweep_grid(smoke=False)
    assert len(grid) == len({(g.entries, g.resolved_ways, g.policy,
                              g.wc_entries) for g in grid})
    assert len({g.entries for g in grid}) >= 3          # size axis
    assert len({g.resolved_ways != g.entries for g in grid}) == 2  # assoc
    assert len({g.policy for g in grid}) == len(POLICIES)
    assert len({g.wc_entries for g in grid}) >= 2       # walk-cache axis
    smoke = sweep_grid(smoke=True)
    assert 0 < len(smoke) < len(grid)


def test_sweep_geometry_differentiates_on_manager_trace():
    """The design-space claim at test scale: on a reuse-heavy serving
    trace, a larger / better-geometry IOTLB walks less."""
    trace = _record_manager_trace()
    kw = dict(kv_bytes_per_token=64, compute_per_token=32.0)
    small = replay_geometry(trace, Geometry(4, 0, "lru", 0), **kw)
    big = replay_geometry(trace, Geometry(64, 0, "lru", 0), **kw)
    assert big["walks"] <= small["walks"]
    assert big["ptw_pct_mean"] <= small["ptw_pct_mean"]
    wc = replay_geometry(trace, Geometry(4, 0, "lru", 16), **kw)
    assert wc["ptw_cycles"] < small["ptw_cycles"]       # walk cache helps
    assert wc["wc_hits"] > 0


# ------------------------------------------------- hypothesis properties

def test_geometry_hypothesis_invariants():
    hypothesis = pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(0, 31), min_size=1, max_size=120),
           st.sampled_from(POLICIES),
           st.sampled_from([(4, 1), (4, 2), (8, 2), (8, 8), (16, 4)]))
    def prop(refs, policy, geom):
        entries, ways = geom
        sa = _mk(entries, policy, ways=ways, seed=1)
        fa = _mk(entries, policy, ways=entries, seed=1)
        df = _mk(entries, policy, seed=1)
        for r in refs:
            sa.translate(0, r)
            fa.translate(0, r)
            df.translate(0, r)
            # occupancy bounds hold at every step
            assert all(len(s) <= sa.tlb.ways for s in sa.tlb._sets)
            assert len(sa.tlb) <= entries
        # ways == n_entries is bit-identical to the default (fully assoc)
        assert fa.stats() == df.stats()
        assert sorted(fa.tlb.keys()) == sorted(df.tlb.keys())
        # fully-associative caches never record a conflict miss
        assert fa.tlb.stats.conflict_misses == 0
        # every miss walked, every access either hit or missed
        s = sa.tlb.stats
        assert s.hits + s.misses == len(refs)
        assert s.walks == s.misses == sa.walk_model.stats.walks
        assert s.conflict_misses <= s.misses

    prop()


def test_walk_cache_hypothesis_accounting():
    hypothesis = pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=60),
           st.sampled_from([2, 4, 8]))
    def prop(pages, wc_entries):
        w = Sv39Walk(levels=3, dram_access_cycles=100.0, llc=False,
                     to_accel=1.0, walk_cache=WalkCacheConfig(wc_entries))
        plain = Sv39Walk(levels=3, dram_access_cycles=100.0, llc=False,
                         to_accel=1.0)
        for p in pages:
            cost = w.walk(0, p)
            # a walk always pays the leaf access and never MORE than the
            # cache-less walker
            assert 100.0 <= cost <= plain.walk(0, p)
        assert w.stats.walks == len(pages)
        wc = w.walk_cache.stats
        # a walk probes the deepest non-leaf level, plus the root on a
        # miss: 1..2 probes per walk, all accounted as hits or misses
        assert len(pages) <= wc.hits + wc.misses <= 2 * len(pages)
        assert len(w.walk_cache) <= wc_entries

    prop()


def test_sweep_hypothesis_trace_parity():
    hypothesis = pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    access = st.tuples(st.integers(0, 3), st.integers(0, 7),
                       st.integers(0, 63))
    step = st.tuples(st.just("step"), st.lists(access, max_size=12),
                     st.integers(0, 64))
    mapev = st.tuples(st.just("map"), st.lists(st.integers(0, 63),
                                               max_size=8))

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.one_of(step, mapev), min_size=1, max_size=30),
           st.sampled_from([Geometry(4, 1, "lru", 0),
                            Geometry(8, 2, "random", 8),
                            Geometry(16, 0, "lfu", 4)]))
    def prop(trace, geom):
        trace = [tuple(ev) for ev in trace]
        kw = dict(kv_bytes_per_token=16, compute_per_token=8.0)
        assert replay_geometry(trace, geom, **kw) == \
            replay_geometry(trace, geom, **kw)

    prop()
