"""Cross-engine conformance matrix over the shared harness
(tests/conformance.py): every engine kind (fixed, continuous,
disagg-share, disagg-copy) x tenancy mode (off, single-tenant deployment,
two tenants) must reproduce the unconstrained fixed engine's outputs
token-for-token on the canonical pressure workload.

The single-tenant column is the PR's compatibility acceptance: a
deployment description with one tenant and partitioning OFF routes every
request through the TenantDomain/quota/isolation machinery and still
matches today's engines bit-for-bit. The two-tenant column adds ASID
isolation across interleaved tenants with quotas and partitions off —
isolation bookkeeping alone never changes tokens."""
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.configs.deployment import DeploymentConfig, TenantSpec
from repro.models import init_params
from tests.conformance import (ARRIVAL_CASES, ENGINE_KINDS, POOL,
                               assert_bit_identical, make_engine,
                               pressure_workload, serve)

TENANCIES = ("off", "single", "two")


@pytest.fixture(scope="module")
def setup():
    import jax
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    return cfg, init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def ref(setup):
    """Ground truth, computed once: unconstrained fixed engine,
    untenanted."""
    cfg, params = setup
    outs, _, _ = serve(cfg, params, "fixed",
                       pressure_workload(cfg.vocab_size))
    return outs


def _tenancy(cfg, kind, tenancy, n_req=6):
    """(compiled cfg, engine tenants dict, per-request tenant names,
    pool_pages) for one matrix cell. Quotas equal the whole pool and
    partitioning stays off, so tenancy adds bookkeeping, never behavior."""
    pool = POOL if kind != "fixed" else None
    if tenancy == "off":
        return cfg, None, None, pool
    if tenancy == "single":
        dep = DeploymentConfig((TenantSpec("t0", pool_share=1.0),))
        quota_base = pool if pool is not None else 32   # 4 slots x 8 pages
        return (dep.compile(cfg), dep.tenant_dict(quota_base),
                ("t0",) * n_req, pool)
    # two tenants, interleaved per request, no quotas/partitions
    return (cfg, {"a": {}, "b": {}},
            tuple("ab"[i % 2] for i in range(n_req)), pool)


@pytest.mark.parametrize("tenancy", TENANCIES)
@pytest.mark.parametrize("kind", ENGINE_KINDS)
def test_cross_engine_matrix(setup, ref, kind, tenancy):
    cfg, params = setup
    ecfg, tenants, names, pool = _tenancy(cfg, kind, tenancy)
    wl = pressure_workload(cfg.vocab_size, tenants=names)
    outs, eng, _ = serve(ecfg, params, kind, wl, tenants=tenants,
                         pool_pages=pool)
    assert outs == ref
    if tenants is not None:
        # every tenant served and the isolation gate saw no denials
        s = eng.stats()["tenant"]
        assert sorted(s) == sorted(tenants)
        assert all(b["denials"] == 0 for b in s.values())
        assert sum(b["seqs"] for b in s.values()) == 0   # all released


@pytest.mark.parametrize("arrivals", ARRIVAL_CASES)
def test_two_tenant_interleavings_bit_identical(setup, ref, arrivals):
    """Tenancy under every arrival interleaving: staggered cross-tenant
    admission still reproduces the untenanted outputs."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size, arrivals=arrivals,
                           tenants=tuple("ab"[i % 2] for i in range(6)))
    outs, _, _ = serve(cfg, params, "continuous", wl,
                       tenants={"a": {}, "b": {}}, pool_pages=POOL)
    assert outs == ref


def test_assert_bit_identical_entrypoint(setup):
    """The harness's own assertion helper: two fresh engines of different
    kinds, one workload."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size)
    assert_bit_identical(make_engine(cfg, params, "fixed"),
                         make_engine(cfg, params, "continuous",
                                     pool_pages=POOL),
                         wl)


def test_assert_bit_identical_detects_divergence(setup):
    """The helper actually fails on divergent engines (different request
    mix via truncated max_tokens)."""
    cfg, params = setup
    wl = pressure_workload(cfg.vocab_size)
    short = pressure_workload(cfg.vocab_size)
    short = type(short)(short.prompts, tuple(m - 1 for m in short.maxtoks))

    class Clipped:
        """Engine proxy that serves the clipped workload instead."""

        def __init__(self, eng):
            self._eng = eng
            self._i = 0

        def submit(self, prompt, max_tokens=16, tenant=None):
            m = short.maxtoks[self._i % len(short.maxtoks)]
            self._i += 1
            return self._eng.submit(prompt, max_tokens=m, tenant=tenant)

        def __getattr__(self, name):
            return getattr(self._eng, name)

    with pytest.raises(AssertionError):
        assert_bit_identical(make_engine(cfg, params, "fixed"),
                             Clipped(make_engine(cfg, params, "fixed")),
                             wl)
