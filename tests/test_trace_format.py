"""Trace event-schema validation (benchmarks/trace_replay.py): malformed
events fail with :class:`TraceFormatError` naming the event index and the
expected shape — the regression this pins is a truncated or hand-edited
trace dying as an anonymous unpacking ``ValueError`` (or replaying as
silently wrong cycle numbers)."""
import pytest

from benchmarks.trace_replay import TraceFormatError, replay_trace
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.sva.iommu import IOMMU, CountingWalk, TLBConfig

SOC = PaperSoCConfig()


def mk_iommu():
    return IOMMU(walk_model=CountingWalk(), tlb=TLBConfig(8, "lru"))


def replay(trace):
    return replay_trace(trace, mk_iommu(), kv_bytes_per_token=1024,
                        compute_per_token=10.0, soc=SOC, dram_latency=200)


def test_well_formed_trace_replays():
    per_step = replay([
        ("map", [0, 1, 2]),                      # short form
        ("map", [3, 4], 1, [3, 4]),              # extended form
        ("step", [(0, 0, 0), (1, 0, 3)], 2),
        ("unmap", 0, 3),
        ("step", [(1, 1, 4)], 1),
    ])
    assert len(per_step) == 2
    assert all(cycles > 0 for _, cycles in per_step)


def test_preemption_bearing_trace_replays():
    # The continuous scheduler annotates pool-pressure preemption with
    # ("preempt", sid) before the victim's "unmap" and ("resume", sid,
    # pages) before the re-admission "map". Annotations must not change
    # replay numbers: same step stream -> same per-step costs.
    base = [
        ("map", [0, 1], 0, [0, 1]),
        ("step", [(0, 0, 0), (0, 1, 1)], 2),
        ("unmap", 0, 2),
        ("map", [2, 3], 0, [2, 3]),
        ("step", [(0, 0, 2)], 1),
    ]
    annotated = [
        base[0], base[1],
        ("preempt", 7),
        base[2],
        ("resume", 7, [2, 3]),
        base[3], base[4],
    ]
    assert replay(annotated) == replay(base)


def test_xfer_bearing_trace_replays():
    # A disaggregated migration annotates the hand-off with ("xfer", sid,
    # n_pages, mode) before the source "unmap" and destination "map" that
    # carry its translation consequences. Like preempt/resume, the
    # annotation must not change replay numbers.
    base = [
        ("map", [0, 1], 0, [0, 1]),
        ("unmap", 0, 2),
        ("map", [], 2, [0, 1]),                # share: same physical pages
        ("step", [(2, 0, 0), (2, 1, 1)], 2),
    ]
    annotated = [base[0], ("xfer", 7, 2, "share")] + base[1:]
    assert replay(annotated) == replay(base)


@pytest.mark.parametrize("bad", [
    ("map",),                     # missing pages
    ("map", [0], 1),              # extended form missing the table row
    ("step", [(0, 0)], 1),        # access pair, not (slot, lp, phys)
    ("step", 5, 1),               # accesses not a sequence
    ("step", [(0, 0, 0)], "2"),   # tokens not a number
    ("unmap", 0),                 # missing n_pages
    ("unmap", "slot0", 3),        # slot not an int
    ("teardown", 0, 3),           # unknown event kind
    ("preempt",),                 # missing seq_id
    ("preempt", "seq7"),          # seq_id not an int
    ("resume", 7),                # missing pages
    ("resume", 7, 3),             # pages not a sequence
    ("xfer", 7, 2),               # missing mode
    ("xfer", 7, 2, "move"),       # mode not copy/share
    ("xfer", "seq7", 2, "copy"),  # seq_id not an int
    ("xfer", 7, "2", "copy"),     # n_pages not an int
    "unmap",                      # event not a tuple
    (),                           # empty event
])
def test_malformed_event_raises_named_error(bad):
    trace = [("map", [0, 1, 2]), bad]
    with pytest.raises(TraceFormatError) as ei:
        replay(trace)
    err = ei.value
    assert err.index == 1                   # names the offending event
    assert "trace event 1" in str(err)
    assert "expected" in str(err)


def test_error_carries_expected_shape():
    with pytest.raises(TraceFormatError) as ei:
        replay([("unmap", 0)])
    assert '("unmap", slot, n_pages)' in ei.value.expected


def test_xfer_error_carries_expected_shape():
    with pytest.raises(TraceFormatError) as ei:
        replay([("xfer", 7, 2, "move")])
    assert '("xfer", seq_id, n_pages, mode)' in ei.value.expected


def test_unknown_tag_error_names_the_tag():
    # "teardown" vs "unmap" should read as a TAG problem at a glance —
    # the error must quote the offending tag, not just list valid shapes.
    with pytest.raises(TraceFormatError) as ei:
        replay([("teardown", 0, 3)])
    assert "'teardown'" in str(ei.value)


def test_malformed_access_deep_in_step_names_event_index():
    trace = [("map", [0, 1]),
             ("step", [(0, 0, 0)], 1),
             ("step", [(0, 0, 0), (0, 1)], 2)]   # second access malformed
    with pytest.raises(TraceFormatError) as ei:
        replay(trace)
    assert ei.value.index == 2
