"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step + prefill + decode on CPU; shape and finiteness checks."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.models import (forward_decode, forward_prefill, forward_train,
                          init_cache, init_params)
from repro.launch.steps import make_train_step
from repro.configs import TrainConfig
from repro.models import NO_MESH
from repro.optim import init_opt_state


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.n_image_tokens:
        batch["img_x"] = jax.random.normal(key, (B, cfg.n_image_tokens,
                                                 cfg.d_model))
    if cfg.is_encdec:
        batch["enc_x"] = jax.random.normal(key, (B, 16, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_serve(arch, key):
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)

    loss = forward_train(cfg, params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss)

    cache = init_cache(cfg, B, max_len=S + 8, page_size=8,
                       src_len=16 if cfg.is_encdec else 3072)
    logits, cache = forward_prefill(cfg, params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = forward_decode(cfg, params, tok, jnp.int32(S), cache)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "olmoe-1b-7b", "rwkv6-3b",
                                  "jamba-1.5-large-398b"])
def test_full_train_step(arch, key):
    """fwd+bwd+AdamW actually updates parameters and reduces nothing to NaN."""
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    step = make_train_step(cfg, TrainConfig(lr=1e-3, total_steps=10,
                                            warmup_steps=1), NO_MESH)
    batch = _batch(cfg, key)
    p1, opt, m1 = step(params, opt, batch)
    p2, opt, m2 = step(p1, opt, batch)
    assert jnp.isfinite(m1["loss"]) and jnp.isfinite(m2["loss"])
    # params changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                                        - b.astype(jnp.float32)))),
                     params, p2))
    assert delta > 0
    # loss on the SAME batch should drop after two updates
    l3 = forward_train(cfg, p2, batch)
    assert float(l3) < float(m1["loss"])
