import os
import sys

# tests see the real device count (1 CPU); only dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
