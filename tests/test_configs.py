"""Registry + parameter-count sanity vs published model sizes."""
import pytest

from repro.configs import (ARCH_IDS, SHAPES, all_cells, get_config,
                           model_active_params, model_params,
                           reduce_for_smoke)

PUBLISHED_B = {  # (total, active), in billions, ±12% tolerance
    "llama3.2-1b": (1.24, 1.24),
    "gemma2-2b": (2.6, 2.6),
    "llama3.2-3b": (3.2, 3.2),
    "qwen2-7b": (7.6, 7.6),
    "olmoe-1b-7b": (6.9, 1.3),
    "kimi-k2-1t-a32b": (1000.0, 32.0),
    "llama-3.2-vision-90b": (88.0, 88.0),
    "rwkv6-3b": (3.0, 3.0),
    "seamless-m4t-medium": (1.0, 1.0),
    "jamba-1.5-large-398b": (398.0, 94.0),
}


def test_all_archs_load():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.n_blocks * len(cfg.block_pattern) + cfg.first_k_dense \
            == cfg.n_layers


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    total, active = PUBLISHED_B[arch]
    n = model_params(cfg) / 1e9
    na = model_active_params(cfg) / 1e9
    assert abs(n - total) / total < 0.12, (n, total)
    assert abs(na - active) / active < 0.12, (na, active)


def test_cells():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2] is None]
    assert len(runnable) == 33
    skipped = {(a, s) for a, s, r in cells if r is not None}
    assert all(s == "long_500k" for _, s in skipped)
    assert ("rwkv6-3b", "long_500k") not in skipped       # ssm runs long
    assert ("jamba-1.5-large-398b", "long_500k") not in skipped
    assert ("gemma2-2b", "long_500k") not in skipped      # local/global runs


def test_shapes():
    assert SHAPES["train_4k"].lowers == "train_step"
    assert SHAPES["decode_32k"].lowers == "serve_step"
    assert SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduction_preserves_family(arch):
    cfg = get_config(arch)
    small = reduce_for_smoke(cfg)
    assert small.family == cfg.family
    assert small.block_pattern == cfg.block_pattern
    assert (small.moe is None) == (cfg.moe is None)
    assert (small.ssm is None) == (cfg.ssm is None)
    assert small.is_encdec == cfg.is_encdec
