"""Benchmark E6 — trace-driven translation design-space sweep (Kim et al.),
static grid + adaptive front-end rows.

Records real serving translation traces (``ServingEngine(
record_translation_trace=True)``) for three deployment profiles — a
prefix-heavy mix (shared system prompt, CoW divergence), an all-unique
mix (no cross-request reuse), and a continuous-batching mix served over an
oversubscribed page pool (its trace bears preempt/resume events around
real ASID teardown/re-mapping) — then replays each trace through the
unified IOMMU front-end across a grid of hardware geometries:

  IOTLB entries x set associativity (ways) x replacement policy
  x walk-cache size (non-leaf Sv39 PTE cache)

The walker is ``Sv39Walk(llc=False)`` — the no-LLC platform where the
paper pays 4.2-17.6% of accelerator runtime for translation, i.e. exactly
the regime where IOTLB/walk-cache geometry decides the design space (with
LLC-resident PTEs the walker is ~free and every geometry ties). Every
replay of the same trace is bit-reproducible: the walker draws no RNG with
the LLC off, the ``random`` policy is seeded, and the prefetcher/tuner are
deterministic.

After the static grid, the ADAPTIVE rows replay the same traces with the
IOTLB *prefetcher* (``stream`` / ``next_page``) and with the online
geometry *auto-tuner* enabled, so static-vs-adaptive is one CSV: the
``adaptive`` column labels the row, ``demand_ptw_cycles`` is the
demand-exposed translation cost (what a prefetcher actually lowers; equal
to ``ptw_cycles`` for static rows), and the ``prefetch_*`` columns carry
the issued/useful/late counters. See ``benchmarks/README.md`` for the
full column contract.

Emits the grid + adaptive rows as CSV (``--out``, default
``tlb_sweep.csv``) and prints summary rows: PTW overhead as a % of modeled
decode-step runtime per geometry axis, the best static geometry per
deployment, and the adaptive rows' win/loss against it.

After the adaptive rows, one RANGE row per deployment prices the same
trace with range-coalesced IOTLB entries (``TLBConfig(ranges=N)``,
``--ranges``) against the per-page 4-entry baseline at EQUAL entry count —
the ``range_entries`` / ``coalesced_pages`` / ``range_splits`` columns
carry the coalescing counters (zero on per-page rows), and the
``tlb_sweep.range.<deployment>`` summary rows print the demand-miss and
demand-PTW-cycle deltas.

``--smoke`` shrinks the grid and the recorded workload (CI smoke path —
wired into ``benchmarks/run.py --only sweep`` and the figure-benchmarks
job).
"""
from __future__ import annotations

import argparse
import csv
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.trace_replay import replay_trace
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.simulator.platform import H2A
from repro.core.sva.iommu import (IOMMU, AutoTuneConfig, PrefetchConfig,
                                  Sv39Walk, TLBAutoTuner, TLBConfig,
                                  WalkCacheConfig)
from repro.core.sva.tlb import POLICIES


@dataclass(frozen=True)
class Geometry:
    """One IOTLB + walk-cache design point of the sweep grid."""
    entries: int
    ways: int                 # 0 = fully associative
    policy: str
    wc_entries: int           # 0 = walk cache off
    ranges: int = 0           # 0 = per-page entries; else max coalesced run

    @property
    def resolved_ways(self) -> int:
        return self.ways or self.entries

    def label(self) -> str:
        w = "full" if self.resolved_ways == self.entries else str(self.ways)
        r = f".r{self.ranges}" if self.ranges else ""
        return (f"e{self.entries}.w{w}.{self.policy}.wc{self.wc_entries}{r}")


def sweep_grid(smoke: bool = False) -> List[Geometry]:
    """entries x ways x policy x walk-cache size; degenerate ways (== entries)
    collapse onto the fully-associative point so no geometry is replayed
    twice."""
    if smoke:
        entries, ways = (4, 16), (1, 0)
        policies, wcs = ("lru", "fifo"), (0, 8)
    else:
        entries, ways = (4, 8, 16, 64), (1, 2, 4, 0)
        policies, wcs = POLICIES, (0, 8, 32)
    out: List[Geometry] = []
    seen = set()
    for e in entries:
        for w in ways:
            if w and (w > e or e % w):
                continue
            rw = w or e
            for p in policies:
                for wc in wcs:
                    key = (e, rw, p, wc)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(Geometry(e, 0 if rw == e else w, p, wc))
    return out


# --------------------------------------------------------------- recording

def record_traces(dry_run: bool = False) -> Tuple[Dict[str, list], dict]:
    """Serve three deployment profiles with translation tracing ON. Returns
    ({deployment: trace}, cost model constants for the replay). The
    ``continuous`` profile serves through the continuous-batching scheduler
    over an oversubscribed page pool, so its trace bears
    ``("preempt", ...)`` / ``("resume", ...)`` annotations around real ASID
    teardown/re-mapping — the replay path is exercised on preemption-bearing
    traces even at ``--smoke`` scale."""
    # Lazy: recording needs jax + the serving engine; replay does not.
    from benchmarks.paged_serving import (_BURST_POOL,  # noqa: PLC0415
                                          _cfg_params,
                                          _prefix_heavy_prompts)
    from repro.core.serving.engine import ServingEngine  # noqa: PLC0415

    n_req, max_tokens = (4, 4) if dry_run else (10, 10)
    cfg, params = _cfg_params()
    soc = PaperSoCConfig()

    def serve(prompts, **engine_kw):
        eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                            record_translation_trace=True, **engine_kw)
        for p in prompts:
            eng.submit(p, max_tokens=max_tokens)
        eng.run()
        return eng, eng.translation_trace

    eng, prefix_trace = serve(_prefix_heavy_prompts(n_req, cfg.vocab_size))
    rng = np.random.default_rng(11)
    unique = [rng.integers(0, cfg.vocab_size,
                           size=int(rng.integers(8, 30))).tolist()
              for _ in range(n_req)]
    _, unique_trace = serve(unique)
    _, cont_trace = serve(_prefix_heavy_prompts(n_req, cfg.vocab_size),
                          scheduler="continuous", pool_pages=_BURST_POOL)

    n_attn = sum(1 for k in cfg.layer_kinds() if "attn" in k)
    consts = dict(
        kv_bytes_per_token=eng.mgr.kv_bytes_per_token,
        # decode attention: ~4 flops per KV token per head-dim per layer
        compute_per_token=4 * cfg.n_heads * cfg.d_head * n_attn / soc.n_pes)
    return {"prefix_heavy": prefix_trace, "unique": unique_trace,
            "continuous": cont_trace}, consts


# ----------------------------------------------------------------- replay

def replay_geometry(trace, geom: Geometry, kv_bytes_per_token: int,
                    compute_per_token: float, dram_latency: int = 200,
                    soc: PaperSoCConfig = None,
                    prefetch: Optional[PrefetchConfig] = None,
                    autotune: Optional[AutoTuneConfig] = None,
                    adaptive: str = "static") -> dict:
    """Price one recorded serving trace under one hardware geometry —
    optionally with the IOTLB prefetcher and/or the online geometry
    auto-tuner armed (``geom`` is then the STARTING geometry). Returns the
    CSV row: TLB/walk-cache stats + PTW overhead as a % of each modeled
    decode step's accelerator runtime. ``demand_ptw_cycles`` is the
    demand-exposed translation cost (what prefetching lowers);
    ``ptw_cycles`` stays the walk model's total, which for adaptive rows
    also contains the prefetch walks done off the demand path."""
    soc = soc or PaperSoCConfig()
    walker = Sv39Walk(
        levels=soc.ptw_levels,
        dram_access_cycles=dram_latency + soc.dram_base_latency,
        llc=False, to_accel=H2A,
        walk_cache=WalkCacheConfig(geom.wc_entries, policy="lru"))
    iommu = IOMMU(walk_model=walker,
                  tlb=TLBConfig(geom.entries, geom.policy, ways=geom.ways,
                                ranges=geom.ranges),
                  prefetch=prefetch or PrefetchConfig())
    tuner = TLBAutoTuner(iommu, autotune) if autotune is not None else None
    per_step = replay_trace(trace, iommu, kv_bytes_per_token,
                            compute_per_token, soc, dram_latency,
                            tuner=tuner)
    pcts = [100.0 * ptw / max(step, 1e-9) for ptw, step in per_step]
    tlb = iommu.tlb.stats
    wc = walker.walk_cache.stats if walker.walk_cache is not None else None
    row = dict(
        n_entries=geom.entries, ways=geom.resolved_ways, policy=geom.policy,
        wc_entries=geom.wc_entries,
        tlb_hits=tlb.hits, tlb_misses=tlb.misses,
        conflict_misses=tlb.conflict_misses,
        hit_rate=round(tlb.hit_rate, 4),
        walks=walker.stats.walks,
        wc_hits=wc.hits if wc else 0, wc_misses=wc.misses if wc else 0,
        ptw_cycles=round(walker.stats.cycles, 1),
        ptw_pct_mean=round(float(np.mean(pcts)) if pcts else 0.0, 3),
        ptw_pct_max=round(float(np.max(pcts)) if pcts else 0.0, 3),
        adaptive=adaptive,
        prefetch_issued=tlb.prefetch_issued,
        prefetch_useful=tlb.prefetch_useful,
        prefetch_late=tlb.prefetch_late,
        demand_ptw_cycles=round(sum(p for p, _ in per_step), 1),
        # range-coalescing counters (all zero on per-page rows)
        range_entries=iommu.range_fills,
        coalesced_pages=iommu.coalesced_pages,
        range_splits=iommu.range_splits)
    if tuner is not None:
        ts = tuner.stats()
        row["n_entries"] = ts["current"]["n_entries"]   # converged geometry
        row["ways"] = ts["current"]["ways"]
        row["policy"] = ts["current"]["policy"]
        row["_tuner"] = ts                              # not a CSV column
    return row


FIELDS = ("deployment", "n_entries", "ways", "policy", "wc_entries",
          "tlb_hits", "tlb_misses", "conflict_misses", "hit_rate", "walks",
          "wc_hits", "wc_misses", "ptw_cycles", "ptw_pct_mean",
          "ptw_pct_max", "adaptive", "prefetch_issued", "prefetch_useful",
          "prefetch_late", "demand_ptw_cycles", "range_entries",
          "coalesced_pages", "range_splits")


def adaptive_rows(trace, best_geom: Geometry, consts: dict,
                  dram_latency: int, smoke: bool = False) -> List[dict]:
    """Replay one trace with the adaptive front-end armed: stream /
    next_page prefetching on both the paper's 4-entry IOTLB and the best
    static geometry, plus the online auto-tuner walking an entries ladder.
    Returns CSV rows (``adaptive`` column labels each configuration)."""
    out: List[dict] = []
    paper = Geometry(4, 0, "lru", 0)
    # The run-ahead distance must fit the IOTLB: on the paper's 4-entry
    # geometry the stream prefetcher runs 2 ahead (more would evict its own
    # unused fills); on the sweep's best static geometry it can run deep.
    pf_points = [("prefetch:next_page:d2", paper,
                  PrefetchConfig("next_page", degree=2)),
                 ("prefetch:stream:d2", paper,
                  PrefetchConfig("stream", degree=2, distance=2)),
                 ("prefetch:stream:d4+best", best_geom,
                  PrefetchConfig("stream", degree=4, distance=8))]
    for label, geom, pf in pf_points:
        out.append(replay_geometry(trace, geom, dram_latency=dram_latency,
                                   prefetch=pf, adaptive=label, **consts))
    ladder = (4, 16) if smoke else (4, 16, 64)
    cands = tuple(TLBConfig(e, "lru") for e in ladder)
    tune = AutoTuneConfig(interval_steps=1 if smoke else 4,
                          candidates=cands)
    out.append(replay_geometry(trace, Geometry(ladder[0], 0, "lru",
                                               best_geom.wc_entries),
                               dram_latency=dram_latency, autotune=tune,
                               adaptive="autotune", **consts))
    return out


# ------------------------------------------------- multi-tenant replay A/B
_TENANTS = ("a", "b")


def _tenant_of_slot(slot: int) -> str:
    """Round-robin slot -> tenant assignment for replaying an untenanted
    recorded trace under tenant identities (the trace carries no tenant
    labels; any deterministic assignment gives the partitioning A/B a
    well-defined workload split)."""
    return _TENANTS[slot % len(_TENANTS)]


def tenant_ab_rows(traces: Dict[str, list], consts: dict,
                   dram_latency: int) -> List[str]:
    """Replay each recorded deployment trace under two-tenant identities
    on a small partitionable IOTLB: all ways shared vs private ways per
    tenant (``TLBConfig.partitions``). Both arms see the exact same
    demand stream — partitioning moves misses between tenants, never
    changes the trace — so the rows isolate the interference/isolation
    trade: a noisy neighbor can thrash the shared arm's whole TLB but
    only its own ways in the partitioned arm."""
    soc = PaperSoCConfig()
    entries, ways = 8, 4
    rows: List[str] = []
    for dep, trace in traces.items():
        arms = {}
        for label, parts in (("shared", {}),
                             ("partitioned", {"a": 2, "b": 1})):
            walker = Sv39Walk(
                levels=soc.ptw_levels,
                dram_access_cycles=dram_latency + soc.dram_base_latency,
                llc=False, to_accel=H2A)
            iommu = IOMMU(walk_model=walker,
                          tlb=TLBConfig(entries, "lru", ways=ways,
                                        partitions=parts))
            for t in _TENANTS:
                iommu.register_tenant(t)
            per_step = replay_trace(trace, iommu,
                                    consts["kv_bytes_per_token"],
                                    consts["compute_per_token"], soc,
                                    dram_latency,
                                    tenant_of=_tenant_of_slot)
            arms[label] = (iommu, sum(p for p, _ in per_step))
        for label in ("shared", "partitioned"):
            iommu, demand = arms[label]
            ts = iommu.stats().get("tenant", {})
            per_t = " ".join(
                f"{t}:hits={ts[t].get('tlb', {}).get('hits', 0)}"
                f"/misses={ts[t].get('tlb', {}).get('misses', 0)}"
                f"/conflict={ts[t].get('tlb', {}).get('conflict_misses', 0)}"
                for t in sorted(ts))
            cfgstr = ("all ways shared" if label == "shared"
                      else "ways a=2 b=1 (+1 shared)")
            rows.append(
                f"tlb_sweep.tenant.{dep}.{label},{demand:.1f},"
                f"demand PTW cycles @ e{entries}.w{ways} {cfgstr}; "
                f"{per_t}")
    return rows


def run(smoke: bool = False, out: str = "tlb_sweep.csv",
        dram_latency: int = 200, ranges: int = 8) -> List[str]:
    traces, consts = record_traces(dry_run=smoke)
    grid = sweep_grid(smoke)
    rows: List[str] = []
    results: Dict[str, List[dict]] = {}
    adaptive: Dict[str, List[dict]] = {}
    for dep, trace in traces.items():
        n_steps = sum(1 for ev in trace if ev[0] == "step")
        n_pre = sum(1 for ev in trace if ev[0] == "preempt")
        rows.append(f"tlb_sweep.trace.{dep},{n_steps},decode steps recorded "
                    f"({len(trace)} events; preempts={n_pre})")
        results[dep] = []
        for geom in grid:
            r = replay_geometry(trace, geom, dram_latency=dram_latency,
                                **consts)
            r["deployment"] = dep
            results[dep].append(r)
    # ONE best-static pick per deployment, shared by the adaptive rows'
    # baseline and the tlb_sweep.best.* summary (so the two can never
    # silently disagree about what "best" means).
    best_key = lambda r: (r["ptw_pct_mean"], r["n_entries"], r["ways"],
                          r["wc_entries"])
    best = {dep: min(rs, key=best_key) for dep, rs in results.items()}
    for dep, trace in traces.items():
        b = best[dep]
        best_geom = Geometry(b["n_entries"],
                             0 if b["ways"] == b["n_entries"]
                             else b["ways"], b["policy"], b["wc_entries"])
        adaptive[dep] = adaptive_rows(trace, best_geom, consts,
                                      dram_latency, smoke=smoke)
        for r in adaptive[dep]:
            r["deployment"] = dep
    # Range-coalescing A/B at EQUAL ENTRY COUNT: the paper's 4-entry
    # fully-assoc lru IOTLB per-page (the static grid row) vs the same
    # geometry with range entries covering up to ``ranges`` pages each.
    range_ab: Dict[str, dict] = {}
    for dep, trace in traces.items():
        r = replay_geometry(trace, Geometry(4, 0, "lru", 0, ranges=ranges),
                            dram_latency=dram_latency,
                            adaptive=f"range:r{ranges}", **consts)
        r["deployment"] = dep
        range_ab[dep] = r

    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=FIELDS, extrasaction="ignore")
        w.writeheader()
        for dep in results:
            w.writerows(results[dep])
        for dep in adaptive:
            w.writerows(adaptive[dep])
        w.writerows(range_ab.values())
    n_rows = sum(len(v) for v in results.values()) \
        + sum(len(v) for v in adaptive.values()) + len(range_ab)
    rows.append(f"tlb_sweep.grid,{len(grid)},geometries x "
                f"{len(results)} deployments + "
                f"{sum(len(v) for v in adaptive.values())} adaptive rows "
                f"-> {n_rows} CSV rows ({out})")

    for dep, rs in results.items():
        # Axis cuts at the paper's 4-entry IOTLB (hold the rest at lru/wc0):
        base = {(r["ways"], r["policy"], r["wc_entries"]): r
                for r in rs if r["n_entries"] == 4}
        fa = base.get((4, "lru", 0))
        dm = base.get((1, "lru", 0))
        if fa and dm:
            rows.append(
                f"tlb_sweep.{dep}.assoc_axis,{dm['ptw_pct_mean']:.2f},"
                f"PTW% direct-mapped 4-entry (fully-assoc: "
                f"{fa['ptw_pct_mean']:.2f}%; conflict_misses="
                f"{dm['conflict_misses']})")
        wc_on = base.get((4, "lru", max(g.wc_entries for g in grid)))
        if fa and wc_on:
            rows.append(
                f"tlb_sweep.{dep}.walk_cache_axis,"
                f"{wc_on['ptw_pct_mean']:.2f},PTW% with a "
                f"{wc_on['wc_entries']}-entry walk cache (off: "
                f"{fa['ptw_pct_mean']:.2f}%; wc_hits={wc_on['wc_hits']})")
        sizes = sorted({r["n_entries"] for r in rs})
        size_cut = [r for r in rs
                    if r["ways"] == r["n_entries"] and r["policy"] == "lru"
                    and r["wc_entries"] == 0]
        span = " ".join(f"{r['n_entries']}e={r['ptw_pct_mean']:.2f}%"
                        for r in sorted(size_cut,
                                        key=lambda r: r["n_entries"]))
        rows.append(f"tlb_sweep.{dep}.size_axis,{len(sizes)},"
                    f"fully-assoc lru PTW% by entries: {span}")
        b = best[dep]
        rows.append(
            f"tlb_sweep.best.{dep},{b['ptw_pct_mean']:.2f},"
            f"PTW% of decode-step runtime @ entries={b['n_entries']} "
            f"ways={b['ways']} policy={b['policy']} "
            f"wc={b['wc_entries']} (hit_rate={b['hit_rate']})")
        # ------------------------- adaptive front-end vs the best static
        for r in adaptive[dep]:
            label = r["adaptive"].replace(":", "_").replace("+", "_")
            extra = ""
            if r["adaptive"] == "autotune":
                ts = r["_tuner"]
                extra = (f" converged=e{r['n_entries']}.w{r['ways']}."
                         f"{r['policy']} switches={ts['switches']} "
                         f"windows={ts['windows']}")
            else:
                extra = (f" issued={r['prefetch_issued']} "
                         f"useful={r['prefetch_useful']} "
                         f"late={r['prefetch_late']}")
            rows.append(
                f"tlb_sweep.adaptive.{dep}.{label},"
                f"{r['demand_ptw_cycles']},demand PTW cycles vs best "
                f"static {b['demand_ptw_cycles']} "
                f"(ptw_pct_mean={r['ptw_pct_mean']:.2f} vs "
                f"{b['ptw_pct_mean']:.2f}){extra}")
        # -------------- range coalescing vs per-page at equal entry count
        pp = next(r for r in rs
                  if r["n_entries"] == 4 and r["ways"] == 4
                  and r["policy"] == "lru" and r["wc_entries"] == 0)
        rr = range_ab[dep]
        rows.append(
            f"tlb_sweep.range.{dep},{rr['demand_ptw_cycles']},"
            f"demand PTW cycles @ ranges={ranges} vs per-page "
            f"{pp['demand_ptw_cycles']} at equal entry count (e4 full lru "
            f"wc0; demand misses {rr['tlb_misses']} vs {pp['tlb_misses']}; "
            f"range_entries={rr['range_entries']} "
            f"coalesced_pages={rr['coalesced_pages']} "
            f"splits={rr['range_splits']})")
    # --------- multi-tenant partitioned-vs-shared A/B (same traces,
    # round-robin slot->tenant identities; see benchmarks/README.md)
    rows += tenant_ab_rows(traces, consts, dram_latency)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + dry-run trace (CI smoke path)")
    ap.add_argument("--out", default="tlb_sweep.csv",
                    help="full-grid CSV output path")
    ap.add_argument("--dram-latency", type=int, default=200,
                    help="AXI delayer setting for the Sv39 walk replay")
    ap.add_argument("--ranges", type=int, default=8,
                    help="max pages per range-coalesced IOTLB entry for the "
                         "range A/B rows (>= 2; the per-page baseline rows "
                         "are unaffected)")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, out=args.out,
                        dram_latency=args.dram_latency,
                        ranges=args.ranges)))
