"""Benchmark E6 — trace-driven translation design-space sweep (Kim et al.).

Records real serving translation traces (``ServingEngine(
record_translation_trace=True)``) for two deployment profiles — a
prefix-heavy mix (shared system prompt, CoW divergence) and an all-unique
mix (no cross-request reuse) — then replays each trace through the unified
IOMMU front-end across a grid of hardware geometries:

  IOTLB entries x set associativity (ways) x replacement policy
  x walk-cache size (non-leaf Sv39 PTE cache)

The walker is ``Sv39Walk(llc=False)`` — the no-LLC platform where the
paper pays 4.2-17.6% of accelerator runtime for translation, i.e. exactly
the regime where IOTLB/walk-cache geometry decides the design space (with
LLC-resident PTEs the walker is ~free and every geometry ties). Every
replay of the same trace is bit-reproducible: the walker draws no RNG with
the LLC off and the ``random`` policy is seeded.

Emits the full grid as CSV (``--out``, default ``tlb_sweep.csv``) and
prints summary rows: PTW overhead as a % of modeled decode-step runtime
per geometry axis, plus the best geometry per deployment.

``--smoke`` shrinks the grid and the recorded workload (CI smoke path —
wired into ``benchmarks/run.py --only sweep`` and the figure-benchmarks
job).
"""
from __future__ import annotations

import argparse
import csv
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.trace_replay import replay_trace
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.simulator.platform import H2A
from repro.core.sva.iommu import (IOMMU, Sv39Walk, TLBConfig,
                                  WalkCacheConfig)
from repro.core.sva.tlb import POLICIES


@dataclass(frozen=True)
class Geometry:
    """One IOTLB + walk-cache design point of the sweep grid."""
    entries: int
    ways: int                 # 0 = fully associative
    policy: str
    wc_entries: int           # 0 = walk cache off

    @property
    def resolved_ways(self) -> int:
        return self.ways or self.entries

    def label(self) -> str:
        w = "full" if self.resolved_ways == self.entries else str(self.ways)
        return (f"e{self.entries}.w{w}.{self.policy}.wc{self.wc_entries}")


def sweep_grid(smoke: bool = False) -> List[Geometry]:
    """entries x ways x policy x walk-cache size; degenerate ways (== entries)
    collapse onto the fully-associative point so no geometry is replayed
    twice."""
    if smoke:
        entries, ways = (4, 16), (1, 0)
        policies, wcs = ("lru", "fifo"), (0, 8)
    else:
        entries, ways = (4, 8, 16, 64), (1, 2, 4, 0)
        policies, wcs = POLICIES, (0, 8, 32)
    out: List[Geometry] = []
    seen = set()
    for e in entries:
        for w in ways:
            if w and (w > e or e % w):
                continue
            rw = w or e
            for p in policies:
                for wc in wcs:
                    key = (e, rw, p, wc)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(Geometry(e, 0 if rw == e else w, p, wc))
    return out


# --------------------------------------------------------------- recording

def record_traces(dry_run: bool = False) -> Tuple[Dict[str, list], dict]:
    """Serve two deployment profiles with translation tracing ON. Returns
    ({deployment: trace}, cost model constants for the replay)."""
    # Lazy: recording needs jax + the serving engine; replay does not.
    from benchmarks.paged_serving import (_cfg_params,  # noqa: PLC0415
                                          _prefix_heavy_prompts)
    from repro.core.serving.engine import ServingEngine  # noqa: PLC0415

    n_req, max_tokens = (4, 4) if dry_run else (10, 10)
    cfg, params = _cfg_params()
    soc = PaperSoCConfig()

    def serve(prompts):
        eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                            record_translation_trace=True)
        for p in prompts:
            eng.submit(p, max_tokens=max_tokens)
        eng.run()
        return eng, eng.translation_trace

    eng, prefix_trace = serve(_prefix_heavy_prompts(n_req, cfg.vocab_size))
    rng = np.random.default_rng(11)
    unique = [rng.integers(0, cfg.vocab_size,
                           size=int(rng.integers(8, 30))).tolist()
              for _ in range(n_req)]
    _, unique_trace = serve(unique)

    n_attn = sum(1 for k in cfg.layer_kinds() if "attn" in k)
    consts = dict(
        kv_bytes_per_token=eng.mgr.kv_bytes_per_token,
        # decode attention: ~4 flops per KV token per head-dim per layer
        compute_per_token=4 * cfg.n_heads * cfg.d_head * n_attn / soc.n_pes)
    return {"prefix_heavy": prefix_trace, "unique": unique_trace}, consts


# ----------------------------------------------------------------- replay

def replay_geometry(trace, geom: Geometry, kv_bytes_per_token: int,
                    compute_per_token: float, dram_latency: int = 200,
                    soc: PaperSoCConfig = None) -> dict:
    """Price one recorded serving trace under one hardware geometry.
    Returns the CSV row: TLB/walk-cache stats + PTW overhead as a % of each
    modeled decode step's accelerator runtime."""
    soc = soc or PaperSoCConfig()
    walker = Sv39Walk(
        levels=soc.ptw_levels,
        dram_access_cycles=dram_latency + soc.dram_base_latency,
        llc=False, to_accel=H2A,
        walk_cache=WalkCacheConfig(geom.wc_entries, policy="lru"))
    iommu = IOMMU(walk_model=walker,
                  tlb=TLBConfig(geom.entries, geom.policy, ways=geom.ways))
    per_step = replay_trace(trace, iommu, kv_bytes_per_token,
                            compute_per_token, soc, dram_latency)
    pcts = [100.0 * ptw / max(step, 1e-9) for ptw, step in per_step]
    tlb = iommu.tlb.stats
    wc = walker.walk_cache.stats if walker.walk_cache is not None else None
    return dict(
        n_entries=geom.entries, ways=geom.resolved_ways, policy=geom.policy,
        wc_entries=geom.wc_entries,
        tlb_hits=tlb.hits, tlb_misses=tlb.misses,
        conflict_misses=tlb.conflict_misses,
        hit_rate=round(tlb.hit_rate, 4),
        walks=walker.stats.walks,
        wc_hits=wc.hits if wc else 0, wc_misses=wc.misses if wc else 0,
        ptw_cycles=round(walker.stats.cycles, 1),
        ptw_pct_mean=round(float(np.mean(pcts)) if pcts else 0.0, 3),
        ptw_pct_max=round(float(np.max(pcts)) if pcts else 0.0, 3))


FIELDS = ("deployment", "n_entries", "ways", "policy", "wc_entries",
          "tlb_hits", "tlb_misses", "conflict_misses", "hit_rate", "walks",
          "wc_hits", "wc_misses", "ptw_cycles", "ptw_pct_mean",
          "ptw_pct_max")


def run(smoke: bool = False, out: str = "tlb_sweep.csv",
        dram_latency: int = 200) -> List[str]:
    traces, consts = record_traces(dry_run=smoke)
    grid = sweep_grid(smoke)
    rows: List[str] = []
    results: Dict[str, List[dict]] = {}
    for dep, trace in traces.items():
        n_steps = sum(1 for ev in trace if ev[0] == "step")
        rows.append(f"tlb_sweep.trace.{dep},{n_steps},decode steps recorded "
                    f"({len(trace)} events)")
        results[dep] = []
        for geom in grid:
            r = replay_geometry(trace, geom, dram_latency=dram_latency,
                                **consts)
            r["deployment"] = dep
            results[dep].append(r)

    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=FIELDS)
        w.writeheader()
        for dep in results:
            w.writerows(results[dep])
    n_rows = sum(len(v) for v in results.values())
    rows.append(f"tlb_sweep.grid,{len(grid)},geometries x "
                f"{len(results)} deployments -> {n_rows} CSV rows ({out})")

    for dep, rs in results.items():
        # Axis cuts at the paper's 4-entry IOTLB (hold the rest at lru/wc0):
        base = {(r["ways"], r["policy"], r["wc_entries"]): r
                for r in rs if r["n_entries"] == 4}
        fa = base.get((4, "lru", 0))
        dm = base.get((1, "lru", 0))
        if fa and dm:
            rows.append(
                f"tlb_sweep.{dep}.assoc_axis,{dm['ptw_pct_mean']:.2f},"
                f"PTW% direct-mapped 4-entry (fully-assoc: "
                f"{fa['ptw_pct_mean']:.2f}%; conflict_misses="
                f"{dm['conflict_misses']})")
        wc_on = base.get((4, "lru", max(g.wc_entries for g in grid)))
        if fa and wc_on:
            rows.append(
                f"tlb_sweep.{dep}.walk_cache_axis,"
                f"{wc_on['ptw_pct_mean']:.2f},PTW% with a "
                f"{wc_on['wc_entries']}-entry walk cache (off: "
                f"{fa['ptw_pct_mean']:.2f}%; wc_hits={wc_on['wc_hits']})")
        sizes = sorted({r["n_entries"] for r in rs})
        size_cut = [r for r in rs
                    if r["ways"] == r["n_entries"] and r["policy"] == "lru"
                    and r["wc_entries"] == 0]
        span = " ".join(f"{r['n_entries']}e={r['ptw_pct_mean']:.2f}%"
                        for r in sorted(size_cut,
                                        key=lambda r: r["n_entries"]))
        rows.append(f"tlb_sweep.{dep}.size_axis,{len(sizes)},"
                    f"fully-assoc lru PTW% by entries: {span}")
        best = min(rs, key=lambda r: (r["ptw_pct_mean"], r["n_entries"],
                                      r["ways"], r["wc_entries"]))
        rows.append(
            f"tlb_sweep.best.{dep},{best['ptw_pct_mean']:.2f},"
            f"PTW% of decode-step runtime @ entries={best['n_entries']} "
            f"ways={best['ways']} policy={best['policy']} "
            f"wc={best['wc_entries']} (hit_rate={best['hit_rate']})")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + dry-run trace (CI smoke path)")
    ap.add_argument("--out", default="tlb_sweep.csv",
                    help="full-grid CSV output path")
    ap.add_argument("--dram-latency", type=int, default=200,
                    help="AXI delayer setting for the Sv39 walk replay")
    args = ap.parse_args()
    print("\n".join(run(smoke=args.smoke, out=args.out,
                        dram_latency=args.dram_latency)))
