"""Shared replay of a recorded serving translation trace through an IOMMU
design point — the ONE cost model behind both
``paged_serving.py --translation-report`` and ``tlb_sweep.py`` (so the
two always report comparable PTW percentages). jax-free: replay prices
recorded events, it never runs the model.

Trace events (recorded by ``ServingEngine(record_translation_trace=True)``):

  ("map",   pages)              Listing-1 host map pass (warms PTE lines)
  ("step",  accesses, tokens)   one decode step's (slot, lp, phys) gathers
  ("unmap", slot, n_pages)      release: per-page self-invalidation
"""
from __future__ import annotations

from typing import List, Tuple

from repro.configs.paper_soc import PaperSoCConfig
from repro.core.simulator.platform import H2A
from repro.core.sva.iommu import IOMMU


def replay_trace(trace, iommu: IOMMU, kv_bytes_per_token: int,
                 compute_per_token: float, soc: PaperSoCConfig,
                 dram_latency: int) -> List[Tuple[float, float]]:
    """Feed a recorded serving translation trace through ``iommu``.
    Returns the per-decode-step list of (ptw_cycles, step_cycles) in
    accelerator cycles."""
    burst = (dram_latency + soc.dram_base_latency) * H2A
    per_step: List[Tuple[float, float]] = []
    for ev in trace:
        if ev[0] == "map":
            iommu.host_map_pass(ev[1])
        elif ev[0] == "unmap":
            _, slot, n_pages = ev
            iommu.invalidate(pages=[(slot, lp) for lp in range(n_pages)])
        else:
            _, accesses, tokens = ev
            ptw = 0.0
            for slot, lp, phys in accesses:
                # translate() re-walks stale hits itself (the recorded phys
                # is ground truth after a CoW remap)
                _, cost, _ = iommu.translate(slot, lp, phys=phys)
                ptw += cost
            kv_bytes = tokens * kv_bytes_per_token
            dma = len(accesses) * burst \
                + kv_bytes / soc.dram_bytes_per_cycle * H2A
            compute = tokens * compute_per_token
            # Double-buffered gather hides compute under DMA (or vice
            # versa); walks serialize in front of their page's burst.
            per_step.append((ptw, max(compute, dma) + ptw))
    return per_step
