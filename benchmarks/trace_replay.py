"""Shared replay of a recorded serving translation trace through an IOMMU
design point — the ONE cost model behind both
``paged_serving.py --translation-report`` and ``tlb_sweep.py`` (so the
two always report comparable PTW percentages). jax-free: replay prices
recorded events, it never runs the model.

Trace events (recorded by ``ServingEngine(record_translation_trace=True)``):

  ("map",   pages)              Listing-1 host map pass (warms PTE lines)
  ("map",   pages, slot, row)   extended form: additionally installs the
                                slot's full logical->physical table into the
                                replay IOMMU's address space, so a replaying
                                IOTLB *prefetcher* can resolve upcoming
                                logical pages the way hardware reads the
                                page table, and the page list doubles as the
                                CONTIGUITY SIGNAL for a range-aware replay
                                IOMMU (``TLBConfig(ranges=N)``): the freshly
                                mapped pages land at the row's logical tail,
                                and physically contiguous runs among them
                                warm as range entries exactly like the live
                                engine's map path. Replay numbers WITHOUT a
                                prefetcher or range entries are bit-identical
                                for both forms (demand accesses carry their
                                physical page in the trace; the table feeds
                                only the prefetcher and the range coalescer).
  ("step",  accesses, tokens)   one decode step's (slot, lp, phys) gathers
  ("unmap", slot, n_pages)      release: per-ASID self-invalidation (TLB
                                entries + prefetcher state die with the
                                slot, mirroring the live engine's detach)
  ("preempt", seq_id)           scheduler preempted a sequence under pool
                                pressure. Annotation only: the translation
                                consequences ride the paired "unmap" the
                                engine emits right after (ASID teardown).
  ("resume", seq_id, pages)     the sequence was re-admitted onto ``pages``.
                                Annotation only: the paired "map" carries
                                the new mapping. Both keep preemption-
                                bearing traces replayable and countable.
  ("xfer", seq_id, n_pages, mode)
                                disaggregated prefill->decode KV migration
                                of ``n_pages`` pages, ``mode`` "copy" or
                                "share". Annotation only: the translation
                                consequences ride the paired "unmap"
                                (source ASID teardown) and "map"
                                (destination attach) the engine emits
                                right after — so disagg traces replay
                                through every IOMMU design point unchanged.

Events are shape-checked on replay: a malformed event raises
:class:`TraceFormatError` naming the event index and the expected shape
(instead of an anonymous unpacking error — or silently wrong numbers).

Adaptive replay: construct the IOMMU with a
:class:`~repro.core.sva.iommu.PrefetchConfig` to replay with IOTLB
prefetching, and/or pass ``tuner=TLBAutoTuner(iommu, AutoTuneConfig(...))``
to let the online geometry auto-tuner advance one window per replayed
decode step — the same machinery the live serving engine runs, priced on a
recorded trace.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from repro.configs.paper_soc import PaperSoCConfig
from repro.core.simulator.platform import H2A
from repro.core.sva.iommu import IOMMU, TLBAutoTuner


class TraceFormatError(ValueError):
    """A recorded trace event does not match the documented schema.

    Raised with the EVENT INDEX and the expected shape, so a malformed
    trace (hand-written, truncated by a crashed recording run, or produced
    by an engine version with a different schema) fails loudly at the
    offending event instead of as a bare unpacking ``ValueError`` deep in
    the replay loop — or worse, as silently wrong cycle numbers."""

    def __init__(self, index: int, got, expected: str):
        self.index = index
        self.expected = expected
        super().__init__(
            f"trace event {index} is malformed: got {got!r}; "
            f"expected {expected}")


_EVENT_SHAPES = {
    "map": '("map", pages) or ("map", pages, slot, row)',
    "step": '("step", accesses, tokens) with accesses a sequence of '
            '(slot, lp, phys) triples',
    "unmap": '("unmap", slot, n_pages)',
    "preempt": '("preempt", seq_id)',
    "resume": '("resume", seq_id, pages)',
    "xfer": '("xfer", seq_id, n_pages, mode) with mode "copy" or "share"',
}


def _validate_event(i: int, ev) -> str:
    """Shape-check one trace event; returns its kind (a key of
    ``_EVENT_SHAPES``) or raises :class:`TraceFormatError` naming the
    event index (and, for an unknown kind, the offending tag)."""
    if not isinstance(ev, (tuple, list)) or not ev:
        raise TraceFormatError(
            i, ev, "a non-empty tuple " + " / ".join(_EVENT_SHAPES.values()))
    kind = ev[0]
    if kind not in _EVENT_SHAPES:
        # NAME the offending tag: "teardown" vs "unmap" should read as a
        # tag problem at a glance, not send the user diffing shape docs.
        raise TraceFormatError(
            i, ev, f'a known event kind (got unknown tag {kind!r}); '
            'expected one of: ' + " / ".join(_EVENT_SHAPES.values()))
    if kind == "map":
        if len(ev) not in (2, 4) or isinstance(ev[1], (str, int, float)):
            raise TraceFormatError(i, ev, _EVENT_SHAPES["map"])
        if len(ev) == 4:
            # The extended form's page list is the range coalescer's
            # contiguity signal — validate it (and the row) up front so a
            # malformed trace fails at the event, not inside a warm fill.
            if (not isinstance(ev[2], int)
                    or isinstance(ev[3], (str, int, float))
                    or not all(isinstance(p, int) for p in ev[1])
                    or not all(isinstance(p, int) for p in ev[3])):
                raise TraceFormatError(i, ev, _EVENT_SHAPES["map"])
    elif kind == "unmap":
        if len(ev) != 3 or not all(isinstance(x, int) for x in ev[1:]):
            raise TraceFormatError(i, ev, _EVENT_SHAPES["unmap"])
    elif kind == "preempt":
        if len(ev) != 2 or not isinstance(ev[1], int):
            raise TraceFormatError(i, ev, _EVENT_SHAPES["preempt"])
    elif kind == "resume":
        if (len(ev) != 3 or not isinstance(ev[1], int)
                or isinstance(ev[2], (str, int, float))):
            raise TraceFormatError(i, ev, _EVENT_SHAPES["resume"])
    elif kind == "xfer":
        if (len(ev) != 4 or not isinstance(ev[1], int)
                or not isinstance(ev[2], int)
                or ev[3] not in ("copy", "share")):
            raise TraceFormatError(i, ev, _EVENT_SHAPES["xfer"])
    else:  # step
        if (len(ev) != 3 or isinstance(ev[1], (str, int, float))
                or not isinstance(ev[2], (int, float))):
            raise TraceFormatError(i, ev, _EVENT_SHAPES["step"])
    return kind


def _install_row(iommu: IOMMU, slot: int, row,
                 tenant: Optional[str] = None) -> None:
    """Install a slot's logical->physical table into the replay IOMMU
    (attaching the space on first sight). The TLB is NOT warmed — the
    recorded demand stream decides what gets cached; only the prefetcher
    (and, via :func:`_warm_ranges`, the range coalescer) reads the table."""
    sp = iommu.space(slot)
    if sp is None:
        sp = iommu.attach(slot, tenant=tenant)
    sp.table.clear()
    for lp, pp in enumerate(row):
        sp.table[lp] = pp


def _warm_ranges(iommu: IOMMU, slot: int, pages, row) -> None:
    """Replay the extended map form's page list as the contiguity signal:
    the freshly mapped pages are the row's logical tail (the engine records
    ``pages = st.pages[shared:]``, ``row = st.pages``), so a range-aware
    replay IOMMU warms physically contiguous runs among them as range
    entries — the same map-time coalescing the live engine performs (range
    entries only: singleton pages stay cold, so the per-page baseline
    replay, which never warms, stays apples-to-apples). A page list that
    is not the row's tail (hand-edited trace) is skipped: demand-side
    coalescing still prices it correctly."""
    if not iommu.range_max or not pages:
        return
    start = len(row) - len(pages)
    if start < 0 or list(row[start:]) != list(pages):
        return
    iommu._warm_fill_runs(slot, start, list(pages), singles=False)


def runs_in(pages) -> int:
    """Number of maximal physically-contiguous runs in a page list (1 run
    == perfectly contiguous; ``len(pages)`` == fully fragmented)."""
    pages = list(pages)
    if not pages:
        return 0
    return 1 + sum(1 for a, b in zip(pages, pages[1:]) if b != a + 1)


def trace_fragmentation(trace) -> dict:
    """Physical-contiguity summary of a recorded trace's admissions: how
    many maximal contiguous runs each sequence's freshly allocated pages
    form (extended ``("map", pages, slot, row)`` events only — the short
    form carries no per-sequence attribution). ``runs_per_seq`` == 1.0
    means every admission got one contiguous run (ideal for range
    coalescing); higher values quantify allocator fragmentation."""
    seqs = runs = pages = 0
    for i, ev in enumerate(trace):
        if _validate_event(i, ev) == "map" and len(ev) == 4 and ev[1]:
            seqs += 1
            runs += runs_in(ev[1])
            pages += len(ev[1])
    return dict(
        sequences=seqs, runs=runs, pages=pages,
        runs_per_seq=(runs / seqs) if seqs else 0.0,
        mean_run_pages=(pages / runs) if runs else 0.0)


def replay_trace(trace, iommu: IOMMU, kv_bytes_per_token: int,
                 compute_per_token: float, soc: PaperSoCConfig,
                 dram_latency: int,
                 tuner: Optional[TLBAutoTuner] = None,
                 tenant_of: Optional[Callable[[int], Optional[str]]] = None
                 ) -> List[Tuple[float, float]]:
    """Feed a recorded serving translation trace through ``iommu``.
    Returns the per-decode-step list of (ptw_cycles, step_cycles) in
    accelerator cycles. ``ptw_cycles`` is the DEMAND-exposed translation
    cost: walk cost on misses plus the exposed latency of late prefetches
    (prefetch walks that completed in time cost the demand path nothing —
    their cycles only show in the walk model's totals).

    ``tenant_of`` (slot -> tenant name, for a replay IOMMU with
    registered TenantDomains) replays every attach and translation under
    the slot's tenant identity — the multi-tenant A/B path
    (``tlb_sweep``): way partitions and per-tenant stats see the same
    traffic the live engine would issue. None (the default) replays
    untenanted, bit-identical to the historical replay."""
    burst = (dram_latency + soc.dram_base_latency) * H2A
    per_step: List[Tuple[float, float]] = []
    for i, ev in enumerate(trace):
        kind = _validate_event(i, ev)
        if kind == "map":
            iommu.host_map_pass(ev[1])
            if len(ev) >= 4:
                _install_row(iommu, ev[2], ev[3],
                             tenant=tenant_of(ev[2]) if tenant_of else None)
                _warm_ranges(iommu, ev[2], ev[1], ev[3])
        elif kind == "unmap":
            _, slot, n_pages = ev
            # Mirror the live engine's release -> detach: a per-ASID
            # invalidation drops the slot's TLB entries AND the
            # prefetcher's stream state / in-flight fills, so slot reuse
            # never inherits a dead sequence's predictor. (For static
            # replays this removes exactly the keys the recorded per-page
            # list would — every demand fill has lp < n_pages.)
            iommu.invalidate(asid=slot)
            sp = iommu.space(slot)
            if sp is not None:
                sp.table.clear()        # released: the prefetcher must not
                                        # resolve through a dead mapping
        elif kind in ("preempt", "resume", "xfer"):
            # Annotations: the engine emits the translation-visible
            # consequences as the paired "unmap" (ASID teardown on
            # preempt / migration source) and "map" (fresh mapping on
            # resume / migration destination) events, so replay only
            # needs to validate and count them.
            continue
        else:
            _, accesses, tokens = ev
            ptw = 0.0
            for acc in accesses:
                try:
                    slot, lp, phys = acc
                except (TypeError, ValueError):
                    raise TraceFormatError(i, ev, _EVENT_SHAPES["step"]) \
                        from None
                # translate() re-walks stale hits itself (the recorded phys
                # is ground truth after a CoW remap)
                _, cost, _ = iommu.translate(
                    slot, lp, phys=phys,
                    tenant=tenant_of(slot) if tenant_of else None)
                ptw += cost
            kv_bytes = tokens * kv_bytes_per_token
            dma = len(accesses) * burst \
                + kv_bytes / soc.dram_bytes_per_cycle * H2A
            compute = tokens * compute_per_token
            # Double-buffered gather hides compute under DMA (or vice
            # versa); walks serialize in front of their page's burst.
            per_step.append((ptw, max(compute, dma) + ptw))
            if tuner is not None:
                tuner.observe_step()
    return per_step
