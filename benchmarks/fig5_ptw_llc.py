"""Benchmark E4 — paper Fig. 5: average PTW time +-LLC +-host interference."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.simulator.paper_targets import CLAIMS
from repro.core.simulator.run import simulate_kernel

INTERFERENCE = 0.028     # calibrated to the paper's ~20% PTW slowdown


def run() -> List[str]:
    rows = []
    no_llc, with_llc, with_intf = [], [], []
    for lat in (200, 600, 1000):
        a = simulate_kernel("axpy", "iommu", lat).avg_ptw_host_cycles
        b = simulate_kernel("axpy", "iommu_llc", lat).avg_ptw_host_cycles
        c = simulate_kernel("axpy", "iommu_llc", lat,
                            host_interference=INTERFERENCE).avg_ptw_host_cycles
        no_llc.append(a)
        with_llc.append(b)
        with_intf.append(c)
        rows.append(f"fig5.ptw.no_llc.{lat},{a:.0f},host cycles")
        rows.append(f"fig5.ptw.llc.{lat},{b:.0f},host cycles")
        rows.append(f"fig5.ptw.llc_interference.{lat},{c:.0f},host cycles")
    speedup = np.mean(no_llc) / np.mean(with_llc)
    slow = 100 * (np.mean(with_intf) / np.mean(with_llc) - 1)
    rows.append(f"fig5.claim.llc_speedup,{speedup:.1f},"
                f"paper={CLAIMS['ptw_llc_speedup_x']}x avg")
    rows.append(f"fig5.claim.llc_max_ptw,{max(with_llc):.0f},"
                f"paper<={CLAIMS['ptw_llc_max_cycles']:.0f} cycles @1000")
    rows.append(f"fig5.claim.interference,{slow:.0f},"
                f"paper~{CLAIMS['ptw_interference_slowdown_pct']}%")
    # IOTLB replacement-policy design space (Kim et al.): the same 4-entry
    # IOTLB + Sv39 walk through the unified IOMMU API, swapping only
    # TLBConfig.policy. avg PTW latency @600 host cycles, lru baseline
    # above.
    for pol in ("fifo", "lfu", "random"):
        v = simulate_kernel("axpy", "iommu_llc", 600,
                            iotlb_policy=pol).avg_ptw_host_cycles
        rows.append(f"fig5.design.iotlb_policy.{pol},{v:.0f},"
                    f"host cycles @600 (lru={with_llc[1]:.0f}; axpy streams "
                    "pages once, so policies tie here — reuse-heavy serving "
                    "traffic differentiates them, see paged_serving "
                    "--translation-report)")
    # Set-associative IOTLB geometry (second Kim-et-al. axis): the same
    # 4-entry IOTLB constrained to 1/2 ways. The paper's kernels stream
    # each page once, so every access is a compulsory miss and geometry
    # cannot change the walk count — these rows pin the fully-associative
    # equivalence at the hardware config; reuse-heavy serving traces are
    # what differentiate geometry (benchmarks/tlb_sweep.py).
    base_walks = simulate_kernel("axpy", "iommu_llc", 600).walks
    for ways in (1, 2):
        r = simulate_kernel("axpy", "iommu_llc", 600, iotlb_ways=ways)
        rows.append(f"fig5.design.iotlb_ways.{ways},{r.walks:.0f},"
                    f"page-table walks @600 with a {ways}-way 4-entry IOTLB "
                    f"(fully assoc: {base_walks:.0f} — compulsory misses "
                    "only on streamed pages; see tlb_sweep for the "
                    "geometry-sensitive serving traces)")
    # Walk-cache axis: without the shared LLC, a 16-entry non-leaf PTE
    # cache on the walker removes most upper-level DRAM accesses — the
    # cheap-hardware alternative to LLC-resident PTEs.
    wc = simulate_kernel("axpy", "iommu", 600,
                         walk_cache_entries=16).avg_ptw_host_cycles
    rows.append(f"fig5.design.walk_cache16.no_llc,{wc:.0f},"
                f"avg PTW host cycles @600 (no walk cache: {no_llc[1]:.0f}; "
                "LLC-on: {:.0f}) — non-leaf PTEs cached on the IOMMU"
                .format(with_llc[1]))
    # IOTLB prefetch axis (Kurth et al. MMU-aware DMA engine): axpy streams
    # pages in order, the stream detector runs ahead of the DMA and the
    # demand accesses hit prefetched translations — the walks migrate off
    # the demand path (walks counts demand misses; exposed ptw_cycles keeps
    # only late prefetches). distance must stay within the 4-entry IOTLB's
    # capacity or the prefetcher evicts its own not-yet-used fills.
    base = simulate_kernel("axpy", "iommu", 600)
    pf = simulate_kernel("axpy", "iommu", 600,
                         iotlb_prefetch_policy="stream",
                         iotlb_prefetch_degree=2,
                         iotlb_prefetch_distance=2)
    rows.append(f"fig5.design.iotlb_prefetch.stream,{pf.ptw_cycles:.0f},"
                f"exposed PTW accel cycles @600 no-LLC with stream "
                f"prefetch d2/2 (no prefetch: {base.ptw_cycles:.0f}; "
                f"demand walks {base.walks:.0f} -> {pf.walks:.0f} — "
                "distance > IOTLB capacity thrashes, see tlb_sweep)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
