"""Multi-tenant serving scenario generator.

Seeded, deterministic request traces for the multi-tenant benchmarks
(``paged_serving --tenants``, ``tlb_sweep``) and the conformance tests —
far more diverse than the two stock deployment profiles, but every trace
is a pure function of ``(kind, tenants, vocab, n_req, seed)`` so A/B arms
replay the exact same workload and goldens pin the generator.

Three scenario kinds:

  bursty_tenants      each tenant arrives with its own burst character —
                      the first tenant in bursts (Poisson gaps ~0.5, many
                      same-tick arrivals), later tenants steadily — the
                      noisy-neighbor regime IOTLB way partitioning and
                      page quotas exist for.
  conversation_trees  per-tenant conversation trees: a system prompt
                      root, follow-ups extending a random earlier node —
                      deep WITHIN-tenant prefix sharing (the tenant-scoped
                      prefix index's win case).
  adversarial_prefix_collisions
                      byte-identical prompts submitted under DIFFERENT
                      tenants (plus shared-prefix/different-tail near
                      misses): isolation must keep these from sharing
                      pages even though the token streams collide.

Use :func:`generate` with a kind from :data:`SCENARIO_KINDS`;
:func:`trace_fingerprint` gives a stable digest for seed-determinism
goldens (tests/test_multitenant.py).
"""
from __future__ import annotations

import hashlib
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import List, Sequence, Tuple

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

__all__ = ["ScenarioRequest", "SCENARIO_KINDS", "generate",
           "bursty_tenants", "conversation_trees",
           "adversarial_prefix_collisions", "trace_fingerprint"]


@dataclass(frozen=True)
class ScenarioRequest:
    """One generated request: which tenant submits what, when (arrival is
    an engine-step tick — the driver injects between steps)."""
    tenant: str
    prompt: Tuple[int, ...]
    max_tokens: int
    arrival: int


def _merge(streams: List[List[ScenarioRequest]]) -> List[ScenarioRequest]:
    """Interleave per-tenant streams by arrival tick; ties resolve in
    tenant-stream order (deterministic)."""
    out = [r for s in streams for r in s]
    out.sort(key=lambda r: r.arrival)        # stable: preserves tie order
    return out


def bursty_tenants(tenants: Sequence[str], vocab: int, n_req: int,
                   seed: int) -> List[ScenarioRequest]:
    rng = np.random.default_rng(seed)
    per = -(-n_req // max(len(tenants), 1))
    streams = []
    for ti, t in enumerate(tenants):
        n = min(per, n_req - ti * per)
        if n <= 0:
            break
        # first tenant bursts (tight gaps), later ones are steady
        lam = 0.5 if ti == 0 else 2.0
        gaps = rng.poisson(lam, size=n)
        gaps[0] = 0
        arrivals = np.cumsum(gaps)
        lens = rng.integers(5, 28, size=n)
        maxtoks = rng.integers(4, 12, size=n)
        streams.append([
            ScenarioRequest(t, tuple(rng.integers(0, vocab,
                                                  size=int(lens[i])).tolist()),
                            int(maxtoks[i]), int(arrivals[i]))
            for i in range(n)])
    return _merge(streams)


def conversation_trees(tenants: Sequence[str], vocab: int, n_req: int,
                       seed: int) -> List[ScenarioRequest]:
    rng = np.random.default_rng(seed)
    per = -(-n_req // max(len(tenants), 1))
    streams = []
    for ti, t in enumerate(tenants):
        n = min(per, n_req - ti * per)
        if n <= 0:
            break
        system = tuple(rng.integers(0, vocab, size=16).tolist())
        nodes: List[Tuple[int, ...]] = [system]
        reqs, clock = [], 0
        for _ in range(n):
            parent = nodes[int(rng.integers(0, len(nodes)))]
            turn = tuple(rng.integers(0, vocab,
                                      size=int(rng.integers(3, 9))).tolist())
            prompt = parent + turn
            nodes.append(prompt)
            clock += int(rng.poisson(1.5))
            reqs.append(ScenarioRequest(t, prompt,
                                        int(rng.integers(4, 10)), clock))
        streams.append(reqs)
    return _merge(streams)


def adversarial_prefix_collisions(tenants: Sequence[str], vocab: int,
                                  n_req: int,
                                  seed: int) -> List[ScenarioRequest]:
    rng = np.random.default_rng(seed)
    # one popular prompt every tenant submits verbatim, plus near misses
    # sharing its prefix with a divergent tail
    popular = tuple(rng.integers(0, vocab, size=21).tolist())
    reqs, clock = [], 0
    for i in range(n_req):
        t = tenants[i % len(tenants)]
        if i % 3 == 2:
            prompt = popular[:16] + tuple(
                rng.integers(0, vocab, size=int(rng.integers(3, 7))).tolist())
        else:
            prompt = popular
        clock += int(rng.poisson(1.0))
        reqs.append(ScenarioRequest(t, prompt, int(rng.integers(4, 10)),
                                    clock))
    return reqs


SCENARIO_KINDS = ("bursty_tenants", "conversation_trees",
                  "adversarial_prefix_collisions")
_GENERATORS = {"bursty_tenants": bursty_tenants,
               "conversation_trees": conversation_trees,
               "adversarial_prefix_collisions":
               adversarial_prefix_collisions}


def generate(kind: str, tenants: Sequence[str], vocab: int,
             n_req: int = 12, seed: int = 0) -> List[ScenarioRequest]:
    """Generate one deterministic trace. Same arguments -> byte-identical
    trace, always (pinned by trace_fingerprint goldens)."""
    if kind not in _GENERATORS:
        raise ValueError(f"unknown scenario kind {kind!r} "
                         f"(known: {list(SCENARIO_KINDS)})")
    if not tenants:
        raise ValueError("scenario generation needs at least one tenant")
    return _GENERATORS[kind](list(tenants), vocab, n_req, seed)


def trace_fingerprint(reqs: Sequence[ScenarioRequest]) -> str:
    """Stable short digest of a trace (seed-determinism goldens)."""
    h = hashlib.sha256()
    for r in reqs:
        h.update(repr((r.tenant, r.prompt, r.max_tokens,
                       r.arrival)).encode())
    return h.hexdigest()[:16]
