"""Benchmark E3 — paper Fig. 3: copy / map time vs DRAM latency."""
from __future__ import annotations

from typing import List

from repro.core.simulator.paper_targets import CLAIMS
from repro.core.simulator.run import host_copy_cycles, host_map_cycles

N_BYTES = 3 * 32768 * 4      # the axpy working set (16 pages/vector scale)


def run() -> List[str]:
    rows = []
    for lat in (200, 400, 600, 800, 1000):
        rows.append(f"fig3.copy.{lat},{host_copy_cycles(N_BYTES, lat):.0f},")
        rows.append(f"fig3.map.{lat},{host_map_cycles(N_BYTES, lat):.0f},")
    cr = host_copy_cycles(N_BYTES, 1000) / host_copy_cycles(N_BYTES, 200)
    mr = host_map_cycles(N_BYTES, 1000) / host_map_cycles(N_BYTES, 200)
    rows.append(f"fig3.claim.copy_ratio,{cr:.2f},"
                f"paper={CLAIMS['copy_time_ratio_1000_200']}x")
    rows.append(f"fig3.claim.map_ratio,{mr:.2f},"
                f"paper={CLAIMS['map_time_ratio_1000_200']}x")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
