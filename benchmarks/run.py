"""Benchmark driver — one section per paper table/figure + the TPU-level
benches. Prints ``name,value,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only table2,fig5
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: table2,fig2,fig3,fig5,serving,disagg,"
                         "sweep,roofline")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    sections = []
    if want is None or "table2" in want:
        from benchmarks import table2_kernels
        sections.append(("Table II (kernel cycles)", table2_kernels.run))
    if want is None or "fig2" in want:
        from benchmarks import fig2_offload
        sections.append(("Fig. 2 (offload breakdown)", fig2_offload.run))
    if want is None or "fig3" in want:
        from benchmarks import fig3_copy_map
        sections.append(("Fig. 3 (copy/map vs latency)", fig3_copy_map.run))
    if want is None or "fig5" in want:
        from benchmarks import fig5_ptw_llc
        sections.append(("Fig. 5 (PTW +-LLC)", fig5_ptw_llc.run))
    if want is None or "serving" in want:
        from benchmarks import paged_serving
        sections.append(("Paged serving (TPU Fig.2 analogue)",
                         paged_serving.run))
    if want is None or "disagg" in want:
        from benchmarks import disagg_serving
        # smoke sizes inside the driver; full sizes via the standalone CLI
        sections.append(("Disaggregated serving A/B (smoke)",
                         lambda: disagg_serving.run(dry_run=True)))
    if want is None or "sweep" in want:
        from benchmarks import tlb_sweep
        # smoke grid inside the driver; the full grid is the standalone CLI
        sections.append(("TLB/walk-cache design-space sweep (smoke)",
                         lambda: tlb_sweep.run(smoke=True)))
    if want is None or "roofline" in want:
        from benchmarks import roofline
        sections.append(("Roofline (dry-run artifacts)", roofline.run))

    print("name,value,derived")
    for title, fn in sections:
        print(f"# --- {title} ---", flush=True)
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{title}.ERROR,0,{e!r}", flush=True)
        print(f"# {title}: {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
