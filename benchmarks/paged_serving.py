"""Benchmark E5 — the TPU adaptation of Fig. 2 at serving granularity:
zero-copy (paged/mapped) vs copy-based (staged) KV admission, on the real
continuous-batching engine with a reduced model (CPU-runnable).

Also reports the paged-attention kernel's translation-traffic A/B:
table-resident-in-SMEM (the paper's LLC-on) vs gather-through-HBM (LLC-off),
as modeled data movement per decode step.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.engine import ServingEngine
from repro.models import init_params


def _run_engine(mode: str, n_req: int = 6, max_tokens: int = 8):
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    params = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                        offload_mode=mode)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, size=12).tolist(),
                   max_tokens=max_tokens)
    done = eng.run()
    wall = time.perf_counter() - t0
    return wall, eng.stats(), done


def run() -> List[str]:
    rows = []
    stats = {}
    for mode in ("zero_copy", "copy"):
        wall, s, done = _run_engine(mode)
        stats[mode] = (wall, s)
        rows.append(f"paged_serving.{mode},{wall*1e6:.0f},"
                    f"tokens={s['tokens']} prefill_s={s['prefill_s']:.3f} "
                    f"staging_copies={s['staging_copies']} "
                    f"bytes_copied={s['sva']['bytes_copied']}")
    zc, cp = stats["zero_copy"][0], stats["copy"][0]
    rows.append(f"paged_serving.zero_copy_advantage,{100*(1-zc/cp):.1f},"
                "percent wall-time saved (CPU engine; paper Fig.2 analogue)")

    # Fig. 2's actual claim, at serving granularity: ADMISSION bytes moved.
    # zero_copy uploads int32 table entries (the paper's 24 B per 4 KiB
    # page); copy stages the prompt's full KV.
    zs, cs = stats["zero_copy"][1], stats["copy"][1]
    zc_admit = zs["admit_table_bytes"]
    cp_admit = cs["sva"]["bytes_copied"]
    rows.append(f"paged_serving.zero_copy_admission_bytes,{zc_admit},"
                f"int32 table entries only "
                f"({zs['sva']['table_entries_written']} entries written)")
    rows.append(f"paged_serving.copy_admission_bytes,{cp_admit},"
                "full KV staged per admitted prompt")
    rows.append(f"paged_serving.admission_bytes_ratio,"
                f"{cp_admit/max(zc_admit,1):.1f},x less admission traffic "
                "with mapped pages (Fig.2 analogue)")
    # Decode-path translation maintenance: delta vs full table uploads.
    rows.append(f"paged_serving.delta_table_upload_bytes,"
                f"{zs['table_upload_bytes']},"
                f"full={zs['table_uploads_full']} "
                f"delta={zs['table_uploads_delta']} "
                f"rows={zs['table_rows_uploaded']} (zero_copy)")
    rows.append(f"paged_serving.full_table_upload_bytes,"
                f"{cs['table_upload_bytes']},"
                f"full re-upload every step x{cs['table_uploads_full']} (copy)")

    # translation-traffic A/B per decode step (modeled bytes):
    cfg = get_config("qwen2-7b")
    B, L, page = 128, 32768, 64
    n_pages = L // page
    kv_layers = cfg.n_layers
    kv_bytes = 2 * B * L * cfg.n_kv_heads * cfg.d_head * 2 * kv_layers
    table_bytes = B * n_pages * 4 * kv_layers
    rows.append(f"paged_serving.table_smem_bytes,{table_bytes},"
                "block tables scalar-prefetched once per step (LLC-on analogue)")
    rows.append(f"paged_serving.table_hbm_gather_bytes,{kv_bytes},"
                "extra pool copy when translations resolve via HBM gather "
                "(LLC-off analogue)")
    rows.append(f"paged_serving.translation_traffic_ratio,"
                f"{kv_bytes/max(table_bytes,1):.0f},x less traffic with "
                "SMEM-resident tables (qwen2-7b decode_32k)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
