"""Benchmark E5 — the TPU adaptation of Fig. 2 at serving granularity:
zero-copy (paged/mapped) vs copy-based (staged) KV admission, on the real
continuous-batching engine with a reduced model (CPU-runnable).

Adds the PREFIX-HEAVY workload: many requests sharing a common system
prompt (plus some exact-duplicate prompts), served with copy-on-write
prefix sharing ON vs OFF — reporting pages shared, prefill tokens saved,
CoW page duplications, and verifying decode outputs are bit-identical to
unshared serving (physical placement never changes results).

Also reports the paged-attention kernel's translation-traffic A/B:
table-resident-in-SMEM (the paper's LLC-on) vs gather-through-HBM (LLC-off),
as modeled data movement per decode step.

``--dry-run`` runs a minimal-size fast path (CI smoke).
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.serving.engine import ServingEngine
from repro.models import init_params


def _cfg_params():
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    return cfg, init_params(cfg, jax.random.key(0))


def _run_engine(mode: str, n_req: int = 6, max_tokens: int = 8):
    cfg, params = _cfg_params()
    eng = ServingEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                        offload_mode=mode)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, size=12).tolist(),
                   max_tokens=max_tokens)
    done = eng.run()
    wall = time.perf_counter() - t0
    return wall, eng.stats(), done


def _prefix_heavy_prompts(n_req: int, vocab: int):
    """A serving mix dominated by a shared system prompt: half the requests
    are EXACT duplicates of one popular prompt (retries / common question —
    these also share the partially-filled tail page, so their first decode
    divergence exercises CoW), a quarter append a distinct user turn, a
    quarter are unrelated."""
    rng = np.random.default_rng(7)
    system = rng.integers(0, vocab, size=24).tolist()   # 3 full pages @ 8
    dup = system + rng.integers(0, vocab, size=5).tolist()
    prompts = []
    for i in range(n_req):
        if i % 4 == 3:
            prompts.append(rng.integers(0, vocab, size=10).tolist())
        elif i % 4 in (1, 2):
            prompts.append(list(dup))
        else:
            prompts.append(system + rng.integers(0, vocab, size=6).tolist())
    return prompts


def _run_prefix_workload(share: bool, n_req: int, max_tokens: int):
    cfg, params = _cfg_params()
    eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                        prefix_sharing=share)
    prompts = _prefix_heavy_prompts(n_req, cfg.vocab_size)
    t0 = time.perf_counter()
    rids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
    done = eng.run()
    wall = time.perf_counter() - t0
    outs = [done[r].out_tokens for r in rids]
    return wall, eng.stats(), outs


def run(dry_run: bool = False) -> List[str]:
    n_req, max_tokens = (4, 4) if dry_run else (6, 8)
    rows = []
    stats = {}
    for mode in ("zero_copy", "copy"):
        wall, s, done = _run_engine(mode, n_req=n_req, max_tokens=max_tokens)
        stats[mode] = (wall, s)
        rows.append(f"paged_serving.{mode},{wall*1e6:.0f},"
                    f"tokens={s['tokens']} prefill_s={s['prefill_s']:.3f} "
                    f"staging_copies={s['staging_copies']} "
                    f"bytes_copied={s['sva']['bytes_copied']}")
    zc, cp = stats["zero_copy"][0], stats["copy"][0]
    rows.append(f"paged_serving.zero_copy_advantage,{100*(1-zc/cp):.1f},"
                "percent wall-time saved (CPU engine; paper Fig.2 analogue)")

    # Fig. 2's actual claim, at serving granularity: ADMISSION bytes moved.
    # zero_copy uploads int32 table entries (the paper's 24 B per 4 KiB
    # page); copy stages the prompt's full KV.
    zs, cs = stats["zero_copy"][1], stats["copy"][1]
    zc_admit = zs["admit_table_bytes"]
    cp_admit = cs["sva"]["bytes_copied"]
    rows.append(f"paged_serving.zero_copy_admission_bytes,{zc_admit},"
                f"int32 table entries only "
                f"({zs['sva']['table_entries_written']} entries written)")
    rows.append(f"paged_serving.copy_admission_bytes,{cp_admit},"
                "full KV staged per admitted prompt")
    rows.append(f"paged_serving.admission_bytes_ratio,"
                f"{cp_admit/max(zc_admit,1):.1f},x less admission traffic "
                "with mapped pages (Fig.2 analogue)")
    # Decode-path translation maintenance: delta vs full table uploads.
    rows.append(f"paged_serving.delta_table_upload_bytes,"
                f"{zs['table_upload_bytes']},"
                f"full={zs['table_uploads_full']} "
                f"delta={zs['table_uploads_delta']} "
                f"rows={zs['table_rows_uploaded']} (zero_copy)")
    rows.append(f"paged_serving.full_table_upload_bytes,"
                f"{cs['table_upload_bytes']},"
                f"full re-upload every step x{cs['table_uploads_full']} (copy)")

    # ------------------------------------------ prefix-heavy CoW workload
    pn = 4 if dry_run else 12
    w_on, s_on, out_on = _run_prefix_workload(True, pn, max_tokens)
    w_off, s_off, out_off = _run_prefix_workload(False, pn, max_tokens)
    # Token-identical on this platform (asserted strictly in
    # tests/test_sva_serving.py); reported rather than asserted here since
    # the shared path uses a different (dense) prefill attention whose
    # argmax is not formally guaranteed across BLAS/backends.
    identical = out_on == out_off
    pf = s_on["prefix"]
    rows.append(f"paged_serving.prefix_pages_shared,{pf['pages_shared']},"
                f"hits={pf['hits']} misses={pf['misses']} "
                f"steals={pf['steals']} evictions={pf['evictions']} "
                f"(token-identical to unshared: {identical})")
    rows.append(f"paged_serving.prefill_tokens_saved,"
                f"{s_on['prefill_tokens_saved']},"
                f"prompt tokens NOT recomputed at admission "
                f"(shared_admissions={s_on['shared_admissions']}; "
                f"unshared baseline saves {s_off['prefill_tokens_saved']})")
    rows.append(f"paged_serving.cow_page_copies,{s_on['cow_page_copies']},"
                "device page duplications on write-into-shared-page "
                "(one page of KV per layer vs re-prefilling the prefix)")
    rows.append(f"paged_serving.prefix_prefill_s,"
                f"{s_on['prefill_s']*1e3:.1f},ms prefill with sharing "
                f"(vs {s_off['prefill_s']*1e3:.1f} ms unshared; wall "
                f"{w_on*1e3:.0f} vs {w_off*1e3:.0f} ms). NOTE: at smoke "
                "scale wall time is dominated by the extra jit traces and "
                "the dense prefix-context attention, not the saved tokens; "
                "the scale-relevant win is prefill_tokens_saved")

    # translation-traffic A/B per decode step (modeled bytes):
    cfg = get_config("qwen2-7b")
    B, L, page = 128, 32768, 64
    n_pages = L // page
    kv_layers = cfg.n_layers
    kv_bytes = 2 * B * L * cfg.n_kv_heads * cfg.d_head * 2 * kv_layers
    table_bytes = B * n_pages * 4 * kv_layers
    rows.append(f"paged_serving.table_smem_bytes,{table_bytes},"
                "block tables scalar-prefetched once per step (LLC-on analogue)")
    rows.append(f"paged_serving.table_hbm_gather_bytes,{kv_bytes},"
                "extra pool copy when translations resolve via HBM gather "
                "(LLC-off analogue)")
    rows.append(f"paged_serving.translation_traffic_ratio,"
                f"{kv_bytes/max(table_bytes,1):.0f},x less traffic with "
                "SMEM-resident tables (qwen2-7b decode_32k)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="minimal sizes (CI smoke path)")
    args = ap.parse_args()
    print("\n".join(run(dry_run=args.dry_run)))
